"""HLO-text evaluator mirroring the Rust compiled interpreter bit-exactly.

Parses the same HLO text interchange format as
rust/vendor/xla/src/interp/parse.rs and evaluates entries with the same
numeric semantics as the compiled register program
(program.rs/kernels.rs/exec.rs):

* all f32 elementwise arithmetic is IEEE single precision (numpy float32
  ufuncs — correctly rounded per element, like the Rust loops);
* transcendentals go through :mod:`mirror.fmath` (the bit-exact mirror of
  interp/fmath.rs) — never numpy's own exp/log;
* ``maximum``/``minimum`` mirror Rust ``f32::max``/``min`` (NaN-ignoring);
* ``dot`` accumulates each output element into 8 pinned lanes —
  contribution ``kk`` lands in lane ``kk % 8``, ascending ``kk`` within
  each lane, mul-then-add (no FMA) — then folds all 8 lanes in the fixed
  order ``((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))``.  This is the single
  lanes contract every kernels::dot variant implements in both the SIMD
  and scalar interpreter tiers, so the mirror needs exactly one dot;
* ``reduce``: add-reductions whose output map is grouped
  (``map[i] == i // group``, e.g. trailing-dim sums) use the same 8-lane
  pinned accumulation with ``out = init + fold``; every other reduce
  folds flat-ascending per output element, exactly like kernels::reduce;
  multi-op regions are evaluated per element with f32 scalar semantics
  (the scalar register program's arithmetic).

Data movement (broadcast/transpose/slice/pad/concatenate) is exact in any
implementation, so numpy indexing is used directly.

KEEP IN SYNC with the Rust interp module: same op set, same orders.
"""

from __future__ import annotations

import numpy as np

from . import fmath


# ------------------------------------------------------------------ parsing


def _split_top(s: str, sep: str) -> list[str]:
    out, depth, cur = [], 0, []
    for c in s:
        if c in "({[":
            depth += 1
        elif c in ")}]":
            depth -= 1
        if c == sep and depth == 0:
            tok = "".join(cur).strip()
            if tok:
                out.append(tok)
            cur = []
        else:
            cur.append(c)
    tok = "".join(cur).strip()
    if tok:
        out.append(tok)
    return out


_DTYPES = {"f32": np.float32, "s32": np.int32, "pred": np.bool_}


def _parse_dense_shape(tok: str):
    tok = tok.strip()
    dt, rest = tok.split("[", 1)
    dtype = _DTYPES[dt.strip()]
    dims_str = rest.split("]", 1)[0]
    dims = tuple(int(d) for d in dims_str.split(",") if d.strip()) if dims_str.strip() else ()
    return dtype, dims


def _parse_shape_spec(s: str):
    s = s.strip()
    if s.startswith("("):
        inner = s[1:].rsplit(")", 1)[0]
        return [("tuple", _parse_dense_shape(p)) for p in _split_top(inner, ",")]
    return _parse_dense_shape(s)


def _parse_usize_set(s: str) -> list[int]:
    inner = s.strip().lstrip("{").rstrip("}")
    return [int(p) for p in inner.split(",") if p.strip()]


def _parse_slice_spec(s: str):
    inner = s.strip().lstrip("{").rstrip("}")
    out = []
    for piece in _split_top(inner, ","):
        parts = piece.strip().lstrip("[").rstrip("]").split(":")
        stride = int(parts[2]) if len(parts) == 3 else 1
        out.append((int(parts[0]), int(parts[1]), stride))
    return out


def _parse_padding_spec(s: str):
    out = []
    for piece in s.strip().split("x"):
        parts = piece.split("_")
        interior = int(parts[2]) if len(parts) == 3 else 0
        out.append((int(parts[0]), int(parts[1]), interior))
    return out


def _operand_name(tok: str) -> str:
    return tok.split()[-1].lstrip("%")


def _strip_comments(s: str) -> str:
    """Drop ``/* ... */`` comments (mirror of parse.rs strip_comments):
    jax annotates long tuple types with ``/*index=N*/``.  An unterminated
    comment drops the tail."""
    out, i = [], 0
    while True:
        j = s.find("/*", i)
        if j < 0:
            out.append(s[i:])
            break
        out.append(s[i:j])
        e = s.find("*/", j + 2)
        if e < 0:
            break
        i = e + 2
    return "".join(out)


def _parse_window_spec(s: str):
    """``window={size=3x3 stride=2x2 pad=1_1x1_1 rhs_dilate=2x2}`` →
    per-dimension dicts, same defaulting as parse.rs parse_window_spec."""
    fields = {}
    for tok in s.strip().lstrip("{").rstrip("}").split():
        key, val = tok.split("=", 1)
        if key not in ("size", "stride", "pad", "lhs_dilate", "rhs_dilate"):
            raise NotImplementedError(f"unsupported window key {key!r}")
        fields[key] = val
    size = [int(v) for v in fields["size"].split("x")]

    def nums(key):
        if key not in fields:
            return [1] * len(size)
        return [int(v) for v in fields[key].split("x")]

    stride, lhs_d, rhs_d = nums("stride"), nums("lhs_dilate"), nums("rhs_dilate")
    if "pad" in fields:
        pad = [tuple(int(x) for x in p.split("_")) for p in fields["pad"].split("x")]
    else:
        pad = [(0, 0)] * len(size)
    return [
        {
            "size": size[d],
            "stride": stride[d],
            "pad_lo": pad[d][0],
            "pad_hi": pad[d][1],
            "base_dilation": lhs_d[d],
            "window_dilation": rhs_d[d],
        }
        for d in range(len(size))
    ]


def _dim_order(seg: str, bc: str, fc: str):
    """One dim_labels segment → (batch pos, feature pos, spatial positions
    sorted by digit) — mirror of program.rs parse_dim_order."""
    b = f = None
    sp: dict[int, int] = {}
    for i, c in enumerate(seg):
        if c == bc:
            b = i
        elif c == fc:
            f = i
        else:
            sp[int(c)] = i
    return b, f, [sp[d] for d in sorted(sp)]


class Instr:
    __slots__ = ("name", "shape", "op", "operands", "attrs", "param", "literal", "is_root")


def _parse_constant(payload: str, dtype, dims):
    toks = payload.replace("{", " ").replace("}", " ").replace(",", " ").split()
    if dtype is np.float32:
        vals = [np.float32(t) for t in toks]
    elif dtype is np.int32:
        vals = [np.int32(t) for t in toks]
    else:
        vals = [t in ("true", "1") for t in toks]
    return np.array(vals, dtype=dtype).reshape(dims)


def _parse_instr(line: str) -> tuple[Instr, list[str]]:
    lhs, rhs = line.split(" = ", 1)
    lhs = lhs.strip()
    ins = Instr()
    ins.is_root = lhs.startswith("ROOT ")
    ins.name = lhs.removeprefix("ROOT ").strip().lstrip("%")
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth, cut = 0, None
        for i, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    cut = i + 1
                    break
        shape_str, rest = rhs[:cut], rhs[cut:].lstrip()
    else:
        cut = rhs.index(" ")
        shape_str, rest = rhs[:cut], rhs[cut:].lstrip()
    ins.shape = _parse_shape_spec(shape_str)

    open_ix = rest.index("(")
    ins.op = rest[:open_ix].strip()
    depth, close = 0, None
    for i in range(open_ix, len(rest)):
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                close = i
                break
    payload = rest[open_ix + 1 : close]
    attrs_str = rest[close + 1 :].lstrip(",").strip()

    attrs = {}
    for piece in _split_top(attrs_str, ","):
        if "=" not in piece:
            continue
        key, val = piece.split("=", 1)
        key = key.strip()
        if key == "dimensions":
            attrs["dimensions"] = _parse_usize_set(val)
        elif key == "slice":
            attrs["slice"] = _parse_slice_spec(val)
        elif key == "padding":
            attrs["padding"] = _parse_padding_spec(val)
        elif key == "direction":
            attrs["direction"] = val.strip()
        elif key == "to_apply":
            attrs["to_apply"] = val.strip().lstrip("%")
        elif key == "lhs_contracting_dims":
            attrs["lhs_contracting"] = _parse_usize_set(val)
        elif key == "rhs_contracting_dims":
            attrs["rhs_contracting"] = _parse_usize_set(val)
        elif key == "lhs_batch_dims":
            attrs["lhs_batch"] = _parse_usize_set(val)
        elif key == "rhs_batch_dims":
            attrs["rhs_batch"] = _parse_usize_set(val)
        elif key == "index":
            attrs["index"] = int(val.strip())
        elif key == "iota_dimension":
            attrs["iota_dimension"] = int(val.strip())
        elif key == "window":
            attrs["window"] = _parse_window_spec(val)
        elif key == "dim_labels":
            attrs["dim_labels"] = val.strip()
        elif key == "feature_group_count":
            attrs["feature_group_count"] = int(val.strip())
        elif key == "batch_group_count":
            attrs["batch_group_count"] = int(val.strip())
        elif key == "condition":
            attrs["condition"] = val.strip().lstrip("%")
        elif key == "body":
            attrs["body"] = val.strip().lstrip("%")
        elif key == "dynamic_slice_sizes":
            attrs["dynamic_slice_sizes"] = _parse_usize_set(val)
    ins.attrs = attrs

    ins.param = None
    ins.literal = None
    operand_names: list[str] = []
    if ins.op == "parameter":
        ins.param = int(payload.strip())
    elif ins.op == "constant":
        dtype, dims = ins.shape
        ins.literal = _parse_constant(payload, dtype, dims)
    else:
        operand_names = [_operand_name(t) for t in _split_top(payload, ",")]
    ins.operands = []
    return ins, operand_names


class Computation:
    def __init__(self, name: str, raws):
        self.name = name
        index = {ins.name: i for i, (ins, _) in enumerate(raws)}
        self.instrs = []
        self.params: list[tuple[int, int]] = []
        self.root = len(raws) - 1
        for i, (ins, names) in enumerate(raws):
            ins.operands = [index[n] for n in names]
            if ins.param is not None:
                self.params.append((ins.param, i))
            if ins.is_root:
                self.root = i
            self.instrs.append(ins)
        self.params = [i for _, i in sorted(self.params)]


class Module:
    """Parsed HLO module (same grammar as parse.rs)."""

    def __init__(self, text: str):
        self.computations: list[Computation] = []
        self.by_name: dict[str, int] = {}
        self.entry = None
        cur = None
        for raw in text.splitlines():
            line = _strip_comments(raw).strip()
            if not line or line.startswith("HloModule") or line.startswith("//"):
                continue
            if line == "}":
                name, is_entry, raws = cur
                comp = Computation(name, raws)
                self.by_name[name] = len(self.computations)
                if is_entry:
                    self.entry = len(self.computations)
                self.computations.append(comp)
                cur = None
                continue
            if line.endswith("{") and " = " not in line:
                is_entry = line.startswith("ENTRY ")
                rest = line.removeprefix("ENTRY ")
                name = rest.split()[0].lstrip("%").split("(")[0]
                cur = (name, is_entry, [])
                continue
            cur[2].append(_parse_instr(line))
        if self.entry is None:
            assert len(self.computations) == 1
            self.entry = 0

    def computation(self, name: str) -> Computation:
        return self.computations[self.by_name[name]]

    # ------------------------------------------------------------ evaluate

    def evaluate(self, args):
        comp = self.computations[self.entry]
        assert len(args) == len(comp.params), "argument arity"
        return self._eval_computation(comp, args)

    def _eval_computation(self, comp, args):
        env = [None] * len(comp.instrs)
        for idx in range(len(comp.instrs)):
            env[idx] = self._eval(comp, idx, env, args)
        return env[comp.root]

    def _eval(self, comp, idx, env, args):
        ins = comp.instrs[idx]
        op = ins.op
        opv = lambda i: env[ins.operands[i]]  # noqa: E731
        if op == "parameter":
            a = args[ins.param]
            return a if isinstance(a, tuple) else np.asarray(a)
        if op == "constant":
            return ins.literal
        if op in _BINARY_F32:
            return _BINARY_F32[op](opv(0), opv(1))
        if op in _UNARY_F32:
            return _UNARY_F32[op](opv(0))
        if op == "compare":
            return _compare(ins.attrs["direction"], opv(0), opv(1))
        if op == "select":
            return np.where(opv(0), opv(1), opv(2))
        if op == "convert":
            dtype, _ = ins.shape
            return _convert(opv(0), dtype)
        if op == "broadcast":
            _, dims = ins.shape
            return _broadcast(opv(0), ins.attrs.get("dimensions", []), dims)
        if op == "reshape":
            _, dims = ins.shape
            return opv(0).reshape(dims)
        if op == "transpose":
            return np.transpose(opv(0), ins.attrs["dimensions"]).copy()
        if op == "slice":
            sl = tuple(slice(s, l, st) for (s, l, st) in ins.attrs["slice"])
            return opv(0)[sl].copy()
        if op == "pad":
            return _pad(opv(0), opv(1), ins.attrs["padding"])
        if op == "concatenate":
            dim = ins.attrs.get("dimensions", [0])[0]
            return np.concatenate([opv(i) for i in range(len(ins.operands))], axis=dim)
        if op == "dot":
            return _dot(opv(0), opv(1), ins.attrs)
        if op == "reduce":
            return self._reduce(opv(0), opv(1), ins.attrs)
        if op == "iota":
            dtype, dims = ins.shape
            dim = ins.attrs.get("iota_dimension", 0)
            idxs = np.arange(dims[dim] if dims else 1)
            shape = [1] * len(dims)
            if dims:
                shape[dim] = dims[dim]
            return np.broadcast_to(idxs.reshape(shape), dims or ()).astype(dtype).copy()
        if op == "tuple":
            return tuple(opv(i) for i in range(len(ins.operands)))
        if op == "get-tuple-element":
            return opv(0)[ins.attrs["index"]]
        if op == "reverse":
            dims = ins.attrs.get("dimensions", [])
            return np.flip(opv(0), axis=tuple(dims)).copy() if dims else opv(0).copy()
        if op == "convolution":
            return _convolution(opv(0), opv(1), ins.attrs)
        if op == "dynamic-slice":
            src = opv(0)
            sizes = ins.attrs["dynamic_slice_sizes"]
            offs = [
                _clamp_start(opv(1 + d), src.shape[d], sizes[d]) for d in range(src.ndim)
            ]
            index = tuple(slice(o, o + sz) for o, sz in zip(offs, sizes))
            return src[index].copy()
        if op == "dynamic-update-slice":
            src, upd = opv(0), opv(1)
            offs = [
                _clamp_start(opv(2 + d), src.shape[d], upd.shape[d])
                for d in range(src.ndim)
            ]
            out = src.copy()
            out[tuple(slice(o, o + sz) for o, sz in zip(offs, upd.shape))] = upd
            return out
        if op == "call":
            callee = self.computation(ins.attrs["to_apply"])
            return self._eval_computation(callee, [opv(i) for i in range(len(ins.operands))])
        if op == "while":
            cond = self.computation(ins.attrs["condition"])
            body = self.computation(ins.attrs["body"])
            state = opv(0)
            while bool(np.asarray(self._eval_computation(cond, [state])).reshape(())):
                state = self._eval_computation(body, [state])
            return state
        raise NotImplementedError(op)

    def _reduce(self, data, init, attrs):
        red = attrs["dimensions"]
        dims = data.shape
        keep = [d for d in range(len(dims)) if d not in red]
        out_dims = tuple(dims[d] for d in keep)
        comp = self.computation(attrs["to_apply"])
        fast = _fast_binop(comp)
        flat = data.reshape(-1)
        # map[in_flat] -> out_flat, identical to program.rs lower_reduce.
        out_elems = int(np.prod(out_dims)) if out_dims else 1
        strides = _row_major_strides(dims)
        out_strides = _row_major_strides(out_dims)
        acc = np.full(out_elems, init.reshape(()).astype(np.float32), dtype=np.float32)
        idx = np.arange(flat.size)
        of = np.zeros(flat.size, dtype=np.int64)
        for k, d in enumerate(keep):
            coord = (idx // strides[d]) % dims[d]
            of += coord * out_strides[k]
        grouped = (
            fast == "add"
            and flat.size > 0
            and out_elems > 0
            and flat.size % out_elems == 0
            and np.array_equal(of, idx // (flat.size // out_elems))
        )
        if grouped:
            # Grouped add reduction (map[i] == i // group, e.g. trailing-dim
            # sums): mirror of kernels::reduce_grouped_lanes.  Contribution
            # kk goes to lane kk % 8 in ascending order; all 8 lanes are
            # folded ((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7)); out = init+fold.
            group = flat.size // out_elems
            r = flat.reshape(out_elems, group)
            lanes = [np.zeros(out_elems, dtype=np.float32) for _ in range(8)]
            with np.errstate(all="ignore"):
                for kk in range(group):
                    lanes[kk % 8] = lanes[kk % 8] + r[:, kk]
                acc = acc + _fold8(lanes)
            return acc.reshape(out_dims)
        for i in range(flat.size):
            o = int(of[i])
            x = flat[i]
            if fast == "add":
                acc[o] = acc[o] + x
            elif fast == "multiply":
                acc[o] = acc[o] * x
            elif fast == "maximum":
                acc[o] = _scalar_max(acc[o], x)
            elif fast == "minimum":
                acc[o] = _scalar_min(acc[o], x)
            else:
                acc[o] = _apply_region(self, comp, acc[o], x)
        return acc.reshape(out_dims)


def _row_major_strides(dims):
    s = [1] * len(dims)
    for i in range(len(dims) - 2, -1, -1):
        s[i] = s[i + 1] * dims[i + 1]
    return s


def _fast_binop(comp):
    if len(comp.instrs) != 3 or len(comp.params) != 2:
        return None
    root = comp.instrs[comp.root]
    if (
        len(root.operands) == 2
        and comp.instrs[root.operands[0]].op == "parameter"
        and comp.instrs[root.operands[1]].op == "parameter"
    ):
        return root.op
    return None


def _apply_region(module, comp, acc, x):
    """Evaluate a reduce region on scalars (acc, x) with f32 semantics —
    numerically identical to the compiled scalar register program."""
    env = [None] * len(comp.instrs)
    args = {comp.params[0]: acc, comp.params[1]: x}
    for idx, ins in enumerate(comp.instrs):
        if ins.op == "parameter":
            env[idx] = args[idx]
        elif ins.op == "constant":
            env[idx] = ins.literal.reshape(())
        elif ins.op in ("reshape", "copy"):
            env[idx] = env[ins.operands[0]]
        elif ins.op in _BINARY_F32:
            env[idx] = _BINARY_F32[ins.op](env[ins.operands[0]], env[ins.operands[1]])
        elif ins.op in _UNARY_F32:
            env[idx] = _UNARY_F32[ins.op](env[ins.operands[0]])
        else:
            raise NotImplementedError(f"region op {ins.op}")
    return np.float32(env[comp.root])


# ------------------------------------------------------------- op kernels


def _f32_max(a, b):
    # Rust f32::max: NaN-ignoring.
    with np.errstate(invalid="ignore"):
        r = np.maximum(a, b)
    r = np.where(np.isnan(a), b, r)
    r = np.where(np.isnan(b) & ~np.isnan(a), a, r)
    return r.astype(np.float32)


def _f32_min(a, b):
    with np.errstate(invalid="ignore"):
        r = np.minimum(a, b)
    r = np.where(np.isnan(a), b, r)
    r = np.where(np.isnan(b) & ~np.isnan(a), a, r)
    return r.astype(np.float32)


def _scalar_max(a, b):
    return _f32_max(np.float32(a), np.float32(b))


def _scalar_min(a, b):
    return _f32_min(np.float32(a), np.float32(b))


def _errwrap(f):
    def g(*a):
        with np.errstate(all="ignore"):
            return f(*a)

    return g


_BINARY_F32 = {
    "add": _errwrap(lambda a, b: a + b),
    "subtract": _errwrap(lambda a, b: a - b),
    "multiply": _errwrap(lambda a, b: a * b),
    "divide": _errwrap(lambda a, b: a / b),
    "maximum": _f32_max,
    "minimum": _f32_min,
    "power": fmath.pow,
    "remainder": _errwrap(np.fmod),
    "and": _errwrap(np.logical_and),
    "or": _errwrap(np.logical_or),
    "xor": _errwrap(np.logical_xor),
}

_UNARY_F32 = {
    "abs": _errwrap(np.abs),
    "negate": _errwrap(np.negative),
    "exponential": fmath.exp,
    "exponential-minus-one": fmath.exp_m1,
    "log": fmath.ln,
    "log-plus-one": fmath.ln_1p,
    "logistic": fmath.logistic,
    "tanh": fmath.tanh,
    "sqrt": fmath.sqrt,
    "rsqrt": fmath.rsqrt,
    "sign": _errwrap(lambda a: np.sign(a)),
    "floor": _errwrap(np.floor),
    "ceil": _errwrap(np.ceil),
    "cosine": fmath.cos,
    "sine": fmath.sin,
    "not": _errwrap(np.logical_not),
    "copy": lambda a: a.copy(),
}


def _compare(direction, a, b):
    with np.errstate(invalid="ignore"):
        if direction == "EQ":
            return a == b
        if direction == "NE":
            return a != b
        if direction == "LT":
            return a < b
        if direction == "GT":
            return a > b
        if direction == "LE":
            return a <= b
        if direction == "GE":
            return a >= b
    raise NotImplementedError(direction)


def _convert(a, dtype):
    if dtype is np.int32 and a.dtype == np.float32:
        # XLA rounds toward zero with saturation (Rust `as i32`).
        w = np.trunc(a.astype(np.float64))
        w = np.where(np.isnan(w), 0.0, np.clip(w, -2147483648.0, 2147483647.0))
        return w.astype(np.int64).astype(np.int32)
    if dtype is np.bool_:
        return a != 0
    return a.astype(dtype)


def _broadcast(a, mapping, out_dims):
    shape = [1] * len(out_dims)
    for i, od in enumerate(mapping):
        shape[od] = a.shape[i]
    return np.broadcast_to(a.reshape(shape), out_dims).copy()


def _pad(a, fill, spec):
    out_dims = tuple(
        lo + (0 if n == 0 else n + (n - 1) * interior) + hi
        for n, (lo, hi, interior) in zip(a.shape, spec)
    )
    out = np.full(out_dims, fill.reshape(()), dtype=a.dtype)
    index = tuple(
        slice(lo, lo + (n - 1) * (1 + interior) + 1 if n else lo, 1 + interior)
        for n, (lo, _hi, interior) in zip(a.shape, spec)
    )
    if all(n > 0 for n in a.shape):
        out[index] = a
    return out


def _dot(a, b, attrs):
    lc = attrs["lhs_contracting"][0]
    rc = attrs["rhs_contracting"][0]
    lbd = attrs.get("lhs_batch", [])
    rbd = attrs.get("rhs_batch", [])
    k = a.shape[lc]
    # Collapse to (B, M, K) and (B, K, N) — batch dims first, free dims in
    # original order, which is exactly the compiled plan's per-slice
    # l_base/r_base ordering (b=1 for an unbatched dot).
    lfree = [d for d in range(a.ndim) if d != lc and d not in lbd]
    rfree = [d for d in range(b.ndim) if d != rc and d not in rbd]
    l3 = np.transpose(a, lbd + lfree + [lc]).reshape(-1, int(np.prod([a.shape[d] for d in lfree], dtype=np.int64)), k)
    r3 = np.transpose(b, rbd + [rc] + rfree).reshape(l3.shape[0], k, -1)
    out_dims = (
        tuple(a.shape[d] for d in lbd)
        + tuple(a.shape[d] for d in lfree)
        + tuple(b.shape[d] for d in rfree)
    )
    slices = [_lanes_matmul(l3[bx], r3[bx]) for bx in range(l3.shape[0])]
    return np.stack(slices).reshape(out_dims)


def _lanes_matmul(l2, r2):
    """(M, K) x (K, N) under the pinned 8-lane accumulation contract
    shared by every compiled dot variant: contribution kk lands in lane
    kk % 8, ascending kk, mul then add (no FMA), then the fixed hfold8
    tree fold."""
    k = l2.shape[1]
    lanes = [np.zeros((l2.shape[0], r2.shape[1]), dtype=np.float32) for _ in range(8)]
    with np.errstate(all="ignore"):
        for kk in range(k):
            lanes[kk % 8] = lanes[kk % 8] + l2[:, kk : kk + 1] * r2[kk : kk + 1, :]
        return _fold8(lanes)


def _clamp_start(v, dim: int, size: int) -> int:
    # HLO dynamic-slice start clamp: start.clamp(0, dim - size), exactly
    # like exec.rs start_offsets / reference.rs dynamic_slice.
    return max(0, min(int(np.asarray(v).reshape(())), dim - size))


def _convolution(a, b, attrs):
    """Mirror of the compiled convolution (program.rs lower_conv + the
    Conv step in exec.rs): per feature group, gather the input patch
    matrix (M, K) with K ordered kernel-spatial-outer / group-local input
    feature fastest (zero fill outside the padded extent), gather the
    kernel matrix (K, Ng), multiply under the pinned-lanes contract, and
    scatter into the declared output layout.

    The Rust side now has two strategies — the materialized im2col path
    (pad + gather + dot + scatter through shared scratch) and the fused
    blocked kernel (kernels.rs conv_blocked, selected by
    cost::select_conv_algo or DIVEBATCH_CONV_ALGO) — but both consume the
    same precomputed patch/weight gather maps in the same K-contraction
    order, so the pinned 8-lane contract (contribution kk in lane kk % 8,
    ascending kk, mul then add, hfold8 fold; halo entries still multiply
    0.0) fully determines every output element's bits.  This one mirror is
    therefore bit-identical to BOTH Rust strategies on BOTH tiers; the
    lane assignment depends only on the shared K order."""
    in_seg, rest = attrs["dim_labels"].split("_", 1)
    ker_seg, out_seg = rest.split("->", 1)
    ib, if_, isp = _dim_order(in_seg, "b", "f")
    ki_, ko_, ksp = _dim_order(ker_seg, "i", "o")
    ob, of_, osp = _dim_order(out_seg, "b", "f")
    window = attrs["window"]
    groups = attrs.get("feature_group_count", 1)
    batch, ci = a.shape[ib], a.shape[if_]
    ki, ko = b.shape[ki_], b.shape[ko_]
    assert ci == groups * ki and ko % groups == 0, "feature_group_count partition"
    ng = ko // groups
    in_sp = [a.shape[p] for p in isp]
    ker_sp = [b.shape[p] for p in ksp]
    s = len(isp)
    out_sp = []
    for d in range(s):
        w = window[d]
        extent = (w["size"] - 1) * w["window_dilation"] + 1
        # lhs_dilate (transposed conv): the input is virtually interior-
        # dilated to (n-1)*base + 1 taps; positions between real taps are
        # halo (zero) entries in the gather below, exactly as in Rust.
        dil_in = 0 if in_sp[d] == 0 else (in_sp[d] - 1) * w["base_dilation"] + 1
        out_sp.append((dil_in + w["pad_lo"] + w["pad_hi"] - extent) // w["stride"] + 1)
    # Canonical layouts: input (B, CI, spatial-flat), kernel (KI, KO,
    # kernel-spatial-flat).
    lt = np.transpose(a, [ib, if_] + isp).reshape(batch, ci, -1)
    kt = np.transpose(b, [ki_, ko_] + ksp).reshape(ki, ko, -1)
    osp_elems = int(np.prod(out_sp)) if out_sp else 1
    ksp_elems = int(np.prod(ker_sp)) if ker_sp else 1
    oc = np.indices(out_sp).reshape(s, -1) if s else np.zeros((0, 1), dtype=np.int64)
    kc = np.indices(ker_sp).reshape(s, -1) if s else np.zeros((0, 1), dtype=np.int64)
    in_st = _row_major_strides(in_sp)
    flat = np.zeros((osp_elems, ksp_elems), dtype=np.int64)
    inside = np.ones((osp_elems, ksp_elems), dtype=bool)
    for d in range(s):
        w = window[d]
        base = w["base_dilation"]
        # Window position in the lhs-dilated coordinate system; only
        # multiples of base_dilation hit a real input tap.
        iy = oc[d][:, None] * w["stride"] - w["pad_lo"] + kc[d][None, :] * w["window_dilation"]
        qy = iy // base
        inside &= (iy >= 0) & (iy % base == 0) & (qy < in_sp[d])
        flat += np.clip(qy, 0, in_sp[d] - 1) * in_st[d]
    out = np.zeros((batch, ko, osp_elems), dtype=np.float32)
    for gx in range(groups):
        # patch[r, c]: r = b*osp + ospi, c = kspi*ki + fi — kernels::pad
        # with the compiled patch_map (u32::MAX cells -> 0.0 fill).
        patch = lt[:, gx * ki : (gx + 1) * ki, :][:, :, flat]  # (B, ki, osp, ksp)
        patch = np.where(inside[None, None], patch, np.float32(0))
        patch = patch.transpose(0, 2, 3, 1).reshape(batch * osp_elems, ksp_elems * ki)
        w2 = kt[:, gx * ng : (gx + 1) * ng, :].transpose(2, 0, 1).reshape(ksp_elems * ki, ng)
        acc = _lanes_matmul(patch, w2)  # (M, ng)
        out[:, gx * ng : (gx + 1) * ng, :] = acc.reshape(batch, osp_elems, ng).transpose(
            0, 2, 1
        )
    out = out.reshape([batch, ko] + out_sp)
    # Inverse-permute the canonical (b, f, spatial...) axes back to the
    # declared output layout.
    return np.transpose(out, np.argsort([ob, of_] + osp)).copy()


def _fold8(lanes):
    # KEEP IN SYNC with kernels::hfold8: ((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7)).
    return ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3])) + (
        (lanes[4] + lanes[5]) + (lanes[6] + lanes[7])
    )


# ---------------------------------------------------------- entry wrappers


class Executable:
    """One compiled HLO entry (mirror of runtime Executable numerics)."""

    def __init__(self, path: str):
        with open(path) as f:
            self.module = Module(f.read())

    def run(self, args):
        out = self.module.evaluate([np.asarray(a) for a in args])
        return out if isinstance(out, tuple) else (out,)
