"""Regenerate rust/tests/fixtures/golden_run_record.json from the mirror.

Usage (from the python/ directory):

    python -m mirror.golden_run [--check]

Runs the mirrored golden training run (see trainer.GoldenRun) and writes
the canonical record JSON — byte-identical to what
`cargo test --test golden_record` produces with DIVEBATCH_BLESS=1 —
after first validating the interpreter mirror against the committed
jax-evaluated golden_entry_outputs.json (selfcheck).  With --check, the
existing committed file is compared instead of overwritten.
"""

from __future__ import annotations

import os
import sys

from . import rust_fmt, selfcheck
from .trainer import GoldenRun

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))
FIXTURES = os.path.join(REPO, "rust", "tests", "fixtures")


def canonical_record_json() -> str:
    record = GoldenRun(os.path.join(FIXTURES, "artifacts")).run()
    # to_canonical_json: wall-clock fields already masked to 0 by the
    # mirror; serialization is sorted-key compact JSON (util/json.rs).
    return rust_fmt.write_json(record)


def main(argv: list[str]) -> int:
    failures = selfcheck.run(FIXTURES)
    if failures:
        print("selfcheck FAILED — not writing the golden record:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("selfcheck: interp mirror matches the jax goldens")
    text = canonical_record_json()
    out = os.path.join(FIXTURES, "golden_run_record.json")
    if "--check" in argv:
        with open(out) as f:
            committed = f.read()
        if committed != text:
            print(f"MISMATCH against {out}")
            print(f"  committed: {committed[:200]}...")
            print(f"  mirrored:  {text[:200]}...")
            return 1
        print(f"{out} matches the mirrored run")
        return 0
    with open(out, "w") as f:
        f.write(text)
    print(f"wrote {out} ({len(text)} bytes)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
