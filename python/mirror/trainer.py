"""Bit-exact mirror of the Rust golden-run training pipeline.

Every component here reproduces, operation for operation, the Rust code
named in its docstring: util/rng.rs (xoshiro256++ / SplitMix64 /
Box-Muller), data/synthetic.rs, data/dataset.rs, coordinator/plan.rs,
coordinator/optimizer.rs (plain-SGD hot path), coordinator/diversity.rs,
coordinator/policy/baselines.rs (DiveBatch), coordinator/schedule.rs,
cluster/mod.rs, metrics/memory.rs, and coordinator/trainer.rs's run loop.

f64 state lives in Python floats (IEEE doubles), f32 state in numpy
float32 arrays; sequential accumulations keep the Rust iteration order.
The only libm calls are Box-Muller's log/sqrt/sin/cos — their outputs are
threshold-consumed (label signs), so last-ulp libm differences across
hosts cannot change the record.

KEEP IN SYNC with the Rust sources above; re-bless the golden via
`python -m mirror.golden_run` after any numeric change.
"""

from __future__ import annotations

import math
import os

import numpy as np

from . import interp

MASK = (1 << 64) - 1


def rust_round(x: float) -> float:
    """f64::round (half away from zero) for non-negative x — the only
    inputs the golden path produces.  `x - floor(x)` is exact in f64 for
    the magnitudes involved, so no spurious half-crossing can occur
    (Python's round() is half-even, hence this helper)."""
    assert x >= 0.0
    f = math.floor(x)
    return f + 1.0 if x - f >= 0.5 else f


# ------------------------------------------------------------ util/rng.rs


def _splitmix64(state: int) -> tuple[int, int]:
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, z ^ (z >> 31)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256++ seeded via SplitMix64 (util/rng.rs)."""

    def __init__(self, seed: int):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s
        self.spare = None

    def fork(self, stream: int) -> "Rng":
        sm = self.next_u64() ^ ((stream * 0xA24BAED4963EE407) & MASK)
        return Rng(sm)

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / 9007199254740992.0)

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()

    def below(self, n: int) -> int:
        threshold = ((1 << 64) - n) % n
        while True:
            r = self.next_u64()
            if r >= threshold:
                return r % n

    def normal(self) -> float:
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        u = 1.0 - self.next_f64()
        v = self.next_f64()
        r = math.sqrt(-2.0 * math.log(u))
        theta = 2.0 * math.pi * v
        self.spare = r * math.sin(theta)
        return r * math.cos(theta)

    def normal_ms(self, mean: float, std: float) -> float:
        return mean + std * self.normal()

    def shuffle(self, xs: list) -> None:
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def permutation(self, n: int) -> list[int]:
        idx = list(range(n))
        self.shuffle(idx)
        return idx

    def fill_uniform_f32(self, out: np.ndarray, lo: float, hi: float) -> None:
        for i in range(out.size):
            out[i] = np.float32(self.uniform(lo, hi))


# ------------------------------------------------------- data/synthetic.rs


class Dataset:
    def __init__(self, x: np.ndarray, y: np.ndarray, d: int, name: str):
        self.x = x  # flat row-major float32, n*d
        self.y = y  # float32 labels
        self.d = d
        self.name = name

    def n(self) -> int:
        return self.y.size

    def split(self, frac: float) -> tuple["Dataset", "Dataset"]:
        n_train = int(rust_round(self.n() * frac))
        f = self.d
        tr = Dataset(self.x[: n_train * f].copy(), self.y[:n_train].copy(), f, self.name)
        va = Dataset(self.x[n_train * f :].copy(), self.y[n_train:].copy(), f, self.name)
        return tr, va

    def gather(self, indices: list[int], pad_to: int):
        """dataset.rs gather_into: padding rows repeat row 0, w = 0."""
        f = self.d
        x = np.empty(pad_to * f, dtype=np.float32)
        w = np.empty(pad_to, dtype=np.float32)
        for row, i in enumerate(indices):
            x[row * f : (row + 1) * f] = self.x[i * f : (i + 1) * f]
            w[row] = 1.0
        for row in range(len(indices), pad_to):
            x[row * f : (row + 1) * f] = self.x[0:f]
            w[row] = 0.0
        y = np.zeros(pad_to, dtype=np.float32)
        for row, i in enumerate(indices):
            y[row] = self.y[i]
        return x.reshape(pad_to, f), y, w


def generate_synthetic(n: int, d: int, noise: float, seed: int) -> Dataset:
    root = Rng(seed)
    w_rng = root.fork(1)
    x_rng = root.fork(2)
    e_rng = root.fork(3)
    w_star = [w_rng.normal() for _ in range(d)]
    x = np.zeros(n * d, dtype=np.float32)
    y = np.zeros(n, dtype=np.float32)
    for i in range(n):
        row = x[i * d : (i + 1) * d]
        x_rng.fill_uniform_f32(row, -1.0, 1.0)
        z = 0.0
        for j in range(d):
            z += w_star[j] * float(row[j])
        z += e_rng.normal_ms(0.0, noise)
        y[i] = np.float32(1.0 if z > 0.0 else 0.0)
    return Dataset(x, y, d, f"synthetic-d{d}-n{n}-s{seed}")


# ----------------------------------------------------- coordinator/plan.rs


def micro_plan(m: int, ladder: list[int]) -> list[tuple[int, int]]:
    """MicroPlan::build with no cap: [(micro, take)] blocks."""
    usable = list(ladder)
    blocks = []
    remaining = m
    for rung in reversed(usable):
        while remaining >= rung:
            blocks.append((rung, rung))
            remaining -= rung
    if remaining > 0:
        rung = next((r for r in usable if r >= remaining), usable[-1])
        if rung >= remaining:
            blocks.append((rung, remaining))
        else:
            while remaining >= rung:
                blocks.append((rung, rung))
                remaining -= rung
            if remaining > 0:
                blocks.append((rung, remaining))
    return blocks


# ------------------------------------------------------------- cluster.rs


class Cluster:
    """ClusterModel::a100x4 with the golden run's constants."""

    def __init__(self, param_count: int, flops_per_sample: float):
        self.workers = 4
        self.t_launch = 60e-6
        self.t_sample = flops_per_sample / 120e12
        self.t_comm_base = 25e-6
        self.t_per_param = 4.0 / 150e9
        self.param_count = param_count
        self.div_overhead = 0.9

    def step_time(self, m: int, instrumented: bool) -> float:
        shard = -(-m // self.workers)  # div_ceil
        compute = shard * self.t_sample
        if instrumented:
            compute *= 1.0 + self.div_overhead
        allreduce = (
            self.t_comm_base
            + 2.0 * (self.workers - 1) / self.workers * self.param_count * self.t_per_param
        )
        return self.t_launch + compute + allreduce

    def epoch_time(self, n: int, m: int, instrumented: bool) -> float:
        full_steps = n // m
        tail = n % m
        t = full_steps * self.step_time(m, instrumented)
        if tail > 0:
            t += self.step_time(tail, instrumented)
        return t


# ------------------------------------------------------ metrics/memory.rs


def mem_step_mb(param_count: int, feat_len: int, chunk: int, m: int) -> float:
    """MemoryModel::for_model (dense) + step_mb in DivChunked mode."""
    act_per_sample = 2 * feat_len + 64
    f = 4.0
    fixed = 4.0 * param_count * f
    batch = m * (float(feat_len) + float(act_per_sample)) * f
    persample = min(chunk, m) * param_count * f
    return (fixed + batch + persample) / (1024.0 * 1024.0)


# ------------------------------------------------------------ golden run


def divebatch_next(m0, delta, m_max, current, n, sqnorm_sum, grad_norm2):
    """baselines.rs divebatch_next."""
    delta_hat = math.inf if grad_norm2 <= 0.0 else sqnorm_sum / grad_norm2
    if not math.isfinite(delta_hat):
        return min(max(current, min(m0, m_max)), m_max)
    target = delta * n * delta_hat
    target = int(max(rust_round(target), 1.0))
    return min(max(target, m0), min(m_max, max(n, m0)))


class GoldenRun:
    """The pinned run of rust/tests/golden_record.rs, mirrored end to end.

    TrialSpec::execute_profiled sets cfg.seed = trial = 0; the dataset is
    Synthetic{n:120, d:8, noise:0.05, seed:33}, policy DiveBatch{m0:4,
    delta:0.5, m_max:8}, LrSchedule::constant(0.3, rescale=true), 6
    epochs, default ClusterSpec, flops_per_sample 1e3, tinylogreg8.
    """

    EPOCHS = 6
    M0 = 4
    DELTA = 0.5
    M_MAX = 8
    LR_BASE = 0.3
    N_TOTAL = 120
    D = 8
    NOISE = 0.05
    DATA_SEED = 33
    SEED = 0
    LADDER = [4, 8]
    PARAM_COUNT = 9
    CHUNK = 4
    FLOPS = 1e3

    def __init__(self, fixtures_dir: str):
        self.execs = {}
        for key in ("train_div_b4", "train_div_b8", "eval_b4", "eval_b8"):
            path = os.path.join(fixtures_dir, "tinylogreg8", f"{key}.hlo.txt")
            self.execs[key] = interp.Executable(path)
        with open(os.path.join(fixtures_dir, "tinylogreg8", "init_s0.bin"), "rb") as f:
            self.init_params = np.frombuffer(f.read(), dtype="<f4").copy()

    def run_train(self, micro: int, params, x, y, w):
        out = self.execs[f"train_div_b{micro}"].run([params, x, y, w])
        return (
            float(out[0].reshape(())),
            float(out[1].reshape(())),
            np.asarray(out[2], dtype=np.float32),
            float(out[3].reshape(())),
        )

    def run_eval(self, micro: int, params, x, y, w):
        out = self.execs[f"eval_b{micro}"].run([params, x, y, w])
        return float(out[0].reshape(())), float(out[1].reshape(()))

    def lr(self, epoch: int, m: int) -> float:
        # LrSchedule::constant(0.3, true): no decay, Goyal rescale by m/m0.
        lr = self.LR_BASE
        lr *= m / float(self.M0)
        return lr

    def evaluate(self, val: Dataset, params) -> tuple[float, float]:
        n = val.n()
        loss = 0.0
        correct = 0.0
        pos = 0
        while pos < n:
            idx = list(range(pos, min(pos + 8, n)))
            pos += len(idx)
            for micro, take in micro_plan(len(idx), self.LADDER):
                block = idx[:take]
                idx = idx[take:]
                x, y, w = val.gather(block, micro)
                l, c = self.run_eval(micro, params, x, y, w)
                loss += l
                correct += c
        return loss / n, 100.0 * correct / n

    def run(self) -> dict:
        full = generate_synthetic(self.N_TOTAL, self.D, self.NOISE, self.DATA_SEED)
        train, val = full.split(0.8)
        n = train.n()
        cluster = Cluster(self.PARAM_COUNT, self.FLOPS)
        params = self.init_params.copy()
        shuffle_rng = Rng((self.SEED * 0x9E3779B97F4A7C15) & MASK ^ 0xD117E)
        _sgld_rng = shuffle_rng.fork(0x561D)  # trainer.rs forks it unconditionally

        m_k = self.M0
        lr_scale = 1.0
        cum_sim = 0.0
        epochs_out = []
        for epoch in range(self.EPOCHS):
            lr = self.lr(epoch, m_k) * lr_scale
            div_grad = [0.0] * self.PARAM_COUNT
            div_sqnorm = 0.0
            div_samples = 0
            train_loss_sum = 0.0
            train_correct = 0.0
            steps = 0
            # Dispatch accounting (trainer.rs): executable dispatches and
            # padding waste are plan-derived — jobs-invariant — while the
            # lane-utilization field is masked to 0.0 in canonical JSON
            # (it depends on --step-jobs, which the record must not).
            dispatches = 0
            padded_rows = 0
            covered_rows = 0
            m_cur = m_k
            m_peak = m_k
            perm = shuffle_rng.permutation(n)
            pos = 0
            while pos < n:
                indices = perm[pos : pos + m_cur]
                pos += len(indices)
                logical = len(indices)
                grad_accum = np.zeros(self.PARAM_COUNT, dtype=np.float32)
                plan = micro_plan(logical, self.LADDER)
                dispatches += len(plan)
                padded_rows += sum(micro for micro, _ in plan)
                covered_rows += sum(take for _, take in plan)
                offset = 0
                for micro, take in plan:
                    idx = indices[offset : offset + take]
                    offset += take
                    x, y, w = train.gather(idx, micro)
                    loss, correct, grad, sqnorm = self.run_train(micro, params, x, y, w)
                    grad_accum = grad_accum + grad  # f32 elementwise, like `*a += g`
                    train_loss_sum += loss
                    train_correct += correct
                    for pi in range(self.PARAM_COUNT):
                        div_grad[pi] += float(grad[pi])
                    div_sqnorm += sqnorm
                    div_samples += take
                # SgdOptimizer::step, plain hot path (mu = wd = 0).
                inv_m = np.float32(1.0) / np.float32(logical)
                scale = np.float32(lr) * inv_m
                params = (params - scale * grad_accum).astype(np.float32)
                steps += 1
                cum_sim += cluster.step_time(logical, True)

            grad_norm2 = 0.0
            for g in div_grad:
                grad_norm2 += g * g
            delta_hat = math.inf if grad_norm2 <= 0.0 else div_sqnorm / grad_norm2
            n_delta = div_samples * delta_hat

            val_loss, val_acc = self.evaluate(val, params)
            sim_epoch = cluster.epoch_time(n, m_k, True)
            train_loss = train_loss_sum / n
            epochs_out.append(
                {
                    "epoch": epoch,
                    "m": m_k,
                    "lr": lr,
                    "steps": steps,
                    "tl": train_loss,
                    "ta": 100.0 * train_correct / n,
                    "vl": val_loss,
                    "va": val_acc,
                    "dh": delta_hat,
                    "nd": n_delta,
                    "xd": None,
                    "ws": 0.0,
                    "ss": sim_epoch,
                    "cw": 0.0,
                    "cs": cum_sim,
                    "mm": mem_step_mb(self.PARAM_COUNT, self.D, self.CHUNK, m_peak),
                    "dp": dispatches,
                    "pw": 0.0 if padded_rows == 0 else 1.0 - covered_rows / padded_rows,
                    "pu": 0.0,  # canonical mask (lane-count dependent)
                }
            )
            m_k = max(
                divebatch_next(
                    self.M0, self.DELTA, self.M_MAX, m_cur, n, div_sqnorm, grad_norm2
                ),
                1,
            )
        return {
            "label": f"DiveBatch ({self.M0} - {self.M_MAX})",
            "model": "tinylogreg8",
            "policy": "divebatch",
            "dataset": train.name,
            "seed": self.SEED,
            "epochs": epochs_out,
        }
