"""Validate the interp mirror against the committed jax goldens.

Replays every entry of every model in
rust/tests/fixtures/golden_entry_outputs.json (``{"models": {name:
{entry: {inputs, outputs}}}}``) through :mod:`mirror.interp` and checks
the outputs against the jax-evaluated values to the same tolerance the
Rust test ``interpreter_matches_python_golden`` uses
(1e-4 * (1 + |want|)).  This anchors the mirror to the exact semantics
the Rust interpreter is anchored to, before the mirror is trusted to
mint the golden run record.
"""

from __future__ import annotations

import json
import os

import numpy as np

from . import interp


def run(fixtures_dir: str) -> list[str]:
    """Returns a list of failure descriptions (empty = all good)."""
    with open(os.path.join(fixtures_dir, "golden_entry_outputs.json")) as f:
        doc = json.load(f)
    failures: list[str] = []
    cases = [
        (model, key, case)
        for model, entries in sorted(doc["models"].items())
        for key, case in sorted(entries.items())
    ]
    for model, key, case in cases:
        path = os.path.join(fixtures_dir, "artifacts", model, f"{key}.hlo.txt")
        exe = interp.Executable(path)
        comp = exe.module.computations[exe.module.entry]
        args = []
        for j, pidx in zip(case["inputs"], comp.params):
            dtype, dims = comp.instrs[pidx].shape
            # The golden json stores every input as floats; build each arg
            # in the entry's declared parameter dtype (s32 labels etc.).
            args.append(np.array(j, dtype=np.float64).astype(dtype).reshape(dims))
        outs = exe.run(args)
        wants = case["outputs"]
        if len(outs) != len(wants):
            failures.append(f"{model}/{key}: arity {len(outs)} vs {len(wants)}")
            continue
        for ix, (got, want) in enumerate(zip(outs, wants)):
            got = np.asarray(got, dtype=np.float32).reshape(-1)
            want = np.asarray(want, dtype=np.float64).reshape(-1)
            for j in range(want.size):
                g, w = float(got[j]), float(want[j])
                if abs(g - w) > 1e-4 * (1.0 + abs(w)):
                    failures.append(f"{model}/{key} out[{ix}][{j}]: mirror {g} vs jax {w}")
    return failures


if __name__ == "__main__":
    here = os.path.dirname(__file__)
    fx = os.path.normpath(os.path.join(here, "..", "..", "rust", "tests", "fixtures"))
    fails = run(fx)
    if fails:
        print("\n".join(fails))
        raise SystemExit(1)
    print("interp mirror matches the jax goldens")
