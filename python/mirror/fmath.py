"""numpy mirror of rust/vendor/xla/src/interp/fmath.rs — bit-exact.

Every function takes/returns ``np.float32`` arrays and performs the same
sequence of IEEE-754 double operations as the Rust kernels: basic
arithmetic (correctly rounded in both), ``floor``, exact power-of-two
scaling, and bit-level mantissa/exponent splits.  No libm transcendental
is ever called, so results match the Rust side bit for bit on any host.

KEEP IN SYNC with fmath.rs (constants, polynomial degrees, operation
order).
"""

from __future__ import annotations

import numpy as np

LOG2E = 1.4426950408889634
LN2_HI = 0.6931471803691238
LN2_LO = 1.9082149292705877e-10
SQRT_2 = 1.4142135623730951
FRAC_2_PI = 0.6366197723675814
PIO2_HI = 1.5707963267341256
PIO2_LO = 6.077100506506192e-11

_MANT = np.uint64(0x000F_FFFF_FFFF_FFFF)
_ONE_BITS = np.uint64(0x3FF0_0000_0000_0000)


def _f64(x):
    return np.asarray(x, dtype=np.float32).astype(np.float64)


def _exp_core(x):
    """e^x for |x| <= 700 (callers clip); mirrors fmath::exp_core."""
    k = np.floor(x * LOG2E + 0.5)
    hi = x - k * LN2_HI
    r = hi - k * LN2_LO
    p = 1.0 + r * (
        1.0
        + r * (
            0.5
            + r * (
                1.0 / 6.0
                + r * (
                    1.0 / 24.0
                    + r * (
                        1.0 / 120.0
                        + r * (
                            1.0 / 720.0
                            + r * (
                                1.0 / 5040.0
                                + r * (
                                    1.0 / 40320.0
                                    + r * (1.0 / 362880.0 + r * (1.0 / 3628800.0))
                                )
                            )
                        )
                    )
                )
            )
        )
    )
    return p * np.ldexp(1.0, k.astype(np.int64))


def _expm1_core(x):
    """e^x - 1 for |x| <= 700; mirrors fmath::expm1_core."""
    r = x
    small = r * (
        1.0
        + r * (
            0.5
            + r * (
                1.0 / 6.0
                + r * (
                    1.0 / 24.0
                    + r * (
                        1.0 / 120.0
                        + r * (
                            1.0 / 720.0
                            + r * (
                                1.0 / 5040.0
                                + r * (
                                    1.0 / 40320.0
                                    + r * (1.0 / 362880.0 + r * (1.0 / 3628800.0))
                                )
                            )
                        )
                    )
                )
            )
        )
    )
    return np.where(np.abs(x) <= 0.34657359027997264, small, _exp_core(x) - 1.0)


def _atanh2_core(t):
    """2*atanh(t); mirrors fmath::atanh2_core."""
    t2 = t * t
    return (
        2.0
        * t
        * (
            1.0
            + t2
            * (
                1.0 / 3.0
                + t2
                * (
                    1.0 / 5.0
                    + t2
                    * (1.0 / 7.0 + t2 * (1.0 / 9.0 + t2 * (1.0 / 11.0 + t2 * (1.0 / 13.0))))
                )
            )
        )
    )


def _ln_core(x):
    """ln x for positive finite f64-normal x; mirrors fmath::ln_core."""
    bits = np.asarray(x, dtype=np.float64).view(np.uint64)
    e = ((bits >> np.uint64(52)) & np.uint64(0x7FF)).astype(np.int64) - 1023
    m = ((bits & _MANT) | _ONE_BITS).view(np.float64)
    big = m > SQRT_2
    m = np.where(big, m * 0.5, m)
    e = e + big
    t = (m - 1.0) / (m + 1.0)
    p = _atanh2_core(t)
    ef = e.astype(np.float64)
    return p + ef * LN2_LO + ef * LN2_HI


def exp(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    with np.errstate(all="ignore"):
        core = _exp_core(np.clip(xd, -700.0, 700.0)).astype(np.float32)
    out = np.where(xd > 700.0, np.float32(np.inf), core)
    out = np.where(xd < -700.0, np.float32(0.0), out)
    return np.where(np.isnan(x), x, out).astype(np.float32)


def exp_m1(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    with np.errstate(all="ignore"):
        core = _expm1_core(np.clip(xd, -700.0, 700.0)).astype(np.float32)
    out = np.where(xd > 700.0, np.float32(np.inf), core)
    out = np.where(xd < -700.0, np.float32(-1.0), out)
    return np.where(np.isnan(x), x, out).astype(np.float32)


def ln(x):
    x = np.asarray(x, dtype=np.float32)
    with np.errstate(all="ignore"):
        safe = np.where(x > 0, x.astype(np.float64), 1.0)
        core = _ln_core(safe).astype(np.float32)
    out = core
    out = np.where(x == 0.0, np.float32(-np.inf), out)
    out = np.where(x < 0.0, np.float32(np.nan), out)
    out = np.where(np.isposinf(x.astype(np.float64)), np.float32(np.inf), out)
    return np.where(np.isnan(x), x, out).astype(np.float32)


def ln_1p(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    with np.errstate(all="ignore"):
        t = xd / (2.0 + xd)
        small = _atanh2_core(t).astype(np.float32)
        safe = np.where(1.0 + xd > 0, 1.0 + xd, 1.0)
        large = _ln_core(safe).astype(np.float32)
    out = np.where((xd > -0.25) & (xd < 0.25), small, large)
    out = np.where(x == -1.0, np.float32(-np.inf), out)
    out = np.where(x < -1.0, np.float32(np.nan), out)
    out = np.where(np.isposinf(xd), np.float32(np.inf), out)
    return np.where(np.isnan(x), x, out).astype(np.float32)


def logistic(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    with np.errstate(all="ignore"):
        core = (1.0 / (1.0 + _exp_core(-np.clip(xd, -700.0, 700.0)))).astype(np.float32)
    out = np.where(xd >= 700.0, np.float32(1.0), core)
    out = np.where(xd <= -700.0, np.float32(0.0), out)
    return np.where(np.isnan(x), x, out).astype(np.float32)


def tanh(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    a = np.abs(xd)
    with np.errstate(all="ignore"):
        em = _expm1_core(-2.0 * np.clip(a, 0.0, 700.0))
        t = -em / (2.0 + em)
    sat = np.where(xd > 0.0, np.float32(1.0), np.float32(-1.0))
    core = np.where(xd < 0.0, -t, t).astype(np.float32)
    out = np.where(a >= 20.0, sat, core)
    out = np.where(x == 0.0, x, out)
    return np.where(np.isnan(x), x, out).astype(np.float32)


def _sin_poly(r):
    r2 = r * r
    return r * (
        1.0
        + r2 * (-1.0 / 6.0 + r2 * (1.0 / 120.0 + r2 * (-1.0 / 5040.0 + r2 * (1.0 / 362880.0))))
    )


def _cos_poly(r):
    r2 = r * r
    return 1.0 + r2 * (
        -0.5
        + r2 * (1.0 / 24.0 + r2 * (-1.0 / 720.0 + r2 * (1.0 / 40320.0 + r2 * (-1.0 / 3628800.0))))
    )


def _sincos_reduce(xd):
    n = np.floor(xd * FRAC_2_PI + 0.5)
    r = xd - n * PIO2_HI - n * PIO2_LO
    nm = n - np.floor(n * 0.25) * 4.0
    q = np.clip(nm, 0.0, 3.0).astype(np.int64) & 3
    return q, r


def sin(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    finite = np.isfinite(xd)
    with np.errstate(all="ignore"):
        q, r = _sincos_reduce(np.where(finite, xd, 0.0))
        s, c = _sin_poly(r), _cos_poly(r)
        core = np.choose(q, [s, c, -s, -c]).astype(np.float32)
    return np.where(finite, core, np.float32(np.nan)).astype(np.float32)


def cos(x):
    x = np.asarray(x, dtype=np.float32)
    xd = x.astype(np.float64)
    finite = np.isfinite(xd)
    with np.errstate(all="ignore"):
        q, r = _sincos_reduce(np.where(finite, xd, 0.0))
        s, c = _sin_poly(r), _cos_poly(r)
        core = np.choose(q, [c, -s, -c, s]).astype(np.float32)
    return np.where(finite, core, np.float32(np.nan)).astype(np.float32)


def pow(a, b):  # noqa: A001 - mirrors fmath::pow
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    a, b = np.broadcast_arrays(a, b)
    ad = a.astype(np.float64)
    bd = b.astype(np.float64)
    with np.errstate(all="ignore"):
        b_is_int = np.floor(bd) == bd
        b_is_odd = b_is_int & (np.floor(bd * 0.5) * 2.0 != bd)
        safe_mag = np.where((np.abs(ad) > 0) & np.isfinite(ad), np.abs(ad), 1.0)
        t = bd * _ln_core(safe_mag)
        mag = np.where(
            t > 700.0,
            np.inf,
            np.where(t < -700.0, 0.0, _exp_core(np.clip(t, -700.0, 700.0))),
        )
        signed = np.where((ad < 0.0) & b_is_odd, -mag, mag).astype(np.float32)
    out = signed
    out = np.where((a < 0.0) & ~b_is_int, np.float32(np.nan), out)
    # a == +-inf
    inf_a = np.isinf(ad)
    out = np.where(inf_a & (bd > 0.0) & ~((ad < 0.0) & b_is_odd), np.float32(np.inf), out)
    out = np.where(inf_a & (bd > 0.0) & (ad < 0.0) & b_is_odd, np.float32(-np.inf), out)
    out = np.where(inf_a & (bd < 0.0) & (ad < 0.0) & b_is_odd, np.float32(-0.0), out)
    out = np.where(inf_a & (bd < 0.0) & ~((ad < 0.0) & b_is_odd), np.float32(0.0), out)
    # b == +-inf
    inf_b = np.isinf(bd)
    small = np.abs(a) < 1.0
    out = np.where(inf_b & ((small & (bd > 0.0)) | (~small & (bd < 0.0))), np.float32(0.0), out)
    out = np.where(
        inf_b & ((small & (bd < 0.0)) | (~small & (bd > 0.0))), np.float32(np.inf), out
    )
    # a == 0
    zero_a = a == 0.0
    out = np.where(zero_a & (bd > 0.0) & b_is_odd, a, out)
    out = np.where(zero_a & (bd > 0.0) & ~b_is_odd, np.float32(0.0), out)
    with np.errstate(divide="ignore"):
        out = np.where(zero_a & (bd < 0.0) & b_is_odd, np.float32(1.0) / a, out)
    out = np.where(zero_a & (bd < 0.0) & ~b_is_odd, np.float32(np.inf), out)
    # NaN propagation, then the two unconditional identities.
    out = np.where(np.isnan(a) | np.isnan(b), np.float32(np.nan), out)
    out = np.where((b == 0.0) | (a == 1.0), np.float32(1.0), out)
    return out.astype(np.float32)


def sqrt(x):
    # IEEE-exact in both languages.
    x = np.asarray(x, dtype=np.float32)
    with np.errstate(all="ignore"):
        return np.sqrt(x)


def rsqrt(x):
    x = np.asarray(x, dtype=np.float32)
    with np.errstate(all="ignore"):
        return (np.float32(1.0) / np.sqrt(x)).astype(np.float32)
