"""Bit-exact Python mirror of the Rust trainer + compiled interpreter.

The PR-4 authoring environment has no Rust toolchain, yet the golden-record
regression gate (rust/tests/golden_record.rs) pins a full training run's
canonical JSON byte for byte.  This package reproduces that run exactly:

* ``fmath``   — line-for-line numpy mirror of the deterministic f32 math
  kernels in rust/vendor/xla/src/interp/fmath.rs;
* ``interp``  — HLO-text parser + evaluator matching the compiled register
  program's numeric semantics (same op order, same f32 rounding);
* ``trainer`` — the full golden-run pipeline: xoshiro256++ RNG, synthetic
  dataset, batching, micro-plans, SGD, diversity accumulation, DiveBatch
  policy, simulated-cluster timing, memory model;
* ``rust_fmt``— Rust ``Display``-compatible f64 formatting + the canonical
  JSON writer (sorted keys, wall-clock masked);
* ``golden_run`` — entry point: regenerates
  rust/tests/fixtures/golden_run_record.json;
* ``selfcheck``  — validates the interp mirror against the committed
  jax-evaluated golden_entry_outputs.json;
* ``check_bench`` — CI perf-smoke comparison of BENCH_4.json files.

Every floating-point operation in the Rust golden path is either IEEE
basic arithmetic (exactly reproduced by numpy f32/f64 ops), an fmath
kernel (mirrored here op for op), or a libm call whose result is only
*threshold*-consumed (dataset label signs) — so the mirrored record is
bit-identical to what `cargo test` produces.  KEEP IN SYNC: any numeric
change on the Rust side must be applied here and the golden re-blessed
(`python -m mirror.golden_run`, or DIVEBATCH_BLESS=1 with a toolchain).
"""
