"""Mirror of rust/src/util/json.rs serialization for RunRecord JSON.

Two pieces matter for byte equality:

* ``write_num`` (json.rs): finite integers with |n| < 9e15 print via the
  ``n as i64`` cast (no fraction); everything else prints with Rust's f64
  ``Display`` — the *shortest* decimal string that round-trips, rendered
  positionally (Rust Display never uses scientific notation).  Python's
  ``repr`` produces the same shortest digit string; this module re-renders
  it positionally.
* objects serialize with keys in sorted (BTreeMap) order, compact
  separators, and the same string escaping.
"""

from __future__ import annotations

import math


def fmt_f64(n: float) -> str:
    """Rust `format!("{n}")` for the values write_num's else-branch sees
    (finite, non-integer or huge)."""
    s = repr(float(n))
    if "e" not in s and "E" not in s:
        return s
    if "inf" in s or "nan" in s:
        raise ValueError(f"non-finite {n} reached fmt_f64")
    mant, exp = s.lower().split("e")
    e = int(exp)
    sign = "-" if mant.startswith("-") else ""
    mant = mant.lstrip("-")
    if "." in mant:
        ip, fp = mant.split(".")
    else:
        ip, fp = mant, ""
    digits = ip + fp
    point = len(ip) + e
    if point <= 0:
        return sign + "0." + "0" * (-point) + digits
    if point >= len(digits):
        return sign + digits + "0" * (point - len(digits))
    return sign + digits[:point] + "." + digits[point:]


def write_num(n: float) -> str:
    """json.rs write_num: null for non-finite; i64 rendering for integral
    values below 9e15; Display otherwise."""
    n = float(n)
    if not math.isfinite(n):
        return "null"
    if n == math.floor(n) and abs(n) < 9.0e15:
        return str(int(n))
    return fmt_f64(n)


def _escape(s: str) -> str:
    out = ['"']
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\r":
            out.append("\\r")
        elif c == "\t":
            out.append("\\t")
        elif ord(c) < 0x20:
            out.append(f"\\u{ord(c):04x}")
        else:
            out.append(c)
    out.append('"')
    return "".join(out)


def write_json(v) -> str:
    """Compact serialization matching util/json.rs `Json::to_string`.

    dict -> Obj (sorted keys), list -> Arr, str -> Str, bool -> Bool,
    None -> Null, int/float -> Num (via write_num).
    """
    if v is None:
        return "null"
    if v is True:
        return "true"
    if v is False:
        return "false"
    if isinstance(v, str):
        return _escape(v)
    if isinstance(v, (int, float)):
        return write_num(float(v))
    if isinstance(v, list):
        return "[" + ",".join(write_json(x) for x in v) + "]"
    if isinstance(v, dict):
        items = (f"{_escape(k)}:{write_json(v[k])}" for k in sorted(v))
        return "{" + ",".join(items) + "}"
    raise TypeError(type(v))
