"""CI perf-smoke gate: compare a fresh perf bench result to its baseline.

Usage:
    check_bench.py NEW_BENCH_JSON COMMITTED_BENCH_JSON
    check_bench.py --bless ARTIFACT_JSON COMMITTED_BENCH_JSON

Works for any bench emitting the ``{"entries": {key: {"speedup": x}}}``
schema — today ``perf_interp`` (BENCH_4.json: compiled interpreter vs
the reference evaluator), ``perf_step`` (BENCH_5.json: sharded step
executor vs the serial loop), ``perf_interp_simd`` (BENCH_6.json:
SIMD tier vs scalar tier of the compiled interpreter, both bit-identical
by the pinned-lanes contract), and ``perf_conv`` (BENCH_7.json: fused
blocked conv kernel vs forced im2col on the tinyresnet8 fixtures, also
bit-identical by the same contract).  Fails (exit 1) if any baseline entry's
speedup regressed more than 2x.  The comparison uses **speedup** (two
paths measured in the same process) rather than raw ns/step: the ratio
is machine-invariant, so a baseline blessed on faster or slower hardware
than the CI runner cannot spuriously trip the gate.  Raw ns/step stays
in the files for humans.  While a committed file is still its bootstrap
marker (``"bootstrap": true`` — the authoring environment had no Rust
toolchain to measure a baseline), the comparison is skipped with a
``::warning::`` asking for the measured artifact to be committed.

``--bless`` turns a green CI run's uploaded perf artifact into the
committed baseline in one command: download the ``perf-bench-results``
artifact, then e.g. ``check_bench.py --bless /tmp/BENCH_4.json
BENCH_4.json``.  Blessing refuses to launder a bad run — the artifact
must name the same bench, contain every entry the committed file gates,
and clear every ``target_speedup_<entry>`` floor recorded in the
committed file (the floors are the in-process DIVEBATCH_PERF_ENFORCE
targets and are carried into the blessed file unchanged).  The rewritten
baseline keeps the single-line sorted-key JSON form and records its
provenance in ``note``.
"""

from __future__ import annotations

import json
import os
import sys

REGRESSION_FACTOR = 2.0

TARGET_PREFIX = "target_speedup_"


def bless(artifact_path: str, committed_path: str) -> int:
    art = json.load(open(artifact_path))
    committed = json.load(open(committed_path))
    name = os.path.basename(committed_path)
    problems = []
    if art.get("bench") != committed.get("bench"):
        problems.append(
            f"bench name mismatch: artifact {art.get('bench')!r}"
            f" vs committed {committed.get('bench')!r}"
        )
    entries = art.get("entries") or {}
    if not entries:
        problems.append("artifact has no entries — refusing to bless an empty run")
    for key in committed.get("entries", {}):
        if key not in entries:
            problems.append(f"entry {key!r} gated by {name} is missing from the artifact")
    floors = {k: v for k, v in committed.items() if k.startswith(TARGET_PREFIX)}
    summary = []
    for k, floor in sorted(floors.items()):
        entry = k[len(TARGET_PREFIX) :]
        got = entries.get(entry, {}).get("speedup")
        if got is None:
            problems.append(f"floor {k} has no measured speedup for {entry!r} in the artifact")
        elif got < float(floor):
            problems.append(f"{entry}: measured {got:.2f}x is below the {floor}x floor")
        else:
            summary.append(f"  {entry}: floor {floor}x -> measured {got:.2f}x")
    if problems:
        print(f"refusing to bless {name}:")
        print("\n".join(f"  {p}" for p in problems))
        return 1
    blessed = dict(art)
    blessed.update(floors)  # keep the enforce-target floors on record
    blessed["bootstrap"] = False
    blessed["note"] = (
        "Measured baseline blessed from a green CI run's perf-smoke artifact"
        " via check_bench.py --bless; the target_speedup_* floors are the"
        " in-process DIVEBATCH_PERF_ENFORCE targets the artifact cleared."
    )
    with open(committed_path, "w") as f:
        json.dump(blessed, f, sort_keys=True, separators=(",", ":"))
        f.write("\n")
    print(f"blessed {name} from {os.path.basename(artifact_path)}:")
    print("\n".join(summary))
    return 0


def main(argv: list[str]) -> int:
    if len(argv) == 4 and argv[1] == "--bless":
        return bless(argv[2], argv[3])
    if len(argv) != 3:
        print(__doc__)
        return 2
    new = json.load(open(argv[1]))
    old = json.load(open(argv[2]))
    baseline_name = os.path.basename(argv[2])
    if old.get("bootstrap"):
        print(
            f"::warning file={baseline_name}::perf baseline is the bootstrap marker"
            " - commit the perf-smoke artifact to arm the 2x regression gate"
        )
        return 0
    bad = []
    for key, ent in old.get("entries", {}).items():
        got = new.get("entries", {}).get(key, {}).get("speedup")
        want = ent.get("speedup")
        if got is None:
            # A baseline entry the fresh run did not produce is itself a
            # failure — otherwise a renamed/truncated bench output would
            # silently drain the gate of coverage.
            bad.append(f"{key}: missing from the fresh bench output")
        elif want and got < want / REGRESSION_FACTOR:
            bad.append(f"{key}: speedup {got:.1f}x vs baseline {want:.1f}x")
    if bad:
        print(f"perf regression >2x vs committed {baseline_name} (speedup ratio):")
        print("\n".join(bad))
        return 1
    print(f"perf-smoke: within 2x of the committed {baseline_name} baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
