"""CI perf-smoke gate: compare a fresh perf bench result to its baseline.

Usage: check_bench.py NEW_BENCH_JSON COMMITTED_BENCH_JSON

Works for any bench emitting the ``{"entries": {key: {"speedup": x}}}``
schema — today ``perf_interp`` (BENCH_4.json: compiled interpreter vs
the reference evaluator), ``perf_step`` (BENCH_5.json: sharded step
executor vs the serial loop), and ``perf_interp_simd`` (BENCH_6.json:
SIMD tier vs scalar tier of the compiled interpreter, both bit-identical
by the pinned-lanes contract).  Fails (exit 1) if any baseline entry's
speedup regressed more than 2x.  The comparison uses **speedup** (two
paths measured in the same process) rather than raw ns/step: the ratio
is machine-invariant, so a baseline blessed on faster or slower hardware
than the CI runner cannot spuriously trip the gate.  Raw ns/step stays
in the files for humans.  While a committed file is still its bootstrap
marker (``"bootstrap": true`` — the authoring environment had no Rust
toolchain to measure a baseline), the comparison is skipped with a
``::warning::`` asking for the measured artifact to be committed.
"""

from __future__ import annotations

import json
import os
import sys

REGRESSION_FACTOR = 2.0


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    new = json.load(open(argv[1]))
    old = json.load(open(argv[2]))
    baseline_name = os.path.basename(argv[2])
    if old.get("bootstrap"):
        print(
            f"::warning file={baseline_name}::perf baseline is the bootstrap marker"
            " - commit the perf-smoke artifact to arm the 2x regression gate"
        )
        return 0
    bad = []
    for key, ent in old.get("entries", {}).items():
        got = new.get("entries", {}).get(key, {}).get("speedup")
        want = ent.get("speedup")
        if got is None:
            # A baseline entry the fresh run did not produce is itself a
            # failure — otherwise a renamed/truncated bench output would
            # silently drain the gate of coverage.
            bad.append(f"{key}: missing from the fresh bench output")
        elif want and got < want / REGRESSION_FACTOR:
            bad.append(f"{key}: speedup {got:.1f}x vs baseline {want:.1f}x")
    if bad:
        print(f"perf regression >2x vs committed {baseline_name} (speedup ratio):")
        print("\n".join(bad))
        return 1
    print(f"perf-smoke: within 2x of the committed {baseline_name} baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
