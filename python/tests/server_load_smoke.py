#!/usr/bin/env python3
"""Load smoke for `divebatch serve` — stdlib only, run by CI.

Usage: server_load_smoke.py <divebatch-binary> <artifacts-dir>

Boots the server on an ephemeral port, fires a few hundred concurrent
requests at it (valid trials, cache-hitting repeats, and a sprinkling of
invalid requests that must come back as structured 400s), checks every
response is valid JSONL (a canonical RunRecord line or a typed error
object), sanity-checks /stats, then sends SIGTERM and requires a clean
graceful exit (status 0) with no connections left serviced afterwards.
"""

import http.client
import json
import signal
import socket
import subprocess
import sys
import threading
import time

TOTAL_REQUESTS = 240
THREADS = 32
DISTINCT_SEEDS = 8
MAX_CLIENTS = 128

TRIAL = {
    "model": "tinylogreg8",
    "policy": "sgd:m=4",
    "epochs": 1,
    "dataset": {"kind": "synthetic", "n": 40, "d": 8, "noise": 0.1, "seed": 1000},
}
BAD_BODIES = [
    '{"model":"tinylogreg8","policy":"sgd:m=4","epochz":3}',  # unknown field
    '{"model":"tinylogreg8","policy":"sdg:m=4"}',  # bad policy
    "{not json",
]


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def post(addr, path, body, timeout=60):
    host, port = addr
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request(
            "POST", path, body=body, headers={"Content-Type": "application/json"}
        )
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def raw_head(addr, timeout=10):
    """GET /healthz over a raw socket; returns the response head (for
    asserting on status line + headers of rejection paths)."""
    with socket.create_connection(addr, timeout=timeout) as s:
        s.sendall(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
        data = b""
        while b"\r\n\r\n" not in data:
            chunk = s.recv(4096)
            if not chunk:
                break
            data += chunk
    return data.split(b"\r\n\r\n", 1)[0].decode("utf-8", "replace")


def header_value(head, name):
    for line in head.split("\r\n")[1:]:
        if ":" in line:
            k, v = line.split(":", 1)
            if k.strip().lower() == name:
                return v.strip()
    return None


def get(addr, path, timeout=30):
    host, port = addr
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8", "replace")
    finally:
        conn.close()


def main():
    if len(sys.argv) != 3:
        fail(f"usage: {sys.argv[0]} <divebatch-binary> <artifacts-dir>")
    binary, artifacts = sys.argv[1], sys.argv[2]

    proc = subprocess.Popen(
        [
            binary,
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--jobs",
            "2",
            "--max-clients",
            str(MAX_CLIENTS),
            "--max-queue",
            "512",
            "--artifacts",
            artifacts,
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )
    try:
        run(proc)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


def run(proc):
    # The server announces "serving on IP:PORT" on stdout once bound.
    line = proc.stdout.readline().strip()
    if not line.startswith("serving on "):
        proc.kill()
        fail(f"expected 'serving on ADDR' banner, got {line!r}")
    host, _, port = line[len("serving on ") :].rpartition(":")
    addr = (host, int(port))
    print(f"serve up on {addr[0]}:{addr[1]}")

    status, body = get(addr, "/healthz")
    if status != 200:
        fail(f"/healthz -> {status}: {body}")

    # ---- concurrent load -------------------------------------------------
    results = []  # (index, kind, status, body)
    lock = threading.Lock()
    next_index = iter(range(TOTAL_REQUESTS))

    def worker():
        while True:
            with lock:
                i = next(next_index, None)
            if i is None:
                return
            if i % 12 == 11:  # every 12th request is deliberately invalid
                kind = "invalid"
                status, body = post(addr, "/trial", BAD_BODIES[i % len(BAD_BODIES)])
            else:
                kind = "trial"
                req = dict(TRIAL)
                req["seed"] = i % DISTINCT_SEEDS
                status, body = post(addr, "/trial", json.dumps(req))
            with lock:
                results.append((i, kind, status, body))

    threads = [threading.Thread(target=worker) for _ in range(THREADS)]
    start = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    print(f"{TOTAL_REQUESTS} requests in {time.time() - start:.1f}s")

    if len(results) != TOTAL_REQUESTS:
        fail(f"expected {TOTAL_REQUESTS} responses, got {len(results)}")

    # Every response is one valid JSON line: a record for valid trials
    # (identical bytes per seed), a typed error object for invalid ones.
    per_seed = {}
    for i, kind, status, body in results:
        lines = [l for l in body.splitlines() if l.strip()]
        if len(lines) != 1:
            fail(f"request {i}: expected one JSONL line, got {len(lines)}: {body!r}")
        try:
            doc = json.loads(lines[0])
        except json.JSONDecodeError as e:
            fail(f"request {i}: response not JSON ({e}): {lines[0]!r}")
        if kind == "trial":
            if status != 200:
                fail(f"trial {i} -> {status}: {body}")
            if "epochs" not in doc:
                fail(f"trial {i}: not a RunRecord line: {lines[0]!r}")
            prev = per_seed.setdefault(i % DISTINCT_SEEDS, lines[0])
            if prev != lines[0]:
                fail(f"trial {i}: same seed produced different bytes")
        else:
            if status != 400:
                fail(f"invalid request {i} -> {status} (want 400): {body}")
            err = doc.get("error", {})
            if not err.get("code") or not err.get("field"):
                fail(f"invalid request {i}: untyped error: {lines[0]!r}")

    # ---- stats sanity ----------------------------------------------------
    status, body = get(addr, "/stats")
    if status != 200:
        fail(f"/stats -> {status}: {body}")
    stats = json.loads(body)
    adm = stats.get("admission", {})
    valid_requests = sum(1 for _, kind, _, _ in results if kind == "trial")
    if adm.get("submitted", 0) < valid_requests:
        fail(f"stats: submitted {adm.get('submitted')} < {valid_requests}")
    if adm.get("trials_failed", 0) != 0:
        fail(f"stats: {adm.get('trials_failed')} trials failed under load")
    if adm.get("batch_size_max_seen", 0) < 2:
        fail(f"stats: admission batching never adapted above 1: {adm}")
    if stats.get("exec_cache", {}).get("entries", 0) < 1:
        fail(f"stats: exec cache empty after load: {stats.get('exec_cache')}")
    print(f"stats ok: {json.dumps(adm)}")

    # ---- backpressure: every 503 must carry Retry-After ------------------
    # Saturate the connection cap with idle sockets (each held connection
    # keeps its permit while the server waits for a request), then the
    # next connection must be refused with a 503 that tells the client
    # when to come back.  Accepts are asynchronous, so retry briefly.
    idle = []
    try:
        for _ in range(MAX_CLIENTS):
            idle.append(socket.create_connection(addr, timeout=10))
        head = ""
        for _ in range(50):
            time.sleep(0.1)  # let the server accept the idle connections
            head = raw_head(addr)
            if " 503 " in head.split("\r\n", 1)[0] + " ":
                break
        status_line = head.split("\r\n", 1)[0]
        if " 503 " not in status_line + " ":
            fail(f"over-cap connection -> {status_line!r} (want 503)")
        retry_after = header_value(head, "retry-after")
        if retry_after is None or not retry_after.isdigit():
            fail(f"503 without a usable Retry-After: {head!r}")
        print(f"backpressure ok: 503 with Retry-After {retry_after}")
    finally:
        for s in idle:
            s.close()

    # ---- graceful shutdown ----------------------------------------------
    proc.send_signal(signal.SIGTERM)
    try:
        code = proc.wait(timeout=60)
    except subprocess.TimeoutExpired:
        proc.kill()
        fail("server did not exit within 60s of SIGTERM")
    if code != 0:
        fail(f"server exited {code} on SIGTERM (want 0): {proc.stderr.read()}")

    # The drained server must no longer take connections; if it still
    # answers (drain window), the refusal is a 503 with Retry-After.
    try:
        with socket.create_connection(addr, timeout=5) as s:
            s.sendall(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
            data = s.recv(1024)
        if data:
            head = data.decode("utf-8", "replace")
            if " 503 " not in head.split("\r\n", 1)[0] + " ":
                fail(f"post-SIGTERM connection was serviced: {data!r}")
            if header_value(head, "retry-after") is None:
                fail(f"draining 503 without Retry-After: {data!r}")
    except OSError:
        pass  # connection refused: exactly right

    print("server load smoke passed")


if __name__ == "__main__":
    main()
