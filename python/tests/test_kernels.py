"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

This is the core correctness signal for the kernel layer: every kernel is
swept over shapes (including shapes that do not divide the block sizes,
exercising the zero-padding path), block-shape choices, and adversarial
values (zeros, single rows, masked weights).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import persample as k
from compile.kernels import ref


def _randn(seed: int, shape) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _mask(seed: int, m: int) -> jax.Array:
    return (jax.random.uniform(jax.random.PRNGKey(seed), (m,)) > 0.3).astype(jnp.float32)


# ---------------------------------------------------------------------- row_sqnorm


@given(
    m=st.integers(1, 200),
    f=st.integers(1, 300),
    bm=st.sampled_from([8, 32, 128]),
    bf=st.sampled_from([16, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_sqnorm_matches_ref(m, f, bm, bf, seed):
    x = _randn(seed, (m, f))
    got = k.row_sqnorm(x, block_m=bm, block_f=bf)
    np.testing.assert_allclose(got, ref.row_sqnorm_ref(x), rtol=2e-5, atol=1e-6)


def test_row_sqnorm_zeros():
    x = jnp.zeros((17, 33))
    np.testing.assert_array_equal(k.row_sqnorm(x, block_m=8, block_f=8), jnp.zeros(17))


def test_row_sqnorm_single_row():
    x = jnp.arange(5.0)[None, :]
    np.testing.assert_allclose(k.row_sqnorm(x), jnp.array([30.0]))


def test_row_sqnorm_jit_lowerable():
    """The kernel must lower under jit (the AOT path)."""
    f = jax.jit(lambda x: k.row_sqnorm(x, block_m=8, block_f=8))
    x = _randn(3, (20, 24))
    np.testing.assert_allclose(f(x), ref.row_sqnorm_ref(x), rtol=2e-5)


# ---------------------------------------------------------------------- dense_sqnorm


@given(
    m=st.integers(1, 150),
    p=st.integers(1, 128),
    q=st.integers(1, 16),
    has_bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_sqnorm_matches_ref(m, p, q, has_bias, seed):
    a = _randn(seed, (m, p))
    d = _randn(seed + 1, (m, q))
    got = k.dense_sqnorm(a, d, has_bias=has_bias, block_m=32)
    want = ref.dense_sqnorm_ref(a, d, has_bias=has_bias)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_dense_sqnorm_wide_features_uses_two_pass():
    """Widths beyond FUSED_FEATURE_LIMIT take the composed row_sqnorm path."""
    a = _randn(0, (8, k.FUSED_FEATURE_LIMIT + 64))
    d = _randn(1, (8, 4))
    got = k.dense_sqnorm(a, d)
    np.testing.assert_allclose(got, ref.dense_sqnorm_ref(a, d), rtol=2e-5)


def test_dense_sqnorm_zero_outgrads_zero():
    a = _randn(0, (9, 7))
    d = jnp.zeros((9, 3))
    np.testing.assert_array_equal(k.dense_sqnorm(a, d), jnp.zeros(9))


def test_dense_sqnorm_row_mismatch_raises():
    with pytest.raises(AssertionError):
        k.dense_sqnorm(_randn(0, (4, 3)), _randn(1, (5, 3)))


# ------------------------------------------------------------------ diversity_reduce


@given(
    m=st.integers(1, 100),
    p=st.integers(1, 200),
    bm=st.sampled_from([8, 32, 128]),
    bp=st.sampled_from([16, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_diversity_reduce_matches_ref(m, p, bm, bp, seed):
    g = _randn(seed, (m, p))
    w = _mask(seed + 1, m)
    sq, gsum = k.diversity_reduce(g, w, block_m=bm, block_f=bp)
    sq_r, gsum_r = ref.diversity_reduce_ref(g, w)
    np.testing.assert_allclose(sq, sq_r, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(gsum, gsum_r, rtol=2e-5, atol=1e-5)


def test_diversity_reduce_all_masked():
    g = _randn(0, (12, 5))
    sq, gsum = k.diversity_reduce(g, jnp.zeros(12))
    assert float(sq) == 0.0
    np.testing.assert_array_equal(gsum, jnp.zeros(5))


def test_diversity_reduce_weights_scale_linearly():
    g = _randn(0, (6, 4))
    w = jnp.ones(6)
    sq1, gs1 = k.diversity_reduce(g, w)
    sq2, gs2 = k.diversity_reduce(g, 2.0 * w)
    np.testing.assert_allclose(sq2, 2.0 * sq1, rtol=1e-6)
    np.testing.assert_allclose(gs2, 2.0 * gs1, rtol=1e-6)


def test_diversity_definition_consistency():
    """n * Delta computed from kernel outputs matches Definition 1."""
    g = _randn(7, (40, 9))
    w = jnp.ones(40)
    sq, gsum = k.diversity_reduce(g, w)
    delta = sq / jnp.sum(gsum**2)
    np.testing.assert_allclose(delta, ref.gradient_diversity_ref(g), rtol=2e-5)


# ---------------------------------------------------------------------- sgd_fused


@given(
    p=st.integers(1, 3000),
    bp=st.sampled_from([64, 1024, 8192]),
    lr=st.floats(1e-4, 10.0),
    mu=st.sampled_from([0.0, 0.5, 0.9]),
    wd=st.sampled_from([0.0, 5e-4]),
    m=st.sampled_from([1, 128, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_fused_matches_ref(p, bp, lr, mu, wd, m, seed):
    params = _randn(seed, (p,))
    vel = _randn(seed + 1, (p,)) * 0.1
    grad = _randn(seed + 2, (p,))
    s = jnp.array([lr, mu, wd, 1.0 / m], jnp.float32)
    got_p, got_v = k.sgd_fused(params, vel, grad, s, block_p=bp)
    want_p, want_v = ref.sgd_fused_ref(params, vel, grad, s)
    np.testing.assert_allclose(got_p, want_p, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, want_v, rtol=2e-5, atol=1e-6)


def test_sgd_fused_zero_lr_is_identity_on_params():
    params = _randn(0, (100,))
    vel = jnp.zeros(100)
    grad = _randn(1, (100,))
    s = jnp.array([0.0, 0.9, 0.0, 1.0], jnp.float32)
    got_p, _ = k.sgd_fused(params, vel, grad, s)
    np.testing.assert_array_equal(got_p, params)


def test_sgd_fused_plain_sgd_step():
    """mu=0, wd=0 reduces to theta - lr/m * grad_sum (Algorithm 1 line 8)."""
    params = _randn(0, (64,))
    grad = _randn(1, (64,))
    s = jnp.array([0.5, 0.0, 0.0, 1.0 / 32.0], jnp.float32)
    got_p, _ = k.sgd_fused(params, jnp.zeros(64), grad, s)
    np.testing.assert_allclose(got_p, params - 0.5 * grad / 32.0, rtol=1e-6)
