"""Shared pytest fixtures and hypothesis configuration."""

from __future__ import annotations

import jax
import pytest
from hypothesis import HealthCheck, settings

# Kernel sweeps lower pallas_call per example; keep example counts modest
# and disable the deadline (interpret-mode tracing is slow but not flaky).
settings.register_profile(
    "kernels",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
