"""L2 model correctness: flatten/unflatten, losses, grads, closed forms."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.models import REGISTRY, get_model
from compile.models.common import bce_with_logits, flat_size, flatten, softmax_ce, unflatten
from compile.models.logreg import make_logreg
from compile.models.mlp import make_mlp
from compile.models.resnet_tiny import make_resnet_tiny


def _batch(model, m, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (m, *model.input_shape), jnp.float32)
    if model.label_dtype == "s32":
        y = jax.random.randint(ky, (m,), 0, model.num_classes)
    else:
        y = (jax.random.uniform(ky, (m,)) > 0.5).astype(jnp.float32)
    return x, y


# ------------------------------------------------------------------ flat params


@pytest.mark.parametrize("name", ["tinylogreg8", "tinymlp8", "tinyresnet4"])
def test_flatten_roundtrip(name):
    model = get_model(name)
    flat = model.init(jax.random.PRNGKey(1))
    assert flat.shape == (model.param_count,)
    tree = unflatten(flat, model.specs)
    back = flatten(tree, model.specs)
    np.testing.assert_array_equal(flat, back)


def test_flat_size_matches_specs():
    model = get_model("tinymlp8")
    assert flat_size(model.specs) == 8 * 4 + 4 + 4 * 1 + 1


def test_init_deterministic_per_seed():
    model = get_model("tinyresnet4")
    a = model.init(jax.random.PRNGKey(3))
    b = model.init(jax.random.PRNGKey(3))
    c = model.init(jax.random.PRNGKey(4))
    np.testing.assert_array_equal(a, b)
    assert not np.allclose(a, c)


# ------------------------------------------------------------------ losses


@given(z=st.floats(-30, 30), y=st.sampled_from([0.0, 1.0]))
@settings(max_examples=50)
def test_bce_matches_naive(z, y):
    """Stable BCE == -y log p - (1-y) log(1-p), float64 reference.

    (The naive f32 formula itself loses precision for |z| > ~9, which is
    exactly why the stable form exists — so the oracle runs in float64.)
    """
    import math

    p = 1.0 / (1.0 + math.exp(-z))
    naive = -(y * math.log(p) + (1 - y) * math.log1p(-p)) if 0.0 < p < 1.0 else None
    got = float(bce_with_logits(jnp.array([z], jnp.float32), jnp.array([y], jnp.float32))[0])
    assert np.isfinite(got)
    if naive is not None and np.isfinite(naive):
        np.testing.assert_allclose(got, naive, rtol=1e-4, atol=1e-5)


def test_bce_gradient_is_sigmoid_minus_y():
    """The dense-trick kernels assume d(bce)/dz == sigmoid(z) - y exactly,
    including at z == 0 (the kink that broke the max-based formulation)."""
    for z in [-5.0, 0.0, 3.0]:
        for y in [0.0, 1.0]:
            g = jax.grad(lambda zz: bce_with_logits(zz[None], jnp.array([y]))[0])(jnp.array(z))
            np.testing.assert_allclose(g, jax.nn.sigmoid(z) - y, rtol=1e-6, atol=1e-7)


def test_softmax_ce_matches_naive():
    logits = jax.random.normal(jax.random.PRNGKey(0), (16, 7))
    y = jax.random.randint(jax.random.PRNGKey(1), (16,), 0, 7)
    probs = jax.nn.softmax(logits, axis=-1)
    naive = -jnp.log(probs[jnp.arange(16), y])
    np.testing.assert_allclose(softmax_ce(logits, y), naive, rtol=1e-5)


def test_softmax_ce_shift_invariant():
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 5))
    y = jnp.array([0, 1, 2, 3])
    shifted = logits + 1000.0
    np.testing.assert_allclose(softmax_ce(logits, y), softmax_ce(shifted, y), rtol=1e-4)


# ------------------------------------------------------------------ logreg


def test_logreg_grad_matches_closed_form():
    """grad of sum-loss == X^T (sigmoid(z) - y), bias = sum(r)."""
    model = make_logreg(6)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, 12)

    def loss(p):
        return jnp.sum(model.per_sample_loss(model.apply(p, x), y))

    g = jax.grad(loss)(flat)
    r = jax.nn.sigmoid(model.apply(flat, x)) - y
    expect_w = x.T @ r
    expect_b = jnp.sum(r)
    np.testing.assert_allclose(g[:6], expect_w, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g[6], expect_b, rtol=1e-5, atol=1e-6)


def test_logreg_grad_matches_finite_differences():
    model = make_logreg(4)
    flat = model.init(jax.random.PRNGKey(1))
    x, y = _batch(model, 5, seed=2)

    def loss(p):
        return float(jnp.sum(model.per_sample_loss(model.apply(p, x), y)))

    g = jax.grad(lambda p: jnp.sum(model.per_sample_loss(model.apply(p, x), y)))(flat)
    eps = 1e-3
    for i in range(5):
        e = jnp.zeros_like(flat).at[i].set(eps)
        fd = (loss(flat + e) - loss(flat - e)) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=2e-2, atol=1e-4)


@pytest.mark.parametrize("d,m", [(8, 16), (32, 7), (512, 64)])
def test_logreg_persample_sqnorm_vs_oracle(d, m):
    model = make_logreg(d)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, m, seed=d)
    got = model.persample_sqnorm(flat, x, y)
    want = ref.persample_grad_sqnorm_oracle(model.single_loss, flat, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------------ mlp


@pytest.mark.parametrize("d,h,m", [(8, 4, 16), (16, 8, 9), (64, 32, 32)])
def test_mlp_persample_sqnorm_vs_oracle(d, h, m):
    model = make_mlp(d, h)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, m, seed=d + h)
    got = model.persample_sqnorm(flat, x, y)
    want = ref.persample_grad_sqnorm_oracle(model.single_loss, flat, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_mlp_nonzero_hidden_grads():
    """MLP must be genuinely nonconvex: hidden-layer grads nonzero."""
    model = make_mlp(8, 4)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, 32)

    def loss(p):
        return jnp.sum(model.per_sample_loss(model.apply(p, x), y))

    g = jax.grad(loss)(flat)
    w1 = g[: 8 * 4]
    assert float(jnp.sum(w1 * w1)) > 0


# ------------------------------------------------------------------ resnet


def test_resnet_output_shape_and_finite():
    model = make_resnet_tiny(10)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, 4)
    logits = model.apply(flat, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_resnet_activation_variance_stable():
    """BN-free residual scaling keeps logits O(1) at init."""
    model = make_resnet_tiny(10)
    flat = model.init(jax.random.PRNGKey(5))
    x, _ = _batch(model, 32, seed=6)
    logits = model.apply(flat, x)
    assert float(jnp.std(logits)) < 50.0


def test_resnet_correct_counts_argmax():
    model = make_resnet_tiny(4, image_size=8, channels=(4,), blocks_per_stage=1)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, 10)
    logits = model.apply(flat, x)
    pred = jnp.argmax(logits, axis=-1)
    np.testing.assert_array_equal(model.correct(logits, y), (pred == y).astype(jnp.float32))


def test_resnet_param_count_in_manifest_range():
    """resnet variants stay in the tens-of-k range (ResNet-20 analogue)."""
    for nc in (10, 100, 200):
        model = make_resnet_tiny(nc)
        assert 40_000 < model.param_count < 100_000


# ------------------------------------------------------------------ registry


def test_registry_ladders_sorted_and_positive():
    for name, entry in REGISTRY.items():
        ladder = entry.ladder
        assert all(b > 0 for b in ladder), name
        assert list(ladder) == sorted(ladder), name
        assert all(b % entry.chunk == 0 or b <= entry.chunk for b in ladder), name


def test_registry_models_instantiate():
    for name in REGISTRY:
        model = get_model(name)
        assert model.param_count > 0
        assert model.name == name
