"""Step-builder semantics: the contract the Rust coordinator relies on.

These tests pin down the executable interface invariants (DESIGN.md
section 2): sample-sum outputs, weight masking, div == plain on shared
outputs, chunked-vmap == batched gradients, and SGD trainability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as sb
from compile.kernels import ref
from compile.models import get_model

MODELS = ["tinylogreg8", "tinymlp8", "tinyresnet4"]


def _batch(model, m, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (m, *model.input_shape), jnp.float32)
    if model.label_dtype == "s32":
        y = jax.random.randint(ky, (m,), 0, model.num_classes)
    else:
        y = (jax.random.uniform(ky, (m,)) > 0.5).astype(jnp.float32)
    return x, y


@pytest.mark.parametrize("name", MODELS)
def test_div_and_plain_agree_on_shared_outputs(name):
    model = get_model(name)
    flat = model.init(jax.random.PRNGKey(0))
    x, y = _batch(model, 8)
    w = jnp.ones(8)
    l1, c1, g1, _ = sb.make_train_div(model, 4)(flat, x, y, w)
    l2, c2, g2, s2 = sb.make_train_plain(model)(flat, x, y, w)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    np.testing.assert_allclose(c1, c2)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)
    assert float(s2) == 0.0  # plain reports no diversity signal


@pytest.mark.parametrize("name", MODELS)
def test_sqnorm_matches_vmap_oracle(name):
    model = get_model(name)
    flat = model.init(jax.random.PRNGKey(1))
    x, y = _batch(model, 8, seed=1)
    w = jnp.ones(8).at[-3:].set(0.0)
    _, _, _, sq = sb.make_train_div(model, 4)(flat, x, y, w)
    oracle = ref.persample_grad_sqnorm_oracle(model.single_loss, flat, x, y)
    np.testing.assert_allclose(sq, jnp.sum(w * oracle), rtol=1e-4)


@pytest.mark.parametrize("name", MODELS)
def test_padding_rows_are_noops(name):
    """w=0 rows must not influence ANY output (the planner pads with them)."""
    model = get_model(name)
    flat = model.init(jax.random.PRNGKey(2))
    x, y = _batch(model, 8, seed=2)
    w = jnp.ones(8).at[6:].set(0.0)
    step = sb.make_train_div(model, 4)
    base = step(flat, x, y, w)
    x_garbage = x.at[6:].set(1e4)
    poked = step(flat, x_garbage, y, w)
    for b, p in zip(base, poked):
        np.testing.assert_allclose(b, p, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", MODELS)
def test_sample_sum_additivity(name):
    """step(batch) == step(half1) + step(half2): the accumulation law."""
    model = get_model(name)
    flat = model.init(jax.random.PRNGKey(3))
    x, y = _batch(model, 8, seed=3)
    w = jnp.ones(8)
    step = sb.make_train_div(model, 4)
    full = step(flat, x, y, w)
    h1 = step(flat, x[:4], y[:4], w[:4])
    h2 = step(flat, x[4:], y[4:], w[4:])
    for f, a, b in zip(full, h1, h2):
        np.testing.assert_allclose(f, a + b, rtol=1e-4, atol=1e-5)


def test_chunk_size_invariance():
    """The generic per-sample path must not depend on the chunk size."""
    model = get_model("tinyresnet4")
    flat = model.init(jax.random.PRNGKey(4))
    x, y = _batch(model, 8, seed=4)
    w = jnp.ones(8)
    r2 = sb.make_train_div(model, 2)(flat, x, y, w)
    r4 = sb.make_train_div(model, 4)(flat, x, y, w)
    r8 = sb.make_train_div(model, 8)(flat, x, y, w)
    for a, b, c in zip(r2, r4, r8):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", MODELS)
def test_eval_matches_train_forward(name):
    model = get_model(name)
    flat = model.init(jax.random.PRNGKey(5))
    x, y = _batch(model, 8, seed=5)
    w = jnp.ones(8)
    le, ce = sb.make_eval(model)(flat, x, y, w)
    lt, ct, _, _ = sb.make_train_plain(model)(flat, x, y, w)
    np.testing.assert_allclose(le, lt, rtol=1e-5)
    np.testing.assert_allclose(ce, ct)


def test_update_step_matches_rust_reference_semantics():
    """update executable implements g/m + wd*p; v' = mu v + g; p' = p - lr v'."""
    model = get_model("tinymlp8")
    upd = sb.make_update(model)
    p = jax.random.normal(jax.random.PRNGKey(6), (model.param_count,))
    v = jax.random.normal(jax.random.PRNGKey(7), (model.param_count,)) * 0.01
    g = jax.random.normal(jax.random.PRNGKey(8), (model.param_count,))
    s = jnp.array([0.1, 0.9, 5e-4, 1.0 / 64], jnp.float32)
    got_p, got_v = upd(p, v, g, s)
    want_p, want_v = ref.sgd_fused_ref(p, v, g, s)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-7)


def test_sgd_reduces_loss_on_separable_data():
    """End-to-end sanity: a few Algorithm-1 steps reduce logreg loss."""
    model = get_model("tinylogreg8")
    flat = model.init(jax.random.PRNGKey(9))
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (64, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(11), (8,))
    y = (x @ w_true > 0).astype(jnp.float32)
    ones = jnp.ones(64)
    step = jax.jit(sb.make_train_plain(model))
    losses = []
    for _ in range(30):
        loss, _, grad, _ = step(flat, x, y, ones)
        losses.append(float(loss))
        flat = flat - 0.5 / 64.0 * grad  # Algorithm 1 line 8 (eta/m * sum-grad)
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_example_batch_shapes():
    model = get_model("tinyresnet4")
    p, x, y, w = sb.example_batch(model, 16)
    assert p.shape == (model.param_count,)
    assert x.shape == (16, 8, 8, 3)
    assert y.dtype == jnp.int32
    assert w.shape == (16,)
