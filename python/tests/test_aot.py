"""AOT pipeline tests: HLO text emission + manifest schema.

Lowers the tiny models to a temp dir and checks everything the Rust
runtime assumes about artifacts/ (file layout, manifest schema, HLO-text
parseability markers, init-params byte size).
"""

from __future__ import annotations

import json
import struct
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as sb
from compile.models import REGISTRY, get_model

TINY = [n for n, e in REGISTRY.items() if "tiny" in e.tags]


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    for name in TINY:
        manifest = aot.build_model_artifacts(name, REGISTRY[name], out, force=True)
        (out / "partial.json").write_text(json.dumps(manifest))
    # full manifest write
    manifest = {"version": aot.MANIFEST_VERSION, "models": {}}
    for name in TINY:
        manifest["models"][name] = aot.build_model_artifacts(name, REGISTRY[name], out, force=False)
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out


def test_hlo_text_is_text_not_proto(tiny_artifacts):
    f = tiny_artifacts / "tinylogreg8" / "train_div_b4.hlo.txt"
    text = f.read_text()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text


def test_manifest_schema(tiny_artifacts):
    m = json.loads((tiny_artifacts / "manifest.json").read_text())
    assert m["version"] == aot.MANIFEST_VERSION
    for name in TINY:
        sec = m["models"][name]
        model = get_model(name)
        assert sec["param_count"] == model.param_count
        assert tuple(sec["input_shape"]) == model.input_shape
        assert sec["label_dtype"] in ("f32", "s32")
        for b in sec["ladder"]:
            for variant in ("train_div", "train_plain", "eval"):
                e = sec["entries"][f"{variant}_b{b}"]
                assert (tiny_artifacts / e["file"]).exists()
                names = [i["name"] for i in e["inputs"]]
                assert names == ["params", "x", "y", "w"]
                assert e["inputs"][0]["shape"] == [model.param_count]
                assert e["inputs"][1]["shape"][0] == b
        upd = sec["entries"]["update"]
        assert [i["name"] for i in upd["inputs"]] == ["params", "velocity", "grad_sum", "scalars"]


def test_train_entry_output_spec(tiny_artifacts):
    m = json.loads((tiny_artifacts / "manifest.json").read_text())
    e = m["models"]["tinymlp8"]["entries"]["train_div_b8"]
    outs = {o["name"]: o for o in e["outputs"]}
    assert outs["loss_sum"]["shape"] == []
    assert outs["correct"]["shape"] == []
    assert outs["grad_sum"]["shape"] == [get_model("tinymlp8").param_count]
    assert outs["sqnorm_sum"]["shape"] == []


def test_init_params_bytes(tiny_artifacts):
    m = json.loads((tiny_artifacts / "manifest.json").read_text())
    for name in TINY:
        sec = m["models"][name]
        for rel in sec["init_params"]:
            f = tiny_artifacts / rel
            data = f.read_bytes()
            assert len(data) == 4 * sec["param_count"], name
            vals = struct.unpack(f"<{sec['param_count']}f", data)
            assert all(abs(v) < 100 for v in vals), name


def test_init_params_differ_across_seeds(tiny_artifacts):
    m = json.loads((tiny_artifacts / "manifest.json").read_text())
    sec = m["models"]["tinymlp8"]
    blobs = [(tiny_artifacts / rel).read_bytes() for rel in sec["init_params"]]
    assert len({b for b in blobs}) == len(blobs)


def test_incremental_rebuild_skips_existing(tiny_artifacts):
    """force=False must not rewrite existing HLO files (mtime stable)."""
    f = tiny_artifacts / "tinylogreg8" / "eval_b4.hlo.txt"
    before = f.stat().st_mtime_ns
    aot.build_model_artifacts("tinylogreg8", REGISTRY["tinylogreg8"], tiny_artifacts, force=False)
    assert f.stat().st_mtime_ns == before


def test_hlo_entry_signature_mentions_all_inputs(tiny_artifacts):
    """The ENTRY computation must take exactly the manifest inputs.

    (The python xla_client bundled with jax 0.8 exposes no public HLO-text
    parser, so the actual execute round-trip is covered by the Rust
    integration tests in rust/tests/.)
    """
    text = (tiny_artifacts / "tinylogreg8" / "eval_b4.hlo.txt").read_text()
    header = text.splitlines()[0]
    # entry_computation_layout: (params f32[9], x f32[4,8], y f32[4], w f32[4])
    assert "entry_computation_layout={(f32[9]{0}, f32[4,8]{1,0}, f32[4]{0}, f32[4]{0})" in header
    assert "->(f32[], f32[])" in header


def test_cli_unknown_model_errors():
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--models", "nope", "--out-dir", "/tmp/aot-nope"],
        capture_output=True,
        text=True,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert proc.returncode != 0
    assert "unknown model" in proc.stderr


def test_to_hlo_text_tuple_root():
    """Lowering uses return_tuple=True: the ENTRY root must be a tuple."""
    model = get_model("tinylogreg8")
    lowered = jax.jit(sb.make_eval(model)).lower(*sb.example_batch(model, 4))
    text = aot.to_hlo_text(lowered)
    assert "tuple(" in text.replace(" ", "") or "(f32[]" in text
