"""L1 kernel ablation harness (P3 in DESIGN.md §5).

Benchmarks and analyzes the Pallas per-sample-gradient kernels:

1. **Algorithmic ablation** (the real content): per-sample grad sq-norm of
   a dense layer via (a) the fused dense-trick kernel, (b) the two-pass
   row_sqnorm composition, (c) naive materialized ``vmap(grad)`` — FLOP
   and memory-traffic counts per variant, plus interpret-mode wallclock
   for reference (NOT a TPU proxy; interpret mode runs numpy-speed).
2. **VMEM/roofline accounting**: per-kernel block footprint vs the 16 MiB
   VMEM budget, bytes moved, arithmetic intensity, and the induced
   HBM-bandwidth-bound time estimate on a v4-class TPU — the structural
   numbers DESIGN.md §6 and EXPERIMENTS.md §Perf quote.
3. **Block-shape sweep**: VMEM footprint + estimated HBM time across
   (block_m, block_f) for diversity_reduce, showing the chosen default is
   on the flat part of the curve.

Run from python/: ``python -m compile.bench_kernels`` (or `make perf-l1`).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from compile.kernels import persample as k
from compile.kernels import ref

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, v4-class
HBM_GBPS = 1200e9  # v4-class HBM bandwidth
F32 = 4


def _timeit(fn, *args, iters=5):
    fn(*args)  # compile/warmup
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def dense_trick_traffic(m: int, p: int, q: int) -> dict:
    """Bytes moved / FLOPs for each per-sample sq-norm strategy."""
    return {
        "fused dense-trick": {
            "bytes": F32 * (m * p + m * q + m),
            "flops": 2 * m * (p + q),
        },
        "two-pass row_sqnorm": {
            "bytes": F32 * (m * p + m * q + 3 * m),
            "flops": 2 * m * (p + q) + m,
        },
        "naive vmap(grad) (BackPACK regime)": {
            # materializes per-sample weight grads: m x p x q write+read.
            "bytes": F32 * (m * p + m * q + 2 * m * p * q + m),
            "flops": 2 * m * p * q + 2 * m * p * q,
        },
    }


def section_ablation():
    print("== P3.1 algorithmic ablation: per-sample dense-layer grad sq-norms ==")
    cases = [(128, 512, 64), (1024, 512, 64), (2048, 512, 1)]
    for m, p, q in cases:
        print(f"\n  m={m} p={p} q={q}:")
        traffic = dense_trick_traffic(m, p, q)
        base = traffic["fused dense-trick"]["bytes"]
        for name, t in traffic.items():
            est = t["bytes"] / HBM_GBPS
            print(
                f"    {name:<36} {t['bytes'] / 1e6:9.2f} MB moved "
                f"({t['bytes'] / base:6.1f}x)   est. HBM-bound {est * 1e6:8.1f} us"
            )
        # Interpret-mode wallclock (reference only).
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (m, p))
        d = jax.random.normal(key, (m, q))
        fused = jax.jit(lambda a, d: k.dense_sqnorm(a, d))
        twopass = jax.jit(lambda a, d: (k.row_sqnorm(a) + 1.0) * k.row_sqnorm(d))
        refn = jax.jit(lambda a, d: ref.dense_sqnorm_ref(a, d))
        print(f"    interpret-mode wallclock (reference, CPU): fused {_timeit(fused, a, d)*1e3:.2f} ms, "
              f"two-pass {_timeit(twopass, a, d)*1e3:.2f} ms, jnp-ref {_timeit(refn, a, d)*1e3:.2f} ms")


def vmem_footprint(block_m: int, block_f: int, outs: int = 1) -> int:
    """Double-buffered VMEM bytes for one (block_m, block_f) grid step."""
    in_tile = block_m * block_f * F32
    out_tile = (block_f + block_m + 1) * F32 * outs
    return 2 * (in_tile + out_tile)  # x2: double buffering


def section_vmem():
    print("\n== P3.2 VMEM / roofline accounting (defaults) ==")
    rows = [
        ("row_sqnorm", k.DEFAULT_BLOCK_M, k.DEFAULT_BLOCK_F, 1),
        ("dense_sqnorm (fused, p=512,q=64)", k.DEFAULT_BLOCK_M, 512 + 64, 1),
        ("diversity_reduce", k.DEFAULT_BLOCK_M, k.DEFAULT_BLOCK_F, 2),
        ("sgd_fused", 1, k.DEFAULT_BLOCK_P, 2),
    ]
    print(f"    {'kernel':<34} {'block':<14} {'VMEM/step':<12} {'of 16MiB':<9} AI(flops/byte)")
    for name, bm, bf, outs in rows:
        vm = vmem_footprint(bm, bf, outs)
        ai = (2 * bm * bf) / (bm * bf * F32)  # ~0.5 for reductions
        print(
            f"    {name:<34} {f'({bm},{bf})':<14} {vm / 1024:9.1f} KiB {100 * vm / VMEM_BYTES:7.2f}%  {ai:6.2f}"
        )
    print(
        "    all kernels are bandwidth-bound streaming reductions (AI ~0.5):\n"
        "    TPU-time estimate = bytes/HBM_BW; VMEM stays <6% of budget, leaving\n"
        "    headroom for the model matmul tiles in the same lowered module."
    )


def section_block_sweep():
    print("\n== P3.3 diversity_reduce block-shape sweep (m=2048, P=57960) ==")
    m, p = 2048, 57960  # resnet200 flat grads
    bytes_moved = F32 * (m * p + m + p + 1)
    print(f"    fixed traffic {bytes_moved / 1e6:.1f} MB -> HBM-bound {1e3 * bytes_moved / HBM_GBPS:.3f} ms")
    print(f"    {'(bm,bf)':<14} {'VMEM/step':<14} {'grid steps':<12} viable")
    for bm in (32, 128, 512):
        for bf in (128, 512, 2048):
            vm = vmem_footprint(bm, bf, 2)
            steps = -(-m // bm) * -(-p // bf)
            viable = "yes" if vm < VMEM_BYTES // 4 else "NO (>25% VMEM)"
            print(f"    ({bm},{bf})".ljust(18) + f"{vm / 1024:8.1f} KiB   {steps:<12} {viable}")
    print(
        "    default (128,512) sits on the flat part: traffic is shape-independent,\n"
        "    so the only lever is keeping per-step VMEM small + grid overhead low."
    )


def section_chunk_sweep():
    print("\n== P3.4 L2 chunk-size sweep (CNN per-sample pass, resnet10-scale) ==")
    p_count = 51_690
    m = 1024
    print(f"    {'chunk':<8} {'per-sample buffer':<20} {'extra HBM traffic':<20}")
    for chunk in (8, 16, 32, 64, 128):
        buf = chunk * p_count * F32
        traffic = 2 * m * p_count * F32  # write+read each per-sample grad once
        print(
            f"    {chunk:<8} {buf / 1e6:10.2f} MB        {traffic / 1e6:10.2f} MB (chunk-independent)"
        )
    print(
        "    memory scales with chunk; traffic does not -> pick the largest chunk\n"
        "    that fits alongside activations (manifest default: 32 for resnets)."
    )


def main():
    print("divebatch L1 kernel ablations (P3)\n" + "=" * 60)
    section_ablation()
    section_vmem()
    section_block_sweep()
    section_chunk_sweep()


if __name__ == "__main__":
    main()
