"""Two-layer MLP — the paper's nonconvex synthetic model (section 5.1).

The per-sample gradient squared norm decomposes layer-by-layer via manual
backprop (exact, no approximation):

    z1 = x W1 + b1,  a1 = relu(z1),  z2 = a1 w2 + b2
    d2 = sigmoid(z2) - y                       (m, 1)
    d1 = (d2 w2^T) * relu'(z1)                 (m, h)
    ||g_i||^2 = dense_sqnorm(x, d1) + dense_sqnorm(a1, d2)

Both layer contributions run through the L1 Pallas kernel, so the lowered
HLO module exercises the kernel on the real hot path.  The model-level
tests validate this closed form against the vmap(grad) oracle.

Note on width: the paper sizes the MLP "with the same number of parameters
as the logistic regression" (d+1 = 513), which for a 2-layer net forces a
single hidden unit and a degenerate nonconvexity.  We default to hidden=64
(a genuinely nonconvex landscape) and expose the width; DESIGN.md section 3
records the deviation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import dense_sqnorm
from compile.models.common import (
    Model,
    ParamSpec,
    bce_with_logits,
    glorot_uniform,
    unflatten,
)


def make_mlp(d: int, hidden: int, name: str | None = None) -> Model:
    """Binary-classification MLP: d -> hidden (relu) -> 1."""
    specs = (
        ParamSpec("w1", (d, hidden)),
        ParamSpec("b1", (hidden,)),
        ParamSpec("w2", (hidden, 1)),
        ParamSpec("b2", (1,)),
    )

    def init(key: jax.Array) -> jax.Array:
        k1, k2 = jax.random.split(key)
        w1 = glorot_uniform(k1, (d, hidden), d, hidden)
        w2 = glorot_uniform(k2, (hidden, 1), hidden, 1)
        return jnp.concatenate(
            [w1.reshape(-1), jnp.zeros((hidden,)), w2.reshape(-1), jnp.zeros((1,))]
        ).astype(jnp.float32)

    def forward(flat: jax.Array, x: jax.Array):
        p = unflatten(flat, specs)
        z1 = x @ p["w1"] + p["b1"]
        a1 = jax.nn.relu(z1)
        z2 = a1 @ p["w2"] + p["b2"]
        return z1, a1, z2[:, 0], p

    def apply(flat: jax.Array, x: jax.Array) -> jax.Array:
        return forward(flat, x)[2]

    def correct(logits: jax.Array, y: jax.Array) -> jax.Array:
        return ((logits > 0).astype(jnp.float32) == y).astype(jnp.float32)

    def persample_sqnorm(flat: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
        z1, a1, z2, p = forward(flat, x)
        d2 = (jax.nn.sigmoid(z2) - y)[:, None]  # (m, 1)
        d1 = (d2 @ p["w2"].T) * (z1 > 0).astype(jnp.float32)  # (m, h)
        return dense_sqnorm(x, d1, has_bias=True) + dense_sqnorm(a1, d2, has_bias=True)

    return Model(
        name=name or f"mlp{d}x{hidden}",
        input_shape=(d,),
        label_dtype="f32",
        num_classes=2,
        specs=specs,
        init=init,
        apply=apply,
        per_sample_loss=bce_with_logits,
        correct=correct,
        persample_sqnorm=persample_sqnorm,
    )
