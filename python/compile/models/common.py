"""Shared Layer-2 model machinery: flat parameter vectors, losses, inits.

Every model in the zoo exposes the same protocol (see :class:`Model`):
parameters live in a single flat ``f32[P]`` vector so the Rust coordinator
can treat optimizer state uniformly (one contiguous buffer per model, no
pytree marshaling across the FFI boundary).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter tensor inside the flat vector."""

    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def flat_size(specs: Sequence[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def unflatten(flat: jax.Array, specs: Sequence[ParamSpec]) -> dict[str, jax.Array]:
    """Slice the flat vector into named tensors (static offsets, jit-safe)."""
    out = {}
    off = 0
    for s in specs:
        out[s.name] = flat[off : off + s.size].reshape(s.shape)
        off += s.size
    assert off == flat.shape[0], f"flat vector size {flat.shape[0]} != specs total {off}"
    return out


def flatten(params: dict[str, jax.Array], specs: Sequence[ParamSpec]) -> jax.Array:
    return jnp.concatenate([params[s.name].reshape(-1) for s in specs])


def bce_with_logits(z: jax.Array, y: jax.Array) -> jax.Array:
    """Numerically-stable per-sample binary cross-entropy.

    ``z``: logits ``(m,)``; ``y``: float labels in {0, 1} ``(m,)``.

    Uses ``logaddexp(z, 0) - z*y`` rather than the max/log1p form: it is
    equally stable but *smooth*, so autodiff yields exactly
    ``sigmoid(z) - y`` everywhere — which the closed-form dense-trick
    kernels assume (the max-based form has a subgradient mismatch at z=0).
    """
    return jnp.logaddexp(z, 0.0) - z * y


def softmax_ce(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Per-sample softmax cross-entropy with integer labels.

    ``logits``: ``(m, k)``; ``y``: int32 labels ``(m,)``.

    Written as a shifted explicit log-sum-exp plus an iota/one-hot label
    pick instead of ``logsumexp`` + ``take_along_axis``: the values and
    gradients are identical (the shift is under ``stop_gradient``), but
    this form lowers to HLO the interp backend executes directly —
    reduce/exp/log/iota/compare — with no gather and no reduce-max VJP
    (select-and-scatter).
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[:, 0]
    k = logits.shape[-1]
    onehot = (jax.lax.iota(jnp.int32, k)[None, :] == y[:, None].astype(jnp.int32)).astype(
        jnp.float32
    )
    picked = jnp.sum(logits * onehot, axis=-1)
    return lse - picked


def glorot_uniform(key: jax.Array, shape: tuple[int, ...], fan_in: int, fan_out: int) -> jax.Array:
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def he_normal(key: jax.Array, shape: tuple[int, ...], fan_in: int) -> jax.Array:
    std = math.sqrt(2.0 / fan_in)
    return std * jax.random.normal(key, shape, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Model:
    """Uniform model protocol consumed by the step builders in model.py.

    Attributes:
      name: registry key; artifact paths are ``artifacts/<name>/...``.
      input_shape: per-sample feature shape (e.g. ``(512,)`` or ``(16,16,3)``).
      label_dtype: ``"f32"`` (binary {0,1} targets) or ``"s32"`` (class ids).
      num_classes: 2 for binary models (label still a single float).
      specs: parameter layout of the flat vector.
      init: ``key -> f32[P]`` flat parameter initialiser.
      apply: ``(flat, x_batch) -> logits`` (``(m,)`` binary / ``(m,k)`` CE).
      per_sample_loss: ``(logits, y) -> (m,)`` UNWEIGHTED per-sample losses.
      correct: ``(logits, y) -> (m,)`` 0/1 prediction-correct indicators.
      persample_sqnorm: optional closed-form ``(flat, x, y) -> (m,)`` exact
        per-sample gradient squared norms (dense-trick Pallas kernels).
        ``None`` selects the generic chunked ``vmap(grad)`` path.
    """

    name: str
    input_shape: tuple[int, ...]
    label_dtype: str
    num_classes: int
    specs: tuple[ParamSpec, ...]
    init: Callable[[jax.Array], jax.Array]
    apply: Callable[[jax.Array, jax.Array], jax.Array]
    per_sample_loss: Callable[[jax.Array, jax.Array], jax.Array]
    correct: Callable[[jax.Array, jax.Array], jax.Array]
    persample_sqnorm: Callable[[jax.Array, jax.Array, jax.Array], jax.Array] | None = None

    @property
    def param_count(self) -> int:
        return flat_size(self.specs)

    def single_loss(self, flat: jax.Array, xi: jax.Array, yi: jax.Array) -> jax.Array:
        """Scalar loss of one sample — used by vmap oracles and tests."""
        logits = self.apply(flat, xi[None])
        return self.per_sample_loss(logits, yi[None])[0]
