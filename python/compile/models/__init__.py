"""Layer-2 model zoo.

``REGISTRY`` maps artifact model names to factory thunks plus their AOT
configuration (micro-batch ladder + chunk size for the generic per-sample
path).  The ladders define which static batch sizes get a compiled
executable; the Rust coordinator's accumulation planner composes arbitrary
logical batch sizes out of these micro-batches (rust/src/coordinator/plan.rs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from compile.models.common import Model, ParamSpec, flat_size, flatten, unflatten  # noqa: F401
from compile.models.logreg import make_logreg
from compile.models.mlp import make_mlp
from compile.models.resnet_tiny import make_resnet_tiny


@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """AOT configuration for one registry model."""

    factory: Callable[[], Model]
    ladder: tuple[int, ...]  # compiled micro-batch sizes (ascending)
    chunk: int  # vmap(grad) chunk for the generic per-sample path
    n_init_seeds: int = 5  # how many seeded init_params files to emit
    tags: tuple[str, ...] = ()  # e.g. ("tiny",) for the test-only artifacts


REGISTRY: dict[str, ModelEntry] = {
    # Synthetic experiments (Figures 1-2): d=512 per the paper.
    "logreg512": ModelEntry(lambda: make_logreg(512, "logreg512"), (128, 512, 2048, 4096), 64),
    "mlp512": ModelEntry(lambda: make_mlp(512, 64, "mlp512"), (128, 512, 2048, 8192), 64),
    # CIFAR-like runs (Figures 3-6, Tables 1-2, 5): one model per class count.
    "resnet10": ModelEntry(lambda: make_resnet_tiny(10, name="resnet10"), (64, 256, 1024), 32),
    "resnet100": ModelEntry(lambda: make_resnet_tiny(100, name="resnet100"), (64, 256, 1024), 32),
    "resnet200": ModelEntry(lambda: make_resnet_tiny(200, name="resnet200"), (64, 256, 1024), 32),
    # Tiny artifacts: fast to lower + compile; used by cargo integration
    # tests and CI so `cargo test` exercises the real PJRT path.
    "tinylogreg8": ModelEntry(
        lambda: make_logreg(8, "tinylogreg8"), (4, 8), 4, n_init_seeds=3, tags=("tiny",)
    ),
    # Wide-ladder variant of the convex fixture model for the sharded
    # step executor: a 64-row rung gives multi-block plans with real
    # per-block work, so the step-parallel speedup bench (perf_step /
    # BENCH_5.json) and the --step-jobs equivalence tests have something
    # to shard.  Same logreg-d8 semantics as tinylogreg8.
    "steplogreg8": ModelEntry(
        lambda: make_logreg(8, "steplogreg8"), (8, 64), 8, n_init_seeds=1, tags=("tiny", "step")
    ),
    "tinymlp8": ModelEntry(
        lambda: make_mlp(8, 4, "tinymlp8"), (4, 8), 4, n_init_seeds=3, tags=("tiny",)
    ),
    "tinyresnet4": ModelEntry(
        lambda: make_resnet_tiny(4, image_size=8, channels=(4,), blocks_per_stage=1, name="tinyresnet4"),
        (4, 8),
        4,
        n_init_seeds=3,
        tags=("tiny",),
    ),
    # Mid-tier conv-dominated fixture (ISSUE 10): two stages, 16x16
    # images, enough channels that the interpreter's conv cost model sees
    # forward convs worth blocking (the weight-gradient convs keep the
    # im2col arm hot).  Drives the fig3/4-style CIFAR-like presets and
    # the perf_conv / BENCH_7.json blocked-vs-im2col gate.
    "tinyresnet8": ModelEntry(
        lambda: make_resnet_tiny(8, image_size=16, channels=(8, 16), blocks_per_stage=1, name="tinyresnet8"),
        (4, 8),
        4,
        n_init_seeds=1,
        tags=("tiny",),
    ),
}


def get_model(name: str) -> Model:
    return REGISTRY[name].factory()
