"""ResNet-tiny — the image-classification model for the CIFAR-like runs.

A residual CNN in the ResNet-20 family (He et al. 2016), sized for the
1-core CPU-PJRT testbed (DESIGN.md section 3): 16x16x3 inputs, a 3x3 stem,
two residual stages with stride-2 transitions, global average pooling and
a dense head.  Batch normalisation is intentionally omitted: its batch-
statistics couple the loss to the batch size, which would confound an
adaptive-batch-size study (and break per-sample gradient semantics); He
initialisation plus residual scaling keeps training stable at this depth.

Per-sample gradient sq-norms have no cheap closed form for conv layers, so
``persample_sqnorm`` is None and the step builder uses the memory-bounded
chunked ``vmap(grad)`` path with the L1 ``diversity_reduce`` kernel — the
exact quantity BackPACK computed for the paper, at bounded memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.models.common import Model, ParamSpec, he_normal, softmax_ce, unflatten

_DN = ("NHWC", "HWIO", "NHWC")


def _conv(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=_DN
    )


def make_resnet_tiny(
    num_classes: int,
    image_size: int = 16,
    channels: tuple[int, ...] = (16, 32),
    blocks_per_stage: int = 2,
    name: str | None = None,
) -> Model:
    """Build a ResNet-tiny for ``num_classes`` over NHWC images."""
    c_in = 3
    specs: list[ParamSpec] = [
        ParamSpec("stem_w", (3, 3, c_in, channels[0])),
        ParamSpec("stem_b", (channels[0],)),
    ]
    for s, c in enumerate(channels):
        if s > 0:
            specs.append(ParamSpec(f"trans{s}_w", (3, 3, channels[s - 1], c)))
            specs.append(ParamSpec(f"trans{s}_b", (c,)))
        for b in range(blocks_per_stage):
            specs.append(ParamSpec(f"s{s}b{b}_w1", (3, 3, c, c)))
            specs.append(ParamSpec(f"s{s}b{b}_b1", (c,)))
            specs.append(ParamSpec(f"s{s}b{b}_w2", (3, 3, c, c)))
            specs.append(ParamSpec(f"s{s}b{b}_b2", (c,)))
    specs.append(ParamSpec("head_w", (channels[-1], num_classes)))
    specs.append(ParamSpec("head_b", (num_classes,)))
    specs = tuple(specs)

    def init(key: jax.Array) -> jax.Array:
        parts = []
        for spec in specs:
            key, sub = jax.random.split(key)
            if len(spec.shape) == 1:  # all rank-1 params are biases
                parts.append(jnp.zeros(spec.shape, jnp.float32).reshape(-1))
            elif spec.name == "head_w":
                fan_in = spec.shape[0]
                parts.append(he_normal(sub, spec.shape, fan_in).reshape(-1))
            else:  # conv weights HWIO
                fan_in = spec.shape[0] * spec.shape[1] * spec.shape[2]
                parts.append(he_normal(sub, spec.shape, fan_in).reshape(-1))
        return jnp.concatenate(parts)

    inv_sqrt2 = 1.0 / jnp.sqrt(2.0)

    def apply(flat: jax.Array, x: jax.Array) -> jax.Array:
        p = unflatten(flat, specs)
        h = jax.nn.relu(_conv(x, p["stem_w"]) + p["stem_b"])
        for s in range(len(channels)):
            if s > 0:
                h = jax.nn.relu(_conv(h, p[f"trans{s}_w"], stride=2) + p[f"trans{s}_b"])
            for b in range(blocks_per_stage):
                r = jax.nn.relu(_conv(h, p[f"s{s}b{b}_w1"]) + p[f"s{s}b{b}_b1"])
                r = _conv(r, p[f"s{s}b{b}_w2"]) + p[f"s{s}b{b}_b2"]
                # 1/sqrt(2) residual scaling keeps activation variance flat
                # without batch statistics (BN-free; see module docstring).
                h = jax.nn.relu((h + r) * inv_sqrt2)
        h = jnp.mean(h, axis=(1, 2))  # global average pool -> (m, c_last)
        return h @ p["head_w"] + p["head_b"]

    def correct(logits: jax.Array, y: jax.Array) -> jax.Array:
        # "Picked logit >= row max" instead of argmax == y: lowers to
        # reduce/compare HLO the interp backend executes (argmax lowers
        # to a variadic reduce it rejects).  Deviation: exact ties on the
        # max logit count as correct; measure-zero for float logits.
        k = logits.shape[-1]
        onehot = (jax.lax.iota(jnp.int32, k)[None, :] == y[:, None].astype(jnp.int32)).astype(
            jnp.float32
        )
        picked = jnp.sum(logits * onehot, axis=-1)
        return (picked >= jnp.max(logits, axis=-1)).astype(jnp.float32)

    return Model(
        name=name or f"resnet{num_classes}",
        input_shape=(image_size, image_size, 3),
        label_dtype="s32",
        num_classes=num_classes,
        specs=specs,
        init=init,
        apply=apply,
        per_sample_loss=softmax_ce,
        correct=correct,
        persample_sqnorm=None,
    )
