"""Logistic regression — the paper's convex synthetic model (section 5.1).

Per-sample gradient squared norms have the closed form

    g_i = (sigmoid(z_i) - y_i) * [x_i, 1]
    ||g_i||^2 = r_i^2 * (||x_i||^2 + 1)

which is exactly the dense-trick Pallas kernel with activations ``x`` and
output-grads ``r[:, None]`` — no per-sample gradient materialization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import dense_sqnorm
from compile.models.common import Model, ParamSpec, bce_with_logits, glorot_uniform, unflatten


def make_logreg(d: int, name: str | None = None) -> Model:
    """Binary logistic regression over ``d`` input features (d+1 params)."""
    specs = (ParamSpec("w", (d,)), ParamSpec("b", (1,)))

    def init(key: jax.Array) -> jax.Array:
        w = glorot_uniform(key, (d,), d, 1)
        return jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])

    def apply(flat: jax.Array, x: jax.Array) -> jax.Array:
        p = unflatten(flat, specs)
        return x @ p["w"] + p["b"][0]

    def correct(logits: jax.Array, y: jax.Array) -> jax.Array:
        return ((logits > 0).astype(jnp.float32) == y).astype(jnp.float32)

    def persample_sqnorm(flat: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
        z = apply(flat, x)
        r = jax.nn.sigmoid(z) - y  # d(loss)/d(z), shape (m,)
        return dense_sqnorm(x, r[:, None], has_bias=True)

    return Model(
        name=name or f"logreg{d}",
        input_shape=(d,),
        label_dtype="f32",
        num_classes=2,
        specs=specs,
        init=init,
        apply=apply,
        per_sample_loss=bce_with_logits,
        correct=correct,
        persample_sqnorm=persample_sqnorm,
    )
