"""AOT pipeline: lower every (model, entry, micro-batch) to HLO text.

Run once at build time (``make artifacts``); Python is never on the
training path.  For each registry model this emits::

    artifacts/<model>/<entry>_b<m>.hlo.txt   # train_div / train_plain / eval
    artifacts/<model>/update.hlo.txt         # fused on-device SGD update
    artifacts/<model>/init_s<seed>.bin       # raw little-endian f32 params
    artifacts/manifest.json                  # shapes, dtypes, ladders

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts [--models a,b] [--tiny]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as step_builders
from compile.models import REGISTRY, ModelEntry
from compile.models.common import Model

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (return_tuple root)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(x) -> str:
    return {"float32": "f32", "int32": "s32"}[str(jnp.dtype(x))]


def _io_spec(avals) -> list[dict]:
    return [
        {"name": name, "dtype": _dt(a.dtype), "shape": [int(s) for s in a.shape]}
        for name, a in avals
    ]


def lower_entry(fn, args, in_names: list[str], out_names: list[str], path: Path) -> dict:
    """Lower ``fn`` at ``args``, write HLO text, return its manifest record."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    out_avals = jax.eval_shape(fn, *args)
    if not isinstance(out_avals, tuple):
        out_avals = (out_avals,)
    return {
        "file": str(path.relative_to(path.parents[1])),
        "inputs": _io_spec(list(zip(in_names, args))),
        "outputs": _io_spec(list(zip(out_names, out_avals))),
        "hlo_bytes": len(text),
    }


TRAIN_OUTS = ["loss_sum", "correct", "grad_sum", "sqnorm_sum"]
EVAL_OUTS = ["loss_sum", "correct"]
BATCH_INS = ["params", "x", "y", "w"]


def build_model_artifacts(name: str, entry: ModelEntry, out_dir: Path, force: bool) -> dict:
    """Emit all artifacts for one model; returns its manifest section."""
    model: Model = entry.factory()
    mdir = out_dir / name
    mdir.mkdir(parents=True, exist_ok=True)

    entries: dict[str, dict] = {}
    t0 = time.time()
    for m in entry.ladder:
        args = step_builders.example_batch(model, m)
        for variant, fn in (
            ("train_div", step_builders.make_train_div(model, entry.chunk)),
            ("train_plain", step_builders.make_train_plain(model)),
        ):
            key = f"{variant}_b{m}"
            path = mdir / f"{key}.hlo.txt"
            if force or not path.exists():
                entries[key] = lower_entry(fn, args, BATCH_INS, TRAIN_OUTS, path)
            else:
                entries[key] = _manifest_stub(fn, args, BATCH_INS, TRAIN_OUTS, path)
        key = f"eval_b{m}"
        path = mdir / f"{key}.hlo.txt"
        fn = step_builders.make_eval(model)
        if force or not path.exists():
            entries[key] = lower_entry(fn, args, BATCH_INS, EVAL_OUTS, path)
        else:
            entries[key] = _manifest_stub(fn, args, BATCH_INS, EVAL_OUTS, path)

    # Fused on-device update (one per model; batch-size independent).
    p = model.param_count
    upd_args = (
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    upd_ins = ["params", "velocity", "grad_sum", "scalars"]
    upd_outs = ["params_out", "velocity_out"]
    upd_fn = step_builders.make_update(model)
    upd_path = mdir / "update.hlo.txt"
    if force or not upd_path.exists():
        entries["update"] = lower_entry(upd_fn, upd_args, upd_ins, upd_outs, upd_path)
    else:
        entries["update"] = _manifest_stub(upd_fn, upd_args, upd_ins, upd_outs, upd_path)

    # Seeded initial parameter vectors (one per trial seed).
    init_files = []
    for seed in range(entry.n_init_seeds):
        f = mdir / f"init_s{seed}.bin"
        if force or not f.exists():
            flat = np.asarray(model.init(jax.random.PRNGKey(seed)), dtype="<f4")
            assert flat.shape == (model.param_count,)
            f.write_bytes(flat.tobytes())
        init_files.append(f"{name}/init_s{seed}.bin")

    print(f"  [{name}] {len(entries)} entries, P={model.param_count}, {time.time() - t0:.1f}s")
    return {
        "param_count": model.param_count,
        "input_shape": list(model.input_shape),
        "label_dtype": model.label_dtype,
        "num_classes": model.num_classes,
        "ladder": list(entry.ladder),
        "chunk": entry.chunk,
        "tags": list(entry.tags),
        "param_specs": [{"name": s.name, "shape": list(s.shape)} for s in model.specs],
        "init_params": init_files,
        "entries": entries,
    }


def _manifest_stub(fn, args, in_names, out_names, path: Path) -> dict:
    """Manifest record for an entry whose HLO file is already up to date."""
    out_avals = jax.eval_shape(fn, *args)
    if not isinstance(out_avals, tuple):
        out_avals = (out_avals,)
    return {
        "file": str(path.relative_to(path.parents[1])),
        "inputs": _io_spec(list(zip(in_names, args))),
        "outputs": _io_spec(list(zip(out_names, out_avals))),
        "hlo_bytes": path.stat().st_size,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact root")
    ap.add_argument("--models", default="", help="comma-separated subset (default: all)")
    ap.add_argument("--tiny", action="store_true", help="only the tiny test models")
    ap.add_argument("--force", action="store_true", help="regenerate even if files exist")
    args = ap.parse_args()

    out_dir = Path(args.out_dir).resolve()
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.models:
        names = [n.strip() for n in args.models.split(",") if n.strip()]
    elif args.tiny:
        names = [n for n, e in REGISTRY.items() if "tiny" in e.tags]
    else:
        names = list(REGISTRY)
    for n in names:
        if n not in REGISTRY:
            raise SystemExit(f"unknown model {n!r}; known: {sorted(REGISTRY)}")

    manifest_path = out_dir / "manifest.json"
    manifest = {"version": MANIFEST_VERSION, "models": {}}
    if manifest_path.exists():
        try:
            old = json.loads(manifest_path.read_text())
            if old.get("version") == MANIFEST_VERSION:
                manifest["models"].update(old.get("models", {}))
        except json.JSONDecodeError:
            pass

    t0 = time.time()
    for name in names:
        manifest["models"][name] = build_model_artifacts(name, REGISTRY[name], out_dir, args.force)
    manifest_path.write_text(json.dumps(manifest, indent=1, sort_keys=True))
    print(f"wrote {manifest_path} ({len(names)} models, {time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
