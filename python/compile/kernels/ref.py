"""Pure-jnp oracles for the Pallas kernels in :mod:`compile.kernels.persample`.

Every kernel has a reference implementation here with identical semantics;
``python/tests/test_kernels.py`` asserts allclose between the two across
hypothesis-generated shapes.  These are also the fallbacks used by the
kernel micro-benchmarks (P3 ablation) as the "naive" baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def row_sqnorm_ref(x: jax.Array) -> jax.Array:
    """``out[i] = ||x[i, :]||^2``."""
    return jnp.sum(x * x, axis=1)


def dense_sqnorm_ref(a: jax.Array, d: jax.Array, *, has_bias: bool = True) -> jax.Array:
    """Per-sample dense-layer grad sq-norm: ``(||a_i||^2 + bias) * ||d_i||^2``."""
    bias = 1.0 if has_bias else 0.0
    return (jnp.sum(a * a, axis=1) + bias) * jnp.sum(d * d, axis=1)


def diversity_reduce_ref(g: jax.Array, w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``(sum_i w_i ||g_i||^2, sum_i w_i g_i)`` over per-sample grads."""
    sq = jnp.sum(w * jnp.sum(g * g, axis=1))
    gsum = jnp.sum(w[:, None] * g, axis=0)
    return sq, gsum


def sgd_fused_ref(
    params: jax.Array,
    velocity: jax.Array,
    grad_sum: jax.Array,
    scalars: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Reference for the fused SGD(+momentum, +wd) update."""
    lr, mu, wd, inv_m = scalars[0], scalars[1], scalars[2], scalars[3]
    eff_g = grad_sum * inv_m + wd * params
    v = mu * velocity + eff_g
    return params - lr * v, v


def gradient_diversity_ref(g: jax.Array) -> jax.Array:
    """Definition 1: ``Delta_S = sum_i ||g_i||^2 / ||sum_i g_i||^2``.

    Used by model-level tests to sanity-check the quantities that the Rust
    coordinator assembles from the executable outputs.
    """
    num = jnp.sum(jnp.sum(g * g, axis=1))
    den = jnp.sum(jnp.sum(g, axis=0) ** 2)
    return num / den


def persample_grad_sqnorm_oracle(loss_fn, params: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Brute-force per-sample grad sq-norms via ``vmap(grad)``.

    ``loss_fn(params, xi, yi)`` must return the scalar per-sample loss.
    This is the ground truth the closed-form dense-trick kernels are
    validated against in the model tests.
    """

    def single(p, xi, yi):
        return loss_fn(p, xi, yi)

    grads = jax.vmap(jax.grad(single), in_axes=(None, 0, 0))(params, x, y)
    return jnp.sum(grads * grads, axis=1)
