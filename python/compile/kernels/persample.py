"""Layer-1 Pallas kernels for DiveBatch's per-sample gradient statistics.

These kernels implement the hot spot of the paper: accumulating, for every
mini-batch, the sum of per-sample squared gradient norms and the sum of
gradients (Definition 2).  The paper used BackPACK-for-PyTorch on A100s and
materialized per-sample gradients in HBM; on a TPU-shaped substrate we
avoid materializing per-sample weight gradients wherever a closed form
exists:

  For a dense layer ``y = x W + b`` with per-sample activation ``a_i`` and
  per-sample output-gradient ``d_i``::

      ||grad_W l_i||^2 = ||a_i||^2 * ||d_i||^2
      ||grad_b l_i||^2 = ||d_i||^2

  so the per-sample squared gradient norm of the layer is
  ``(||a_i||^2 + has_bias) * ||d_i||^2`` -- computed by streaming the
  activation and output-grad matrices through VMEM in (block_m, block_f)
  tiles.  This is O(m * (p + q)) memory traffic instead of the O(m * p * q)
  of materialized per-sample gradients.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin used
by the Rust runtime cannot execute Mosaic custom-calls, and interpret mode
lowers the kernels to plain HLO.  Block shapes still express the HBM<->VMEM
schedule that a real TPU lowering would use; see DESIGN.md section 6 and
EXPERIMENTS.md section Perf for the VMEM/roofline accounting.

Correctness oracles live in :mod:`compile.kernels.ref` and are enforced by
``python/tests/test_kernels.py`` (hypothesis sweeps over shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  block_m rides the sublane dimension, block_f the
# lane dimension; (128, 512) f32 tiles are 256 KiB -- small enough to
# double-buffer in a 16 MiB VMEM alongside the model's matmul tiles.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_F = 512
# Feature widths up to this bound use the single-pass fused dense kernel;
# wider layers fall back to the two-pass row_sqnorm composition.
FUSED_FEATURE_LIMIT = 2048
# Parameter-vector tile for the fused SGD update kernel.
DEFAULT_BLOCK_P = 8192

_INTERPRET = True  # CPU PJRT; see module docstring.


def _pad_to_multiple(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to a multiple of ``multiple``.

    Zero rows/columns are exact no-ops for every kernel in this module
    (all reductions are sums of products), so padding preserves results.
    """
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# row_sqnorm: out[i] = sum_j x[i, j]^2
# ---------------------------------------------------------------------------


def _row_sqnorm_kernel(x_ref, o_ref):
    """Accumulate squared row norms over feature blocks.

    Grid is (m_blocks, f_blocks) with the feature axis innermost; the
    output block for row-block ``i`` is revisited across ``j`` and
    accumulated in place (initialised at j == 0).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = x_ref[...]
    o_ref[...] += jnp.sum(blk * blk, axis=1)


def row_sqnorm(
    x: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_f: int = DEFAULT_BLOCK_F,
) -> jax.Array:
    """Per-row squared L2 norm of a 2-D array, tiled for VMEM.

    Args:
      x: ``(m, f)`` float array.
      block_m / block_f: VMEM tile shape.

    Returns:
      ``(m,)`` array with ``out[i] = ||x[i, :]||^2``.
    """
    m, f = x.shape
    bm = min(block_m, m)
    bf = min(block_f, f)
    xp = _pad_to_multiple(_pad_to_multiple(x, bm, 0), bf, 1)
    mp, fp = xp.shape
    out = pl.pallas_call(
        _row_sqnorm_kernel,
        grid=(mp // bm, fp // bf),
        in_specs=[pl.BlockSpec((bm, bf), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=_INTERPRET,
    )(xp)
    return out[:m]


# ---------------------------------------------------------------------------
# dense_sqnorm: per-sample grad sq-norm of a dense layer (fused single pass)
# ---------------------------------------------------------------------------


def _dense_sqnorm_kernel(a_ref, d_ref, o_ref, *, bias: float):
    a = a_ref[...]
    d = d_ref[...]
    a_nrm = jnp.sum(a * a, axis=1) + bias
    d_nrm = jnp.sum(d * d, axis=1)
    o_ref[...] = a_nrm * d_nrm


def dense_sqnorm(
    a: jax.Array,
    d: jax.Array,
    *,
    has_bias: bool = True,
    block_m: int = DEFAULT_BLOCK_M,
) -> jax.Array:
    """Per-sample squared gradient norm of a dense layer ``y = a W (+ b)``.

    Args:
      a: ``(m, p)`` layer-input activations.
      d: ``(m, q)`` gradients of the per-sample losses w.r.t. the layer
        outputs (NOT scaled by any batch weighting).
      has_bias: include the bias-gradient term ``||d_i||^2``.

    Returns:
      ``(m,)`` array: ``(||a_i||^2 + has_bias) * ||d_i||^2``.

    When either feature width exceeds ``FUSED_FEATURE_LIMIT`` the fused
    kernel's full-row tile would pressure VMEM, so we compose two
    feature-tiled :func:`row_sqnorm` passes instead (same numerics; see
    the P3 ablation bench for the crossover).
    """
    m, p = a.shape
    m2, q = d.shape
    assert m == m2, f"row mismatch {m} vs {m2}"
    bias = 1.0 if has_bias else 0.0
    if p > FUSED_FEATURE_LIMIT or q > FUSED_FEATURE_LIMIT:
        return (row_sqnorm(a) + bias) * row_sqnorm(d)
    bm = min(block_m, m)
    ap = _pad_to_multiple(a, bm, 0)
    dp = _pad_to_multiple(d, bm, 0)
    mp = ap.shape[0]
    out = pl.pallas_call(
        functools.partial(_dense_sqnorm_kernel, bias=bias),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, p), lambda i: (i, 0)),
            pl.BlockSpec((bm, q), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=_INTERPRET,
    )(ap, dp)
    return out[:m]


# ---------------------------------------------------------------------------
# diversity_reduce: (G, w) -> (sum_i w_i ||g_i||^2, sum_i w_i g_i)
# ---------------------------------------------------------------------------


def _diversity_reduce_kernel(g_ref, w_ref, sq_ref, gsum_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    g = g_ref[...]  # (bm, bp)
    w = w_ref[...]  # (bm,)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_sq():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    @pl.when(i == 0)
    def _init_gsum():
        gsum_ref[...] = jnp.zeros_like(gsum_ref)

    sq_ref[...] += jnp.sum(w * jnp.sum(g * g, axis=1))[None]
    gsum_ref[...] += jnp.sum(w[:, None] * g, axis=0)


def diversity_reduce(
    g: jax.Array,
    w: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_f: int = DEFAULT_BLOCK_F,
) -> tuple[jax.Array, jax.Array]:
    """One-pass Definition-2 reductions over a per-sample gradient matrix.

    Args:
      g: ``(m, P)`` per-sample (flat) gradients.
      w: ``(m,)`` per-sample weights (0 masks padding rows).

    Returns:
      ``(sqnorm_sum, grad_sum)`` where ``sqnorm_sum`` is the scalar
      ``sum_i w_i ||g_i||^2`` and ``grad_sum`` the ``(P,)`` vector
      ``sum_i w_i g_i``.  Both feed the epoch-level gradient-diversity
      accumulators on the Rust side.
    """
    m, p = g.shape
    bm = min(block_m, m)
    bp = min(block_f, p)
    gp = _pad_to_multiple(_pad_to_multiple(g, bm, 0), bp, 1)
    wp = _pad_to_multiple(w, bm, 0)
    mp, pp = gp.shape
    sq, gsum = pl.pallas_call(
        _diversity_reduce_kernel,
        grid=(mp // bm, pp // bp),
        in_specs=[
            pl.BlockSpec((bm, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bp,), lambda i, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((pp,), jnp.float32),
        ],
        interpret=_INTERPRET,
    )(gp, wp)
    return sq[0], gsum[:p]


# ---------------------------------------------------------------------------
# sgd_fused: fused SGD(+momentum, +weight-decay) parameter update
# ---------------------------------------------------------------------------


def _sgd_fused_kernel(p_ref, v_ref, g_ref, s_ref, po_ref, vo_ref):
    lr = s_ref[0]
    mu = s_ref[1]
    wd = s_ref[2]
    inv_m = s_ref[3]
    p = p_ref[...]
    eff_g = g_ref[...] * inv_m + wd * p
    v = mu * v_ref[...] + eff_g
    po_ref[...] = p - lr * v
    vo_ref[...] = v


def sgd_fused(
    params: jax.Array,
    velocity: jax.Array,
    grad_sum: jax.Array,
    scalars: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
) -> tuple[jax.Array, jax.Array]:
    """Fused SGD update over the flat parameter vector.

    Args:
      params / velocity / grad_sum: ``(P,)`` flat vectors.  ``grad_sum``
        is the SAMPLE-SUM gradient returned by the train-step executables.
      scalars: ``(4,)`` = ``[lr, momentum, weight_decay, 1/batch_size]``.

    Returns:
      ``(new_params, new_velocity)``.

    Update rule (matches ``coordinator/optimizer.rs`` on the Rust side,
    which is the reference implementation and the ablation baseline)::

        g   = grad_sum / m + wd * p
        v'  = mu * v + g
        p'  = p - lr * v'
    """
    (p,) = params.shape
    bp = min(block_p, p)
    pp_params = _pad_to_multiple(params, bp, 0)
    pp_vel = _pad_to_multiple(velocity, bp, 0)
    pp_grad = _pad_to_multiple(grad_sum, bp, 0)
    n = pp_params.shape[0]
    new_p, new_v = pl.pallas_call(
        _sgd_fused_kernel,
        grid=(n // bp,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=_INTERPRET,
    )(pp_params, pp_vel, pp_grad, scalars)
    return new_p[:p], new_v[:p]
