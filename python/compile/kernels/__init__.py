"""Layer-1 Pallas kernels (build-time only; lowered into the AOT modules)."""

from compile.kernels.persample import (  # noqa: F401
    dense_sqnorm,
    diversity_reduce,
    row_sqnorm,
    sgd_fused,
)
