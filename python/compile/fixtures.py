"""Emit the committed interpreter-backend test fixtures (rust/tests/fixtures).

The Rust numeric test suites run everywhere — no AOT build, no real XLA —
against a pure-Rust HLO interpreter (rust/vendor/xla, ``interp`` backend)
over a tiny set of **committed** artifacts for the paper's synthetic-convex
model (``tinylogreg8``).  This script generates those artifacts once, at
authoring time; the files it writes are checked in, so `cargo test` never
needs Python.

Five fixture models are emitted — the interpreter's "model zoo ladder":
``tinylogreg8`` (the (4, 8) ladder the trainer/golden-record suites pin),
``steplogreg8`` (a (8, 64) ladder whose 64-row rung feeds the sharded step
executor's speedup bench and ``--step-jobs`` equivalence tests with
multi-block plans), ``tinymlp8`` (the paper's nonconvex MLP with the
closed-form dense-trick sqnorm path), ``tinyresnet4`` (the CIFAR-like
conv net: its HLO exercises ``convolution`` forward/filter/input-grad
forms, the chunked vmap(grad) ``while`` loop with dynamic slices, and
``call``/``reverse`` — the ops the interpreter grew to run the real zoo),
and ``tinyresnet8`` (the mid-tier conv-dominated resnet — two stages,
16x16 images, (8, 16) channels — whose forward convs are big enough that
the interpreter's conv cost model picks the fused blocked kernel; the
``perf_conv`` bench and the CIFAR-like presets run on it).

Two outputs:

* ``rust/tests/fixtures/artifacts/`` — a regular artifact tree (same layout
  as ``python -m compile.aot``): ``manifest.json``, per-entry HLO text per
  model ladder, and seeded ``init_s<k>.bin`` parameter files.
* ``rust/tests/fixtures/golden_entry_outputs.json`` — for every model and
  entry, a deterministic set of inputs and the jax-evaluated outputs
  (``{"models": {<name>: {<entry>: {inputs, outputs}}}}``).  The Rust
  test ``integration_runtime::interpreter_matches_python_golden`` replays
  these through the interpreter, anchoring it to the Python reference
  (the same traced functions the HLO was lowered from); the bit-exact
  record mirror validates itself the same way (python/mirror/selfcheck.py).

The Pallas kernels are swapped for their pure-jnp references
(:mod:`compile.kernels.ref`, semantics enforced identical by
``python/tests/test_kernels.py``) BEFORE the step builders import them:
interpret-mode ``pallas_call`` lowers to while-loops + dynamic slices,
outside the interpreter's op subset, while the refs lower to plain
elementwise/dot/reduce HLO.

Usage (from ``python/``)::

    python -m compile.fixtures [--out-dir ../rust/tests/fixtures]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

import compile.kernels as kernels
from compile.kernels import ref as kernel_ref

# Patch before compile.model / compile.models bind the kernel names.
kernels.dense_sqnorm = lambda a, d, *, has_bias=True: kernel_ref.dense_sqnorm_ref(
    a, d, has_bias=has_bias
)
kernels.diversity_reduce = kernel_ref.diversity_reduce_ref
kernels.sgd_fused = kernel_ref.sgd_fused_ref
kernels.row_sqnorm = kernel_ref.row_sqnorm_ref

from compile import aot  # noqa: E402  (must import after the patch)
from compile import model as step_builders  # noqa: E402
from compile.models import REGISTRY  # noqa: E402

FIXTURE_MODELS = ("tinylogreg8", "steplogreg8", "tinymlp8", "tinyresnet4", "tinyresnet8")


def golden_inputs(model, m: int) -> tuple[np.ndarray, ...]:
    """Deterministic batch inputs (mirrors the Rust toy_dataset pattern).

    Shapes and label dtype come from the model; the d=8 logreg param
    vector is pinned to its historical literal so the committed logreg
    goldens stay bit-identical across regenerations.
    """
    p = model.param_count
    if p == 9:
        params = np.array(
            [0.3, -0.2, 0.05, 0.7, -0.4, 0.11, -0.09, 0.25, 0.02], dtype=np.float32
        )
    else:
        params = (np.sin(np.arange(p, dtype=np.float32) * 0.13) * 0.1).astype(np.float32)
    n = m * int(np.prod(model.input_shape))
    x = np.sin(np.arange(n, dtype=np.float32) * 0.37).reshape((m, *model.input_shape))
    if model.label_dtype == "s32":
        y = np.array([(i * 7) % model.num_classes for i in range(m)], dtype=np.int32)
    else:
        y = np.array([(i * 7) % 2 for i in range(m)], dtype=np.float32)
    # One padding row (w = 0) when m > 4 so the goldens pin the padding
    # no-op behaviour too.
    w = np.ones(m, dtype=np.float32)
    if m > 4:
        w[m - 1] = 0.0
    return params, x, y, w


def golden_update_inputs(p: int) -> tuple[np.ndarray, ...]:
    i = np.arange(p, dtype=np.float32)
    params = np.sin(i * 0.1).astype(np.float32)
    velocity = (np.cos(i * 0.05) * 0.01).astype(np.float32)
    grad_sum = np.cos(i * 0.2).astype(np.float32)
    scalars = np.array([0.1, 0.9, 5e-4, 1.0 / 64.0], dtype=np.float32)
    return params, velocity, grad_sum, scalars


def flat(a) -> list[float]:
    return [float(v) for v in np.asarray(a, dtype=np.float32).reshape(-1)]


def build_golden(model, entry) -> dict:
    """Evaluate every entry's step function on the deterministic inputs."""
    out: dict[str, dict] = {}
    for m in entry.ladder:
        args = tuple(jnp.asarray(a) for a in golden_inputs(model, m))
        for key, fn in (
            (f"train_div_b{m}", step_builders.make_train_div(model, entry.chunk)),
            (f"train_plain_b{m}", step_builders.make_train_plain(model)),
            (f"eval_b{m}", step_builders.make_eval(model)),
        ):
            res = jax.jit(fn)(*args)
            out[key] = {
                "inputs": [flat(a) for a in args],
                "outputs": [flat(r) for r in res],
            }
    upd_args = tuple(jnp.asarray(a) for a in golden_update_inputs(model.param_count))
    res = jax.jit(step_builders.make_update(model))(*upd_args)
    out["update"] = {
        "inputs": [flat(a) for a in upd_args],
        "outputs": [flat(r) for r in res],
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default="../rust/tests/fixtures",
        help="fixture root (artifacts/ + golden json go under it)",
    )
    args = ap.parse_args()

    fixture_root = Path(args.out_dir).resolve()
    artifacts = fixture_root / "artifacts"
    artifacts.mkdir(parents=True, exist_ok=True)

    sections = {}
    goldens = {}
    for name in FIXTURE_MODELS:
        entry = REGISTRY[name]
        model = entry.factory()
        sections[name] = aot.build_model_artifacts(name, entry, artifacts, force=True)
        goldens[name] = build_golden(model, entry)

    manifest = {"version": aot.MANIFEST_VERSION, "models": sections}
    (artifacts / "manifest.json").write_text(json.dumps(manifest, indent=1, sort_keys=True))

    golden_path = fixture_root / "golden_entry_outputs.json"
    golden_path.write_text(json.dumps({"models": goldens}, indent=1, sort_keys=True))
    print(f"wrote {artifacts}/manifest.json and {golden_path}")


if __name__ == "__main__":
    main()
