"""Layer-2 step builders: the functions that get AOT-lowered to HLO.

Every executable shares one interface over flat f32 tensors (DESIGN.md
section 2) so the Rust runtime can marshal uniformly:

    train_{div|plain}(params[P], x[m,...], y[m], w[m])
        -> (loss_sum[], correct[], grad_sum[P], sqnorm_sum[])
    eval(params[P], x[m,...], y[m], w[m]) -> (loss_sum[], correct[])
    update(params[P], velocity[P], grad_sum[P], scalars[4])
        -> (params'[P], velocity'[P])

Outputs are SAMPLE SUMS (not means): micro-batch accumulation on the Rust
side is plain addition, the optimizer divides by the logical batch size,
and Definition 2's epoch accumulators (sum of per-sample grad sq-norms;
sum of gradients) fall out of `sqnorm_sum` / `grad_sum` directly.

``w`` is a per-sample weight: 1 for real samples, 0 for the padding rows
the accumulation planner appends to fill the last micro-batch.  Every
output is weighted, so padded rows are exact no-ops.

The `div` (diversity-instrumented) variant computes per-sample gradient
squared norms:
  * models with a closed form (logreg / MLP dense-trick) call the L1
    ``dense_sqnorm`` Pallas kernel on top of the ordinary batched backward;
  * generic models (the CNN) use a `lax.map`-chunked ``vmap(grad)`` that
    produces grad_sum AND sqnorm_sum in one pass through the L1
    ``diversity_reduce`` kernel, with peak memory bounded by
    ``chunk * P`` (the knob behind the paper's Table 2 trade-off).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import diversity_reduce, sgd_fused
from compile.models.common import Model

StepFn = Callable[..., tuple]


def _loss_and_grad(model: Model, flat, x, y, w):
    """Weighted-sum loss, correct count and batched gradient."""

    def loss_fn(p):
        logits = model.apply(p, x)
        return jnp.sum(w * model.per_sample_loss(logits, y)), logits

    (loss, logits), grad = jax.value_and_grad(loss_fn, has_aux=True)(flat)
    corr = jnp.sum(w * model.correct(logits, y))
    return loss, corr, grad


def make_train_plain(model: Model) -> StepFn:
    """Uninstrumented train step (fixed-batch SGD / AdaBatch baselines).

    Returns sqnorm_sum = 0 to keep the output arity uniform with `div`.
    """

    def step(flat, x, y, w):
        loss, corr, grad = _loss_and_grad(model, flat, x, y, w)
        return loss, corr, grad, jnp.zeros((), jnp.float32)

    return step


def make_train_div(model: Model, chunk: int) -> StepFn:
    """Diversity-instrumented train step."""
    if model.persample_sqnorm is not None:
        # Closed-form path: ordinary batched backward + dense-trick kernel.
        def step(flat, x, y, w):
            loss, corr, grad = _loss_and_grad(model, flat, x, y, w)
            sq = model.persample_sqnorm(flat, x, y)  # (m,), unweighted
            return loss, corr, grad, jnp.sum(w * sq)

        return step

    # Generic path: chunked per-sample gradients.  The weighted sum of
    # per-sample grads IS the batched gradient, so one chunked pass yields
    # both outputs; no second backward.
    def step(flat, x, y, w):
        logits = model.apply(flat, x)
        loss = jnp.sum(w * model.per_sample_loss(logits, y))
        corr = jnp.sum(w * model.correct(logits, y))
        m = x.shape[0]
        c = min(chunk, m)
        assert m % c == 0, f"batch {m} not a multiple of chunk {c}"
        xs = x.reshape(m // c, c, *x.shape[1:])
        ys = y.reshape(m // c, c)
        ws = w.reshape(m // c, c)

        grad_single = jax.grad(model.single_loss)
        grad_chunk = jax.vmap(grad_single, in_axes=(None, 0, 0))

        def one_chunk(args):
            xc, yc, wc = args
            g = grad_chunk(flat, xc, yc)  # (c, P) materialized per chunk only
            return diversity_reduce(g, wc)  # L1 kernel: (scalar, (P,))

        sqs, gsums = jax.lax.map(one_chunk, (xs, ys, ws))
        return loss, corr, jnp.sum(gsums, axis=0), jnp.sum(sqs)

    return step


def make_eval(model: Model) -> StepFn:
    """Validation step: weighted loss sum + correct count."""

    def step(flat, x, y, w):
        logits = model.apply(flat, x)
        loss = jnp.sum(w * model.per_sample_loss(logits, y))
        corr = jnp.sum(w * model.correct(logits, y))
        return loss, corr

    return step


def make_update(model: Model) -> StepFn:  # noqa: ARG001 (uniform signature)
    """Fused on-device SGD update (L1 ``sgd_fused`` kernel).

    scalars = [lr, momentum, weight_decay, 1/batch_size].  The Rust-side
    scalar optimizer in coordinator/optimizer.rs is the reference; this
    executable is the ablation alternative (P2 bench).
    """

    def step(params, velocity, grad_sum, scalars):
        return sgd_fused(params, velocity, grad_sum, scalars)

    return step


def example_batch(model: Model, m: int) -> tuple[jax.ShapeDtypeStruct, ...]:
    """ShapeDtypeStructs for lowering a batch-``m`` train/eval entry."""
    p = jax.ShapeDtypeStruct((model.param_count,), jnp.float32)
    x = jax.ShapeDtypeStruct((m, *model.input_shape), jnp.float32)
    ydt = jnp.int32 if model.label_dtype == "s32" else jnp.float32
    y = jax.ShapeDtypeStruct((m,), ydt)
    w = jax.ShapeDtypeStruct((m,), jnp.float32)
    return p, x, y, w
