//! Run records: per-epoch metrics, CSV/JSONL serialization, and the
//! summary accessors the paper's tables are computed from (accuracy at
//! 25/50/75/100% of training; time to within ±1% of final accuracy).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Everything recorded at one epoch boundary.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Logical batch size used during this epoch.
    pub batch_size: usize,
    pub lr: f64,
    /// Optimizer steps taken this epoch (= ceil(n/m)).
    pub steps: usize,
    /// Mean per-sample training loss / accuracy over the epoch.
    pub train_loss: f64,
    pub train_acc: f64,
    /// Validation metrics at the epoch boundary.
    pub val_loss: f64,
    pub val_acc: f64,
    /// Definition-2 estimate observed during the epoch (div policies).
    pub delta_hat: Option<f64>,
    /// `n * Delta_hat` (the Algorithm-1 line-11 quantity).
    pub n_delta: Option<f64>,
    /// Exact full-dataset diversity (Oracle policy only).
    pub exact_delta: Option<f64>,
    /// Real wall-clock seconds spent in this epoch (this testbed).
    pub wall_s: f64,
    /// Simulated cluster seconds (DESIGN.md §3 timing model).
    pub sim_s: f64,
    pub cum_wall_s: f64,
    pub cum_sim_s: f64,
    /// Analytic peak training memory at this epoch's batch size (MB).
    pub mem_mb: f64,
    /// Executable dispatches across this epoch's training steps (the
    /// micro-plan block count — the fixed-cost driver the planner
    /// minimizes).  Plan-derived, so jobs-invariant.
    pub dispatches: usize,
    /// Fraction of executed training rows that were padding
    /// (`1 - covered/padded` over the epoch's plans; 0 = perfect fit).
    pub pad_waste: f64,
    /// Mean step-executor dispatch utilization of this epoch's plans at
    /// the run's `--step-jobs` lane count (1.0 when serial).  Depends on
    /// the lane count, so it is masked in the canonical JSON.
    pub par_util: f64,
}

/// One complete training run (one trial).
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Paper-style label, e.g. "DiveBatch (128 - 2048)".
    pub label: String,
    pub model: String,
    pub policy_kind: String,
    pub dataset: String,
    pub seed: u64,
    pub epochs: Vec<EpochRecord>,
}

pub const CSV_HEADER: &str = "epoch,batch_size,lr,steps,train_loss,train_acc,val_loss,val_acc,\
delta_hat,n_delta,exact_delta,wall_s,sim_s,cum_wall_s,cum_sim_s,mem_mb,dispatches,pad_waste,\
par_util";

fn opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.6e}")).unwrap_or_default()
}

impl RunRecord {
    pub fn new(label: &str, model: &str, policy_kind: &str, dataset: &str, seed: u64) -> Self {
        RunRecord {
            label: label.to_string(),
            model: model.to_string(),
            policy_kind: policy_kind.to_string(),
            dataset: dataset.to_string(),
            seed,
            epochs: Vec::new(),
        }
    }

    // ------------------------------------------------------------- series

    pub fn val_acc_curve(&self) -> Vec<f64> {
        self.epochs.iter().map(|e| e.val_acc).collect()
    }

    pub fn val_loss_curve(&self) -> Vec<f64> {
        self.epochs.iter().map(|e| e.val_loss).collect()
    }

    pub fn batch_size_curve(&self) -> Vec<f64> {
        self.epochs.iter().map(|e| e.batch_size as f64).collect()
    }

    pub fn delta_hat_curve(&self) -> Vec<f64> {
        self.epochs
            .iter()
            .map(|e| e.delta_hat.unwrap_or(f64::NAN))
            .collect()
    }

    pub fn exact_delta_curve(&self) -> Vec<f64> {
        self.epochs
            .iter()
            .map(|e| e.exact_delta.unwrap_or(f64::NAN))
            .collect()
    }

    // ------------------------------------------------------------ summary

    pub fn final_val_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.val_acc).unwrap_or(f64::NAN)
    }

    /// Validation accuracy at `frac` (0..=1) of total training epochs —
    /// the paper's 25% / 50% / 75% / 100% columns.
    pub fn val_acc_at_frac(&self, frac: f64) -> f64 {
        if self.epochs.is_empty() {
            return f64::NAN;
        }
        let idx = ((self.epochs.len() as f64 * frac).ceil() as usize)
            .clamp(1, self.epochs.len())
            - 1;
        self.epochs[idx].val_acc
    }

    /// First epoch whose val acc is within `tol_pct` percentage points of
    /// the final accuracy AND stays within for the rest of the run
    /// (the paper's "time to ±1% of final accuracy" criterion).
    pub fn epoch_within_final(&self, tol_pct: f64) -> Option<usize> {
        let final_acc = self.final_val_acc();
        if final_acc.is_nan() {
            return None;
        }
        let ok = |e: &EpochRecord| (e.val_acc - final_acc).abs() <= tol_pct;
        // Find the earliest epoch from which every later epoch stays within.
        let mut candidate = None;
        for (i, e) in self.epochs.iter().enumerate() {
            if ok(e) {
                if candidate.is_none() {
                    candidate = Some(i);
                }
            } else {
                candidate = None;
            }
        }
        candidate
    }

    /// Cumulative (simulated cluster | wall) seconds at the
    /// `epoch_within_final` point.
    pub fn time_within_final(&self, tol_pct: f64, simulated: bool) -> Option<f64> {
        self.epoch_within_final(tol_pct).map(|i| {
            let e = &self.epochs[i];
            if simulated {
                e.cum_sim_s
            } else {
                e.cum_wall_s
            }
        })
    }

    pub fn peak_mem_mb(&self) -> f64 {
        self.epochs.iter().map(|e| e.mem_mb).fold(0.0, f64::max)
    }

    pub fn mean_mem_mb(&self) -> f64 {
        if self.epochs.is_empty() {
            return 0.0;
        }
        self.epochs.iter().map(|e| e.mem_mb).sum::<f64>() / self.epochs.len() as f64
    }

    /// Final (maximum) batch size the policy reached — the paper reports
    /// "initial - end" batch ranges.
    pub fn end_batch_size(&self) -> usize {
        self.epochs.iter().map(|e| e.batch_size).max().unwrap_or(0)
    }

    /// Total executable dispatches across the run's training steps.
    pub fn total_dispatches(&self) -> usize {
        self.epochs.iter().map(|e| e.dispatches).sum()
    }

    /// Mean per-epoch padding-waste fraction (0 = every plan fit its
    /// rungs exactly).
    pub fn mean_pad_waste(&self) -> f64 {
        if self.epochs.is_empty() {
            return 0.0;
        }
        self.epochs.iter().map(|e| e.pad_waste).sum::<f64>() / self.epochs.len() as f64
    }

    /// Mean per-epoch step-dispatch utilization at the run's lane count
    /// (1.0 for serial runs).
    pub fn mean_par_util(&self) -> f64 {
        if self.epochs.is_empty() {
            return 1.0;
        }
        self.epochs.iter().map(|e| e.par_util).sum::<f64>() / self.epochs.len() as f64
    }

    // -------------------------------------------------------------- io

    pub fn to_csv(&self) -> String {
        let mut out = String::from(CSV_HEADER);
        out.push('\n');
        for e in &self.epochs {
            out.push_str(&format!(
                "{},{},{:.6e},{},{:.6},{:.6},{:.6},{:.6},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.2},{},{:.4},{:.4}\n",
                e.epoch,
                e.batch_size,
                e.lr,
                e.steps,
                e.train_loss,
                e.train_acc,
                e.val_loss,
                e.val_acc,
                opt(e.delta_hat),
                opt(e.n_delta),
                opt(e.exact_delta),
                e.wall_s,
                e.sim_s,
                e.cum_wall_s,
                e.cum_sim_s,
                e.mem_mb,
                e.dispatches,
                e.pad_waste,
                e.par_util,
            ));
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv()).with_context(|| format!("writing {path:?}"))
    }

    /// Full-fidelity JSON (used by the results cache so benches sharing
    /// experiment arms — e.g. Figures 3/4 and Table 1 — reuse runs).
    pub fn to_json(&self) -> Json {
        let num = |v: f64| Json::Num(v);
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("model", Json::Str(self.model.clone())),
            ("policy", Json::Str(self.policy_kind.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("seed", num(self.seed as f64)),
            (
                "epochs",
                Json::Arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("epoch", num(e.epoch as f64)),
                                ("m", num(e.batch_size as f64)),
                                ("lr", num(e.lr)),
                                ("steps", num(e.steps as f64)),
                                ("tl", num(e.train_loss)),
                                ("ta", num(e.train_acc)),
                                ("vl", num(e.val_loss)),
                                ("va", num(e.val_acc)),
                                ("dh", opt_num(e.delta_hat)),
                                ("nd", opt_num(e.n_delta)),
                                ("xd", opt_num(e.exact_delta)),
                                ("ws", num(e.wall_s)),
                                ("ss", num(e.sim_s)),
                                ("cw", num(e.cum_wall_s)),
                                ("cs", num(e.cum_sim_s)),
                                ("mm", num(e.mem_mb)),
                                ("dp", num(e.dispatches as f64)),
                                ("pw", num(e.pad_waste)),
                                ("pu", num(e.par_util)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Determinism-comparable JSON: identical across reruns and across
    /// trial-engine `--jobs` AND step-executor `--step-jobs` levels.
    /// Wall-clock fields (`ws`, `cw`) are zeroed because they measure
    /// this testbed's real elapsed time — which varies run to run and
    /// under CPU contention — and the dispatch-utilization field (`pu`)
    /// is zeroed because it is a function of the step-executor lane
    /// count, not of the run's outcome; every other field (including
    /// `dp`/`pw`, which derive from the plans alone) is
    /// bit-deterministic given the spec.  The serial-vs-parallel
    /// equivalence tests compare these strings byte for byte.
    pub fn to_canonical_json(&self) -> Json {
        let mut canon = self.clone();
        for e in &mut canon.epochs {
            e.wall_s = 0.0;
            e.cum_wall_s = 0.0;
            e.par_util = 0.0;
        }
        canon.to_json()
    }

    /// Inverse of [`to_json`].
    pub fn from_json(j: &Json) -> Result<RunRecord> {
        let get_f = |e: &Json, k: &str| -> Result<f64> {
            e.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("field {k} not a number"))
        };
        let get_opt = |e: &Json, k: &str| -> Option<f64> { e.get(k).and_then(|v| v.as_f64()) };
        let mut rec = RunRecord::new(
            j.req_str("label")?,
            j.req_str("model")?,
            j.req_str("policy")?,
            j.req_str("dataset")?,
            j.req_usize("seed")? as u64,
        );
        for e in j.req_arr("epochs")? {
            rec.epochs.push(EpochRecord {
                epoch: e.req_usize("epoch")?,
                batch_size: e.req_usize("m")?,
                lr: get_f(e, "lr")?,
                steps: e.req_usize("steps")?,
                train_loss: get_f(e, "tl")?,
                train_acc: get_f(e, "ta")?,
                val_loss: get_f(e, "vl")?,
                val_acc: get_f(e, "va")?,
                delta_hat: get_opt(e, "dh"),
                n_delta: get_opt(e, "nd"),
                exact_delta: get_opt(e, "xd"),
                wall_s: get_f(e, "ws")?,
                sim_s: get_f(e, "ss")?,
                cum_wall_s: get_f(e, "cw")?,
                cum_sim_s: get_f(e, "cs")?,
                mem_mb: get_f(e, "mm")?,
                // Dispatch fields default when absent so result caches
                // written before they existed keep loading.
                dispatches: e.get("dp").and_then(|v| v.as_usize()).unwrap_or(0),
                pad_waste: get_opt(e, "pw").unwrap_or(0.0),
                par_util: get_opt(e, "pu").unwrap_or(1.0),
            });
        }
        Ok(rec)
    }

    /// One-line JSON summary (JSONL sink for sweep aggregation).
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("model", Json::Str(self.model.clone())),
            ("policy", Json::Str(self.policy_kind.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("epochs", Json::Num(self.epochs.len() as f64)),
            ("final_val_acc", Json::Num(self.final_val_acc())),
            (
                "end_batch_size",
                Json::Num(self.end_batch_size() as f64),
            ),
            (
                "cum_wall_s",
                Json::Num(self.epochs.last().map(|e| e.cum_wall_s).unwrap_or(0.0)),
            ),
            (
                "cum_sim_s",
                Json::Num(self.epochs.last().map(|e| e.cum_sim_s).unwrap_or(0.0)),
            ),
            ("peak_mem_mb", Json::Num(self.peak_mem_mb())),
            ("dispatches", Json::Num(self.total_dispatches() as f64)),
            ("mean_pad_waste", Json::Num(self.mean_pad_waste())),
            ("mean_par_util", Json::Num(self.mean_par_util())),
        ])
    }

    pub fn append_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {path:?}"))?;
        writeln!(f, "{}", self.summary_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, val_acc: f64, m: usize) -> EpochRecord {
        EpochRecord {
            epoch,
            batch_size: m,
            lr: 0.1,
            steps: 10,
            train_loss: 1.0,
            train_acc: 0.5,
            val_loss: 1.0,
            val_acc,
            delta_hat: Some(2.0),
            n_delta: Some(100.0),
            exact_delta: None,
            wall_s: 1.0,
            sim_s: 0.5,
            cum_wall_s: (epoch + 1) as f64,
            cum_sim_s: 0.5 * (epoch + 1) as f64,
            mem_mb: 10.0 + m as f64,
            dispatches: 4 * (epoch + 1),
            pad_waste: 0.125,
            par_util: 0.75,
        }
    }

    fn run_with_accs(accs: &[f64]) -> RunRecord {
        let mut r = RunRecord::new("t", "m", "sgd", "d", 0);
        for (i, &a) in accs.iter().enumerate() {
            r.epochs.push(rec(i, a, 128 * (i + 1)));
        }
        r
    }

    #[test]
    fn acc_at_fractions() {
        let r = run_with_accs(&[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(r.val_acc_at_frac(0.25), 10.0);
        assert_eq!(r.val_acc_at_frac(0.5), 20.0);
        assert_eq!(r.val_acc_at_frac(0.75), 30.0);
        assert_eq!(r.val_acc_at_frac(1.0), 40.0);
        assert_eq!(r.final_val_acc(), 40.0);
    }

    #[test]
    fn epoch_within_final_requires_staying_within() {
        // Dips back out at epoch 3, so the answer is 4 not 1.
        let r = run_with_accs(&[50.0, 89.5, 89.8, 80.0, 89.9, 90.0]);
        assert_eq!(r.epoch_within_final(1.0), Some(4));
        assert_eq!(r.time_within_final(1.0, false), Some(5.0));
        assert_eq!(r.time_within_final(1.0, true), Some(2.5));
    }

    #[test]
    fn epoch_within_final_monotone_run() {
        let r = run_with_accs(&[50.0, 70.0, 89.2, 89.8, 90.0]);
        assert_eq!(r.epoch_within_final(1.0), Some(2));
    }

    #[test]
    fn csv_round_numbers() {
        let r = run_with_accs(&[1.0, 2.0]);
        let csv = r.to_csv();
        assert!(csv.starts_with(CSV_HEADER));
        assert_eq!(csv.lines().count(), 3);
        // Optional exact_delta empty.
        assert!(csv.lines().nth(1).unwrap().contains(",,"));
    }

    #[test]
    fn summary_json_fields() {
        let r = run_with_accs(&[1.0, 2.0, 3.0]);
        let j = r.summary_json().to_string();
        assert!(j.contains("\"final_val_acc\":3"));
        assert!(j.contains("\"end_batch_size\":384"));
        assert!(j.contains("\"epochs\":3"));
        // Dispatch accounting flows into the sweep JSONL summary.
        assert!(j.contains("\"dispatches\":24"), "{j}"); // 4 + 8 + 12
        assert!(j.contains("\"mean_pad_waste\":0.125"), "{j}");
        assert!(j.contains("\"mean_par_util\":0.75"), "{j}");
    }

    #[test]
    fn dispatch_summaries() {
        let r = run_with_accs(&[1.0, 2.0]);
        assert_eq!(r.total_dispatches(), 12);
        assert!((r.mean_pad_waste() - 0.125).abs() < 1e-12);
        assert!((r.mean_par_util() - 0.75).abs() < 1e-12);
        let empty = RunRecord::new("t", "m", "sgd", "d", 0);
        assert_eq!(empty.total_dispatches(), 0);
        assert_eq!(empty.mean_pad_waste(), 0.0);
        assert_eq!(empty.mean_par_util(), 1.0);
    }

    #[test]
    fn mem_summaries() {
        let r = run_with_accs(&[1.0, 2.0]);
        assert_eq!(r.peak_mem_mb(), 10.0 + 256.0);
        assert!((r.mean_mem_mb() - (138.0 + 266.0) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_full_fidelity() {
        let mut r = run_with_accs(&[10.0, 20.0]);
        r.epochs[1].exact_delta = Some(3.5);
        let j = r.to_json();
        let back = RunRecord::from_json(&crate::util::json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.label, r.label);
        assert_eq!(back.seed, r.seed);
        assert_eq!(back.epochs.len(), 2);
        assert_eq!(back.epochs[0].batch_size, r.epochs[0].batch_size);
        assert_eq!(back.epochs[0].val_acc, r.epochs[0].val_acc);
        assert_eq!(back.epochs[0].delta_hat, Some(2.0));
        assert_eq!(back.epochs[0].exact_delta, None);
        assert_eq!(back.epochs[1].exact_delta, Some(3.5));
        assert_eq!(back.epochs[1].cum_sim_s, r.epochs[1].cum_sim_s);
        assert_eq!(back.epochs[1].dispatches, 8);
        assert_eq!(back.epochs[1].pad_waste, 0.125);
        assert_eq!(back.epochs[1].par_util, 0.75);
    }

    #[test]
    fn from_json_defaults_dispatch_fields_for_old_caches() {
        // A record serialized before dp/pw/pu existed must still load.
        let r = run_with_accs(&[5.0]);
        let mut j = r.to_json().to_string();
        for k in ["\"dp\":4,", "\"pw\":0.125,", "\"pu\":0.75,"] {
            j = j.replace(k, "");
        }
        let back = RunRecord::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.epochs[0].dispatches, 0);
        assert_eq!(back.epochs[0].pad_waste, 0.0);
        assert_eq!(back.epochs[0].par_util, 1.0);
    }

    #[test]
    fn canonical_json_masks_wall_clock_and_lane_utilization_only() {
        let mut a = run_with_accs(&[10.0, 20.0]);
        let mut b = run_with_accs(&[10.0, 20.0]);
        // Same outcome, different testbed timing and step-lane count.
        a.epochs[0].wall_s = 1.25;
        a.epochs[0].cum_wall_s = 1.25;
        a.epochs[0].par_util = 1.0;
        b.epochs[0].wall_s = 9.75;
        b.epochs[0].cum_wall_s = 9.75;
        b.epochs[0].par_util = 0.5;
        assert_ne!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(
            a.to_canonical_json().to_string(),
            b.to_canonical_json().to_string()
        );
        // Outcome changes still show through — including the
        // plan-derived dispatch fields, which are NOT masked.
        let mut c = run_with_accs(&[10.0, 20.0]);
        c.epochs[1].dispatches += 1;
        assert_ne!(
            a.to_canonical_json().to_string(),
            c.to_canonical_json().to_string()
        );
        b.epochs[1].val_acc += 1.0;
        assert_ne!(
            a.to_canonical_json().to_string(),
            b.to_canonical_json().to_string()
        );
    }

    #[test]
    fn empty_run_is_safe() {
        let r = RunRecord::new("t", "m", "sgd", "d", 0);
        assert!(r.final_val_acc().is_nan());
        assert_eq!(r.epoch_within_final(1.0), None);
        assert_eq!(r.end_batch_size(), 0);
    }
}
