//! Analytic training-memory model (Table 2) + process RSS measurement.
//!
//! The paper's Table 2 compares peak GPU memory: per-sample gradient
//! extraction (BackPACK) makes DiveBatch the most memory-hungry method.
//! We model peak training memory analytically from the manifest's
//! parameter layout and the model's activation profile, in three modes:
//!
//! * `Plain`        — fwd/bwd activations + params + grads + optimizer
//! * `DivNaive`     — plus `m x P` materialized per-sample gradients
//!                    (what BackPACK/the paper did — Table 2's regime)
//! * `DivChunked`   — plus only `chunk x P` (this repo's L2 design)
//!
//! RSS deltas of the actual process are reported alongside (the CPU
//! allocator and XLA arena make them noisier, but the ordering holds).

/// Peak-memory estimation modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemMode {
    Plain,
    DivNaive,
    DivChunked,
}

/// Per-model activation/memory profile.
#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub param_count: usize,
    /// Input features per sample.
    pub feat_len: usize,
    /// Forward activation floats stored per sample for backward
    /// (estimated from the architecture; see `for_model`).
    pub act_per_sample: usize,
    /// Chunk size of the chunked per-sample path.
    pub chunk: usize,
}

impl MemoryModel {
    /// Build from manifest facts.  Activation profile heuristics:
    /// dense nets store roughly `feat + hidden` floats per sample (~2x
    /// feat); conv nets store every feature map — for resnet_tiny that is
    /// stem + 2 convs/block * blocks + transitions ~ 10 maps of up to
    /// 16x16x16..32 = about 40 x feat_len.
    pub fn for_model(
        param_count: usize,
        feat_len: usize,
        input_rank: usize,
        chunk: usize,
    ) -> MemoryModel {
        let act_per_sample = if input_rank >= 3 {
            40 * feat_len // conv pyramid
        } else {
            2 * feat_len + 64 // dense: input + hidden
        };
        MemoryModel {
            param_count,
            feat_len,
            act_per_sample,
            chunk,
        }
    }

    /// Peak bytes for one training step at logical batch `m`.
    pub fn step_bytes(&self, m: usize, mode: MemMode) -> f64 {
        let f = 4.0; // f32
        let p = self.param_count as f64;
        // params + grad accum + optimizer velocity + update scratch.
        let fixed = 4.0 * p * f;
        // batch tensors + stored activations for backward.
        let batch = m as f64 * (self.feat_len as f64 + self.act_per_sample as f64) * f;
        let persample = match mode {
            MemMode::Plain => 0.0,
            MemMode::DivNaive => m as f64 * p * f,
            MemMode::DivChunked => self.chunk.min(m) as f64 * p * f,
        };
        fixed + batch + persample
    }

    pub fn step_mb(&self, m: usize, mode: MemMode) -> f64 {
        self.step_bytes(m, mode) / (1024.0 * 1024.0)
    }
}

/// Current process resident-set size in MB (Linux /proc/self/status).
pub fn rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .ok()?;
            return Some(kb / 1024.0);
        }
    }
    None
}

/// Peak process RSS in MB (VmHWM).
pub fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .ok()?;
            return Some(kb / 1024.0);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resnet20_like() -> MemoryModel {
        // ResNet-20 on CIFAR-10: 272k params, 32*32*3 input.
        MemoryModel::for_model(272_000, 3072, 3, 32)
    }

    #[test]
    fn ordering_matches_paper_table2() {
        // Paper: SGD(128) < AdaBatch(avg) < SGD(2048) < DiveBatch(naive).
        let mm = resnet20_like();
        let sgd128 = mm.step_bytes(128, MemMode::Plain);
        let sgd2048 = mm.step_bytes(2048, MemMode::Plain);
        let dive2048 = mm.step_bytes(2048, MemMode::DivNaive);
        assert!(sgd128 < sgd2048);
        assert!(sgd2048 < dive2048);
        // DiveBatch naive at max batch dominates everything by a wide
        // margin (paper: 13.2 GB vs 9.5 GB).
        assert!(dive2048 / sgd2048 > 1.3);
    }

    #[test]
    fn chunking_removes_batch_dependence_of_persample_term() {
        let mm = resnet20_like();
        let plain = mm.step_bytes(2048, MemMode::Plain);
        let naive = mm.step_bytes(2048, MemMode::DivNaive);
        let chunked = mm.step_bytes(2048, MemMode::DivChunked);
        // The per-sample-gradient term shrinks by m/chunk = 64x.
        assert!(
            (chunked - plain) < (naive - plain) / 10.0,
            "{chunked} vs {naive}"
        );
        // Chunked at 2048 ~ chunked at 4096 for the per-sample part.
        let c1 = mm.step_bytes(2048, MemMode::DivChunked) - mm.step_bytes(2048, MemMode::Plain);
        let c2 = mm.step_bytes(4096, MemMode::DivChunked) - mm.step_bytes(4096, MemMode::Plain);
        assert!((c1 - c2).abs() < 1.0);
    }

    #[test]
    fn dense_profile_is_lighter_than_conv() {
        let dense = MemoryModel::for_model(513, 512, 1, 64);
        let conv = MemoryModel::for_model(513, 512, 3, 64);
        assert!(dense.step_bytes(128, MemMode::Plain) < conv.step_bytes(128, MemMode::Plain));
    }

    #[test]
    fn rss_readable_on_linux() {
        let rss = rss_mb();
        assert!(rss.is_some());
        assert!(rss.unwrap() > 1.0);
        let peak = peak_rss_mb().unwrap();
        assert!(peak >= rss_mb().unwrap() * 0.5);
    }

    #[test]
    fn mb_conversion() {
        let mm = MemoryModel {
            param_count: 0,
            feat_len: 0,
            act_per_sample: 0,
            chunk: 1,
        };
        assert_eq!(mm.step_mb(1, MemMode::Plain), 0.0);
    }
}
