//! Metrics: per-epoch run records (CSV/JSONL) + the analytic memory model
//! behind the Table 2 reproduction.

pub mod memory;
pub mod records;

pub use memory::{peak_rss_mb, rss_mb, MemMode, MemoryModel};
pub use records::{EpochRecord, RunRecord, CSV_HEADER};
