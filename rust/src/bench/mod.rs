//! In-tree micro-benchmark harness (criterion is not vendored).
//!
//! Provides warmup + timed iterations with mean/median/stddev/min and
//! throughput reporting for the `perf_*` benches, plus a tiny runner for
//! "experiment benches" (the figure/table reproductions) that mostly care
//! about printing paper-style outputs rather than ns-level timing.
//!
//! The `perf_*` speedup gates compare **medians** (`median_s`), not
//! means: a single scheduler hiccup in a 20-iteration run can move the
//! mean by double digits but leaves the median untouched, and the CI
//! perf-smoke runs on shared runners where that matters.

pub mod report;

pub use report::{run_experiment, run_experiment_jobs, ArmResult, ExperimentResult};

use crate::util::stats::Running;
use crate::util::timer::Timer;

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    /// Median of the per-iteration samples (midpoint average for even N).
    /// Use this for speedup ratios — it is robust to scheduler outliers.
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|n| n / self.mean_s)
    }

    pub fn line(&self) -> String {
        let tp = self
            .throughput()
            .map(|t| format!("  ({t:.0} items/s)"))
            .unwrap_or_default();
        format!(
            "{:<40} {:>12}  med {:>10}  ± {:>10}  min {:>10}  x{}{}",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.iters,
            tp
        )
    }
}

/// Human time formatting (s / ms / us / ns).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub max_iters: u64,
    /// Target measured time (seconds) before stopping.
    pub target_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            target_s: 2.0,
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 100,
            target_s: 0.5,
        }
    }

    /// Time `f` repeatedly; `items` is the per-iteration workload size
    /// for throughput reporting (e.g. samples processed).
    pub fn run<F: FnMut()>(&self, name: &str, items: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut stats = Running::new();
        let mut samples = Vec::new();
        let total = Timer::start();
        let mut iters = 0u64;
        while iters < self.min_iters
            || (total.seconds() < self.target_s && iters < self.max_iters)
        {
            let t = Timer::start();
            f();
            let s = t.seconds();
            stats.push(s);
            samples.push(s);
            iters += 1;
        }
        BenchResult {
            name: name.to_string(),
            iters,
            mean_s: stats.mean(),
            median_s: median(&mut samples),
            std_s: stats.std(),
            min_s: stats.min(),
            items,
        }
    }
}

/// Median of a sample set (sorts in place; midpoint average for even N).
fn median(samples: &mut [f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    if n % 2 == 1 {
        samples[n / 2]
    } else {
        (samples[n / 2 - 1] + samples[n / 2]) / 2.0
    }
}

/// Standard header printed by every bench binary.
pub fn bench_header(bench: &str, description: &str) {
    println!("=== divebatch bench: {bench} ===");
    println!("{description}");
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_minimum_iterations() {
        let b = Bencher {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            target_s: 0.0,
        };
        let mut count = 0;
        let r = b.run("noop", None, || count += 1);
        assert!(r.iters >= 5);
        assert_eq!(count as u64, r.iters + 1); // + warmup
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s);
        assert!(r.min_s <= r.median_s);
    }

    #[test]
    fn median_is_robust_to_outliers() {
        assert_eq!(median(&mut []), 0.0);
        assert_eq!(median(&mut [3.0]), 3.0);
        assert_eq!(median(&mut [1.0, 2.0, 1000.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
        // A single huge outlier moves the mean but not the median.
        let mut v = [1.0, 1.0, 1.0, 1.0, 500.0];
        assert_eq!(median(&mut v), 1.0);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 7,
            target_s: 100.0,
        };
        let r = b.run("noop", None, || {});
        assert!(r.iters <= 7);
    }

    #[test]
    fn throughput_computed() {
        let b = Bencher::quick();
        let r = b.run("sleepy", Some(1000.0), || {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1000.0 / 50e-6);
        assert!(r.line().contains("items/s"));
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(3e-3), "3.000 ms");
        assert_eq!(fmt_time(4e-6), "4.000 us");
        assert!(fmt_time(5e-9).contains("ns"));
    }
}
