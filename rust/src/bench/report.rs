//! Shared experiment-report machinery for the figure/table benches:
//! runs an [`Experiment`]'s arms, aggregates trials, and prints the
//! paper-style outputs (accuracy/loss figures, Table 1 rows, speedup
//! factors).  Keeping it in the library lets every bench and the CLI
//! share one implementation (and lets unit tests cover the aggregation).

use anyhow::Result;

use crate::config::presets::Experiment;
use crate::metrics::RunRecord;
use crate::runtime::Runtime;
use crate::util::plot::{render, Series};
use crate::util::stats;
use crate::util::table::{pm, Table};

/// One experiment arm's trials.
pub struct ArmResult {
    pub label: String,
    pub records: Vec<RunRecord>,
}

impl ArmResult {
    pub fn acc_at(&self, frac: f64) -> Vec<f64> {
        self.records.iter().map(|r| r.val_acc_at_frac(frac)).collect()
    }

    pub fn mean_acc_curve(&self) -> Vec<f64> {
        stats::mean_curve(&self.records.iter().map(|r| r.val_acc_curve()).collect::<Vec<_>>())
    }

    pub fn mean_loss_curve(&self) -> Vec<f64> {
        stats::mean_curve(&self.records.iter().map(|r| r.val_loss_curve()).collect::<Vec<_>>())
    }

    pub fn mean_batch_curve(&self) -> Vec<f64> {
        stats::mean_curve(
            &self
                .records
                .iter()
                .map(|r| r.batch_size_curve())
                .collect::<Vec<_>>(),
        )
    }

    /// Mean time to within ±tol of final acc (simulated or wall).
    pub fn mean_time_within(&self, tol_pct: f64, simulated: bool) -> Option<f64> {
        let ts: Vec<f64> = self
            .records
            .iter()
            .filter_map(|r| r.time_within_final(tol_pct, simulated))
            .collect();
        if ts.is_empty() {
            None
        } else {
            Some(stats::mean(&ts))
        }
    }
}

/// All arms of one experiment.
pub struct ExperimentResult {
    pub title: String,
    pub arms: Vec<ArmResult>,
}

/// Run every arm (all trials) of `exp`; prints progress to stderr.
///
/// Results are memoized under `DIVEBATCH_RESULTS` (default
/// `results/cache`) so benches that share arms — Figures 3/4 and
/// Table 1 run the *same* experiments — reuse completed runs.  Set
/// `DIVEBATCH_NO_CACHE=1` to force recomputation.
///
/// Trials of *all* uncached arms are fanned across one trial-engine
/// worker pool; `DIVEBATCH_JOBS` picks the worker count (unset/0 = all
/// cores).  Records are identical at any jobs level, but the real
/// wall-clock columns (`t±1% wall(s)`) measure contended time under
/// parallel trials — set `DIVEBATCH_JOBS=1` when those columns matter.
/// Parallel results are cached in a jobs-segregated subdirectory
/// ([`crate::config::RunSpec::cache_dir_for_run`]) so a later
/// `DIVEBATCH_JOBS=1` run never silently reuses contention-inflated
/// wall times.
pub fn run_experiment(rt: &Runtime, exp: &Experiment, verbose: bool) -> Result<ExperimentResult> {
    run_experiment_jobs(rt, exp, verbose, crate::engine::jobs_from_env())
}

/// [`run_experiment`] with an explicit trial-engine jobs knob
/// (0 = all available cores).
pub fn run_experiment_jobs(
    rt: &Runtime,
    exp: &Experiment,
    verbose: bool,
    jobs: usize,
) -> Result<ExperimentResult> {
    let base_dir = std::path::PathBuf::from(
        std::env::var("DIVEBATCH_RESULTS").unwrap_or_else(|_| "results/cache".into()),
    );
    let use_cache = std::env::var("DIVEBATCH_NO_CACHE").is_err();

    // Resolve cache hits first; everything else becomes engine work.
    let mut arm_records: Vec<Option<Vec<crate::metrics::RunRecord>>> = Vec::new();
    let mut pending: Vec<(usize, crate::config::RunSpec)> = Vec::new();
    for (i, run) in exp.runs.iter().enumerate() {
        let mut r = run.clone();
        r.cfg.verbose = verbose;
        // Pin the step-lane count to explicit/env-or-serial (never the
        // engine's pending-count-dependent auto allowance): cached
        // wall-clock columns must come from a lane regime derivable
        // from the spec + environment alone, and the per-run
        // `jobs<N>[-step<M>]` cache tag (cache_dir_for_run) reflects
        // exactly that regime, so entries from different regimes can
        // never be confused.
        r.cfg.step_jobs = crate::pool::resolve_step_jobs(r.cfg.step_jobs, 1);
        let cached = if use_cache {
            let cache =
                crate::config::rescache::ResultsCache::from_env(r.cache_dir_for_run(&base_dir, jobs));
            let hit = cache.load(&r.fingerprint(), r.trials);
            if hit.is_some() {
                eprintln!("  (cache hit: {})", cache.path_for(&r.fingerprint()).display());
            }
            hit
        } else {
            None
        };
        let hit = cached.is_some();
        arm_records.push(cached);
        if !hit {
            pending.push((i, r));
        }
    }

    if !pending.is_empty() {
        // One flat trial list across all uncached arms: the pool stays
        // busy even when arms have uneven trial counts.
        let mut specs = Vec::new();
        let mut owner = Vec::new();
        for (slot, (_, r)) in pending.iter().enumerate() {
            for t in crate::engine::TrialSpec::expand(r) {
                specs.push(t);
                owner.push(slot);
            }
        }
        let runner = crate::engine::TrialRunner::new(jobs);
        eprintln!(
            "  engine: {} trials ({} arms) on {} workers",
            specs.len(),
            pending.len(),
            runner.jobs_for(specs.len())
        );
        let t = crate::util::timer::Timer::start();
        let results = runner.run_with(rt, &specs, |spec, res| match res {
            Ok(_) => eprintln!("  trial done: {}", spec.label()),
            Err(e) => eprintln!("  trial FAILED: {}: {e}", spec.label()),
        });
        let mut grouped: Vec<Vec<crate::metrics::RunRecord>> = Vec::new();
        grouped.resize_with(pending.len(), Vec::new);
        let mut first_err = None;
        for ((res, spec), &slot) in results.into_iter().zip(&specs).zip(&owner) {
            match res {
                Ok(rec) => grouped[slot].push(rec),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("{}: {e}", spec.label()));
                    }
                }
            }
        }
        eprintln!("  engine: sweep finished in {:.1}s", t.seconds());
        // Persist every FULLY-completed arm before reporting any failure:
        // engine isolation means the other arms' work is done, and a rerun
        // after fixing the failing arm should not recompute them.
        for ((i, r), recs) in pending.iter().zip(grouped) {
            if recs.len() != r.trials {
                continue; // incomplete arm (some trial failed)
            }
            if use_cache {
                // Stores go through the bounded results-cache service:
                // single-writer locked, atomic publish, LRU-evicted when
                // DIVEBATCH_RESULTS_MAX_ENTRIES/_MAX_BYTES are set.
                let cache = crate::config::rescache::ResultsCache::from_env(
                    r.cache_dir_for_run(&base_dir, jobs),
                );
                cache.store(&r.fingerprint(), &recs)?;
                let st = cache.stats();
                if st.evictions > 0 {
                    eprintln!(
                        "  (results cache evicted {} entr{} to stay within bounds)",
                        st.evictions,
                        if st.evictions == 1 { "y" } else { "ies" }
                    );
                }
            }
            arm_records[*i] = Some(recs);
        }
        if let Some(e) = first_err {
            return Err(e);
        }
    }

    let mut arms = Vec::new();
    for cached in arm_records {
        let records = cached.expect("every arm resolved via cache or engine");
        eprintln!(
            "  arm done: {:<26} ({} trials)",
            records[0].label,
            records.len()
        );
        arms.push(ArmResult {
            label: records[0].label.clone(),
            records,
        });
    }
    Ok(ExperimentResult {
        title: exp.title.clone(),
        arms,
    })
}

impl ExperimentResult {
    /// Figure-style accuracy plot (mean over trials).
    pub fn acc_figure(&self, width: usize, height: usize) -> String {
        let series: Vec<Series> = self
            .arms
            .iter()
            .map(|a| Series::new(&a.label, a.mean_acc_curve()))
            .collect();
        render(
            &format!("{} — validation accuracy", self.title),
            "epoch",
            &series,
            width,
            height,
        )
    }

    /// Figure-style loss plot (mean over trials).
    pub fn loss_figure(&self, width: usize, height: usize) -> String {
        let series: Vec<Series> = self
            .arms
            .iter()
            .map(|a| Series::new(&a.label, a.mean_loss_curve()))
            .collect();
        render(
            &format!("{} — validation loss", self.title),
            "epoch",
            &series,
            width,
            height,
        )
    }

    /// Batch-size progression plot (Figure 2 middle panels).
    pub fn batch_figure(&self, width: usize, height: usize) -> String {
        let series: Vec<Series> = self
            .arms
            .iter()
            .map(|a| Series::new(&a.label, a.mean_batch_curve()))
            .collect();
        render(
            &format!("{} — batch size", self.title),
            "epoch",
            &series,
            width,
            height,
        )
    }

    /// Paper Table-1 rows: accuracy at 25/50/75/100% + time to ±1%.
    pub fn table1(&self) -> Table {
        let mut t = Table::new(
            &format!("{} — Table 1 format", self.title),
            &[
                "Algorithm",
                "25%",
                "50%",
                "75%",
                "100% (Final)",
                "t±1% sim(s)",
                "t±1% wall(s)",
            ],
        );
        for a in &self.arms {
            let col = |f: f64| {
                let xs = a.acc_at(f);
                pm(stats::mean(&xs), stats::stderr(&xs))
            };
            t.row(vec![
                a.label.clone(),
                col(0.25),
                col(0.5),
                col(0.75),
                col(1.0),
                a.mean_time_within(1.0, true)
                    .map(|x| format!("{x:.2}"))
                    .unwrap_or_else(|| "-".into()),
                a.mean_time_within(1.0, false)
                    .map(|x| format!("{x:.2}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// Headline speedups: each arm's time-to-±1% relative to DiveBatch
    /// (the paper's "1.06-5x faster" claim).
    pub fn speedup_rows(&self) -> Table {
        let mut t = Table::new(
            "time-to-±1%-of-final speedup vs DiveBatch (simulated cluster)",
            &["Algorithm", "t±1% (s)", "DiveBatch speedup"],
        );
        let dive = self
            .arms
            .iter()
            .find(|a| a.label.starts_with("DiveBatch"))
            .and_then(|a| a.mean_time_within(1.0, true));
        for a in &self.arms {
            let time = a.mean_time_within(1.0, true);
            let speed = match (dive, time) {
                (Some(d), Some(t)) if d > 0.0 => format!("{:.2}x", t / d),
                _ => "-".into(),
            };
            t.row(vec![
                a.label.clone(),
                time.map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".into()),
                speed,
            ]);
        }
        t
    }

    pub fn arm(&self, prefix: &str) -> Option<&ArmResult> {
        self.arms.iter().find(|a| a.label.starts_with(prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochRecord;

    fn fake_arm(label: &str, accs: &[f64], sim_per_epoch: f64) -> ArmResult {
        let mut rec = RunRecord::new(label, "m", "x", "d", 0);
        for (i, &a) in accs.iter().enumerate() {
            rec.epochs.push(EpochRecord {
                epoch: i,
                batch_size: 8,
                lr: 0.1,
                steps: 1,
                train_loss: 1.0,
                train_acc: 0.0,
                val_loss: 1.0 / (i + 1) as f64,
                val_acc: a,
                delta_hat: None,
                n_delta: None,
                exact_delta: None,
                wall_s: 1.0,
                sim_s: sim_per_epoch,
                cum_wall_s: (i + 1) as f64,
                cum_sim_s: sim_per_epoch * (i + 1) as f64,
                mem_mb: 1.0,
                dispatches: 1,
                pad_waste: 0.0,
                par_util: 1.0,
            });
        }
        ArmResult {
            label: label.into(),
            records: vec![rec],
        }
    }

    #[test]
    fn table_and_speedups_render() {
        let res = ExperimentResult {
            title: "demo".into(),
            arms: vec![
                fake_arm("SGD (8)", &[10.0, 50.0, 88.0, 89.5, 90.0], 2.0),
                fake_arm("DiveBatch (4 - 8)", &[60.0, 88.5, 89.0, 89.0, 89.0], 1.0),
            ],
        };
        let t1 = res.table1().render();
        assert!(t1.contains("SGD (8)"));
        assert!(t1.contains("100% (Final)"));
        let sp = res.speedup_rows().render();
        // SGD hits ±1% at epoch 3 (cum 8s? -> acc 89.5 within 0.5 of 90 at
        // epoch 3, stays) vs DiveBatch at epoch 1 (cum 2s): speedup 4x.
        assert!(sp.contains("x"), "{sp}");
        assert!(res.arm("DiveBatch").is_some());
        assert!(res.arm("nope").is_none());
        assert!(res.acc_figure(40, 8).contains("validation accuracy"));
        assert!(res.loss_figure(40, 8).contains("loss"));
        assert!(res.batch_figure(40, 8).contains("batch size"));
    }

    #[test]
    fn time_within_uses_simulated_column() {
        let arm = fake_arm("A", &[10.0, 89.5, 90.0], 3.0);
        assert_eq!(arm.mean_time_within(1.0, true), Some(6.0));
        assert_eq!(arm.mean_time_within(1.0, false), Some(2.0));
    }
}
