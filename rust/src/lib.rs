//! # DiveBatch — gradient-diversity aware batch-size adaptation
//!
//! Production-shaped reproduction of *"DiveBatch: Accelerating Model
//! Training Through Gradient-Diversity Aware Batch Size Adaptation"*
//! (Chen, Wang & Sundaram, 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the training coordinator: batch-size policies
//!   (Fixed / AdaBatch / DiveBatch / Oracle), accumulation planning over a
//!   compiled micro-batch ladder, optimizer, LR schedules, diversity
//!   accumulation, data pipeline, simulated-cluster timing, metrics and
//!   benches.  Owns the event loop; Python never runs here.
//! * **L2 (python/compile, build time)** — JAX model fwd/bwd step
//!   functions lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels for the
//!   per-sample gradient-statistics hot spot, lowered into the same
//!   modules.
//!
//! Quickstart:
//!
//! ```bash
//! make artifacts                     # AOT: python runs once, never again
//! cargo run --release --example quickstart
//! cargo run --release -- train logreg512 --policy divebatch:m0=128,delta=1,mmax=4096
//! cargo bench --bench fig1_synthetic
//! ```
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod util;

pub use cluster::ClusterModel;
pub use config::{presets, DatasetSpec, RunSpec};
pub use coordinator::{
    DiversityAccum, DiversityNeed, DiversityStats, LrSchedule, MicroPlan, Policy, SgdOptimizer,
    TrainConfig, Trainer,
};
pub use data::{Batch, Dataset, EpochBatches, ImageSpec, Labels, SyntheticSpec};
pub use metrics::{EpochRecord, MemMode, MemoryModel, RunRecord};
pub use runtime::{Manifest, ModelInfo, Runtime};
