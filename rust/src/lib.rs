//! # DiveBatch — gradient-diversity aware batch-size adaptation
//!
//! Production-shaped reproduction of *"DiveBatch: Accelerating Model
//! Training Through Gradient-Diversity Aware Batch Size Adaptation"*
//! (Chen, Wang & Sundaram, 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the training coordinator: the open
//!   [`BatchPolicy`] controller API (below), accumulation planning over a
//!   compiled micro-batch ladder, optimizer, LR schedules, diversity
//!   accumulation, data pipeline, simulated-cluster timing, metrics and
//!   benches, plus the **parallel trial engine** ([`engine`]) that fans
//!   multi-policy / multi-seed sweeps across a scoped worker pool.  Owns
//!   the event loop; Python never runs here.
//! * **L2 (python/compile, build time)** — JAX model fwd/bwd step
//!   functions lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels for the
//!   per-sample gradient-statistics hot spot, lowered into the same
//!   modules.
//!
//! Quickstart:
//!
//! ```bash
//! make artifacts                     # AOT: python runs once, never again
//! cargo run --release --example quickstart
//! cargo run --release -- train logreg512 --policy divebatch:m0=128,delta=1,mmax=4096
//! cargo run --release -- sweep logreg512 --seeds 5 --jobs 0 \
//!     --policies "sgd:m=128;adabatch:m0=128,mmax=4096;divebatch:m0=128,mmax=4096"
//! cargo run --release -- policies    # list every policy + wrapper
//! DIVEBATCH_JOBS=0 cargo bench --bench fig1_synthetic
//! ```
//!
//! ## The pool layer: two levels of parallelism, one budget
//!
//! All parallel execution sits on one shared pool layer ([`pool`]):
//!
//! * **Trial-level** — the trial engine ([`engine`]) schedules
//!   `(config, dataset, seed)` trials ([`TrialSpec`]) across a scoped
//!   fan-out ([`TrialRunner`], `--jobs N`, 0 = all cores), streaming
//!   records back **in spec order** with per-trial panic isolation: a
//!   poisoned trial reports an error and the rest of the sweep
//!   completes.
//! * **Step-level** — inside each trial, the sharded step executor
//!   ([`StepExecutor`], `--step-jobs N` / `DIVEBATCH_STEP_JOBS`)
//!   dispatches the micro-batch blocks of every logical batch across a
//!   persistent [`pool::WorkerPool`] (workers park between steps — no
//!   per-step thread spawns).  Each lane owns its gather buffer and
//!   executable handles; block outputs are folded **in block order**,
//!   so the gradient reduction is byte-identical to the serial loop.
//!   This is what makes batch-size adaptation bend *measured*
//!   wall-clock, not just the simulated cluster columns: a batch grown
//!   8x yields 8x the blocks, executing concurrently.
//!
//! The two levels compose under **one** jobs budget instead of
//! multiplying: the engine hands each concurrent trial a step allowance
//! of `budget / trial_workers` lanes (`train --trials 1 --jobs 8` = 1
//! trial x 8 lanes; a 16-trial sweep on 8 cores = 8 serial trials), and
//! an explicit `--step-jobs` / `DIVEBATCH_STEP_JOBS` overrides the
//! allowance ([`pool::resolve_step_jobs`]).
//!
//! Records are identical at every `--jobs` x `--step-jobs` combination
//! (each trial owns its RNG streams and policy instance; each step
//! folds deterministically); only the real wall-clock columns — and the
//! step-lane utilization field `pu` — vary, and
//! `RunRecord::to_canonical_json` masks exactly those.  The
//! `train`/`sweep`/`preset` subcommands, the figure/table benches
//! (`DIVEBATCH_JOBS`), and the sweep examples all route through the
//! engine.
//!
//! The runtime layer ([`runtime`]) underpinning this is `Send + Sync`
//! end to end: one [`Runtime`] — PJRT client, manifest, and executable
//! cache — is shared by every worker thread, with concurrent first
//! access to an entry compiling it exactly once (and `Runtime::warmup`
//! precompiling the whole train/eval surface so parallel step lanes
//! never serialize on first-compile guards), execution counts kept
//! exact, and per-lane [`runtime::ExecCache`] handle caches making the
//! per-block executable lookup allocation- and lock-free.
//!
//! ## Execution backends
//!
//! Compiled entries execute on one of three backend tiers (selected in
//! rust/vendor/xla — see its crate docs):
//!
//! 1. **Interpreter** (default): a pure-Rust HLO engine split into a
//!    compile phase (HLO text -> flat SSA register program: typed
//!    kernels, precomputed gather/dot/reduce plans, fused elementwise
//!    chains, last-use buffer-slot assignment) and an execute phase (the
//!    program over a pooled buffer arena — near-zero steady-state
//!    allocation, borrowed argument literals, deterministic in-crate
//!    math so results are bit-identical across platforms).  Every
//!    numeric test — trainer epochs, policy trajectories, the `jobs=1`
//!    vs `jobs=4` equivalence gate, the byte-for-byte golden-record
//!    regression — runs in plain `cargo test` over the committed
//!    fixtures in rust/tests/fixtures, on any machine, with zero skips.
//!    Execution runs in one of two bit-identical tiers — the default
//!    SIMD tier (8-lane kernels, cost-model-selected dot variants, AVX
//!    where available) and a scalar escape hatch
//!    (`DIVEBATCH_INTERP_TIER=scalar`); both implement one pinned
//!    8-lane accumulation contract, so the tier never changes a byte of
//!    output.  Correctness is anchored by jax-evaluated goldens
//!    (`python -m compile.fixtures` regenerates both) and by the
//!    three-way differential suite — SIMD vs scalar bitwise, both vs
//!    the retained tree-walk evaluator (tests/differential_interp.rs);
//!    speed is tracked in BENCH_4.json by `cargo bench --bench
//!    perf_interp` and the SIMD-over-scalar win in BENCH_6.json by
//!    `cargo bench --bench perf_interp_simd`.
//! 2. **Stub** (`DIVEBATCH_BACKEND=stub`): compile/cache-only — for
//!    exercising the runtime plumbing with execution explicitly off.
//! 3. **Real PJRT**: swap the `xla` dependency in rust/Cargo.toml to the
//!    real xla_extension binding and run over `make artifacts` output;
//!    integration suites pick up extra real-backend coverage via
//!    `DIVEBATCH_TEST_ARTIFACTS=<dir>`.
//!
//! ## Batch policies
//!
//! Batch-size control is an open, trait-based API
//! ([`coordinator::policy`]).  A policy implements [`BatchPolicy`]: the
//! trainer hands it an [`AdaptContext`] (epoch, step, current batch,
//! dataset size, diversity stats, loss/val history, simulated cluster
//! clock) at `on_epoch_start` / `on_step` / `on_epoch_end`, and receives
//! a [`Decision`] — the next batch size, the diversity instrumentation
//! the next epoch needs, and an optional lr rescale factor.  Step-level
//! policies (opt-in via `wants_step_decisions`) can resize batches
//! mid-epoch, not just at boundaries.
//!
//! Built-ins: Fixed SGD, AdaBatch, DiveBatch (Algorithm 1), Oracle, and
//! EMA-smoothed DiveBatch, plus composable wrappers (`warmup`, `clamp`,
//! `ema` hysteresis, programmatic `Chain`).  The [`PolicyRegistry`] owns
//! the CLI spec grammar:
//!
//! ```text
//! spec := (wrapper "/")* base          leftmost wrapper = outermost
//! divebatch:m0=128,delta=1,mmax=4096
//! warmup:epochs=5,m=64/divebatch:m0=128,mmax=4096
//! clamp:min=64,max=1024/ema:beta=0.7/divebatch:m0=128,mmax=4096
//! ```
//!
//! Parsing is strict — unknown policies/parameters fail with "did you
//! mean" suggestions — and every registry spec round-trips through
//! `render_spec` (property-tested).  Writing your own policy is ~30
//! lines; `coordinator/policy/smoothed.rs` is the template:
//!
//! ```ignore
//! use divebatch::{AdaptContext, BatchPolicy, Decision, DiversityNeed, PolicyError};
//!
//! /// Double the batch whenever validation loss stops improving.
//! #[derive(Clone, Copy, Debug)]
//! struct Plateau { m0: usize, m_max: usize, tol: f64 }
//!
//! impl BatchPolicy for Plateau {
//!     fn kind(&self) -> &'static str { "plateau" }
//!     fn label(&self) -> String { format!("Plateau ({} - {})", self.m0, self.m_max) }
//!     fn initial(&self) -> usize { self.m0 }
//!     fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
//!         let stalled = match ctx.history {
//!             [.., prev, last] => prev.val_loss - last.val_loss < self.tol,
//!             _ => false,
//!         };
//!         let next = if stalled { ctx.batch_size * 2 } else { ctx.batch_size };
//!         Ok(Decision::new(next.min(self.m_max), DiversityNeed::None))
//!     }
//!     fn render_spec(&self) -> String {
//!         format!("plateau:m0={},mmax={},tol={}", self.m0, self.m_max, self.tol)
//!     }
//!     fn clone_box(&self) -> Box<dyn BatchPolicy> { Box::new(*self) }
//! }
//! // CLI selection = one registration in PolicyRegistry::with_builtins
//! // (or a custom registry); TrainConfig::new also accepts the boxed
//! // policy directly.  See examples/custom_policy.rs for the full flow.
//! ```
//!
//! ## Training as a service
//!
//! `divebatch serve` ([`server`]) exposes the trial engine over a
//! std-only HTTP/1.1 server: clients POST trial/sweep requests as JSON
//! and read canonical [`RunRecord`] JSONL back — byte-identical to
//! offline `train` output for the same spec.  An adaptive admission
//! layer coalesces queued requests into engine dispatches sized to the
//! observed queue depth (the serving-side analogue of batch-size
//! adaptation), and both shared caches — the runtime's compiled
//! executable cache and the on-disk results cache — run
//! eviction-bounded with hit/miss/eviction counters at `GET /stats`.
//! Validation is strict end to end: unknown fields, bad types and
//! out-of-range values come back as structured 400s with "did you
//! mean" suggestions, never 500s.  SIGTERM drains gracefully.
//!
//! ## Fault tolerance & chaos testing
//!
//! The [`fault`] subsystem makes failure a first-class, *deterministic*
//! input.  A seeded [`FaultPlan`] (CLI `--inject`, env
//! `DIVEBATCH_FAULTS`) injects panics, typed errors, stalls and
//! connection drops at four audited hook points — trial boundary,
//! step-block dispatch, results-cache I/O, server connection handling —
//! with per-rule budgets and seed-stable probabilities, so every chaos
//! run is reproducible.  On top of that:
//!
//! * [`engine::TrialRunner`] retries transient (injected / cache-I/O)
//!   failures under a [`fault::RetryPolicy`] — bounded exponential
//!   backoff on a real or simulated clock — while deterministic compute
//!   panics fail fast, with the full attempt history attached to the
//!   [`TrialError`].
//! * `sweep --journal` writes each completed trial's canonical record
//!   to a crash-safe journal (atomic tmp+rename under the shared
//!   directory lock); `sweep --resume` validates the journal's spec
//!   fingerprint and runs only the missing trials, producing
//!   byte-identical output to an uninterrupted run — even after
//!   SIGKILL (tests/chaos.rs gates this).
//! * [`ClusterSpec`] models imperfect clusters: per-worker speed
//!   heterogeneity, seeded stragglers and preemptions, all folded into
//!   the simulated timing columns deterministically.
//! * The server bounds `/trial` waits (`--trial-timeout` → 504) and
//!   attaches `Retry-After` to every backpressure 503.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fault;
pub mod metrics;
pub mod pool;
pub mod runtime;
pub mod server;
pub mod util;

pub use cluster::{ClusterModel, ClusterSpec};
pub use config::{presets, DatasetSpec, RunSpec};
pub use engine::{sweep_fingerprint, SweepJournal, TrialError, TrialRunner, TrialSpec};
pub use fault::{FaultPlan, RetryPolicy};
pub use coordinator::{
    AdaptContext, BatchPolicy, Decision, DiversityAccum, DiversityNeed, DiversityStats,
    HistoryPoint, LrSchedule, MicroPlan, Policy, PolicyError, PolicyHandle, PolicyRegistry,
    SgdOptimizer, StepExecutor, TrainConfig, Trainer,
};
pub use data::{Batch, Dataset, EpochBatches, ImageSpec, Labels, SyntheticSpec};
pub use metrics::{EpochRecord, MemMode, MemoryModel, RunRecord};
pub use runtime::{Manifest, ModelInfo, Runtime};
pub use server::{ServeConfig, Server, ServerHandle};
