//! In-memory dataset container + seeded epoch batching.
//!
//! All experiment datasets (synthetic Eq. 3 and the procedural image sets)
//! are materialized up front as contiguous row-major f32 feature buffers;
//! the trainer consumes shuffled index batches per epoch and gathers them
//! into padded micro-batch buffers (`w = 0` padding rows — the executables
//! treat them as exact no-ops, see python/compile/model.py).

use crate::util::rng::Rng;

/// Labels are either float {0,1} (binary models) or int class ids.
#[derive(Clone, Debug, PartialEq)]
pub enum Labels {
    Float(Vec<f32>),
    Int(Vec<i32>),
}

impl Labels {
    pub fn len(&self) -> usize {
        match self {
            Labels::Float(v) => v.len(),
            Labels::Int(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Labels::Float(_) => "f32",
            Labels::Int(_) => "s32",
        }
    }
}

/// A gathered, padded micro-batch ready for upload.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Row-major features, `pad_to * feat_len` elements.
    pub x: Vec<f32>,
    /// Labels in each dtype view (only the matching one is populated).
    pub y_f32: Vec<f32>,
    pub y_i32: Vec<i32>,
    /// Per-sample weights: 1.0 for real rows, 0.0 for padding.
    pub w: Vec<f32>,
    /// Number of REAL samples (<= pad_to).
    pub real: usize,
    /// Padded row count (the executable's static batch dimension).
    pub pad_to: usize,
}

/// An in-memory supervised dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major `[n, feat...]`.
    pub x: Vec<f32>,
    pub y: Labels,
    /// Per-sample feature shape (e.g. `[512]` or `[16, 16, 3]`).
    pub feat_shape: Vec<usize>,
    pub num_classes: usize,
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn feat_len(&self) -> usize {
        self.feat_shape.iter().product()
    }

    /// Split into (train, val) with the given train fraction, preserving
    /// order (callers shuffle first if needed; generators emit i.i.d. rows).
    pub fn split(&self, train_frac: f64) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&train_frac));
        let n_train = ((self.n() as f64) * train_frac).round() as usize;
        (self.slice(0, n_train), self.slice(n_train, self.n()))
    }

    /// Rows `[lo, hi)` as a new dataset.
    pub fn slice(&self, lo: usize, hi: usize) -> Dataset {
        assert!(lo <= hi && hi <= self.n());
        let f = self.feat_len();
        let y = match &self.y {
            Labels::Float(v) => Labels::Float(v[lo..hi].to_vec()),
            Labels::Int(v) => Labels::Int(v[lo..hi].to_vec()),
        };
        Dataset {
            x: self.x[lo * f..hi * f].to_vec(),
            y,
            feat_shape: self.feat_shape.clone(),
            num_classes: self.num_classes,
            name: self.name.clone(),
        }
    }

    /// Gather `indices` into a batch padded to `pad_to` rows.
    ///
    /// Padding rows repeat row 0's features (arbitrary — masked by w=0)
    /// and carry label 0; only `w` distinguishes them.
    pub fn gather(&self, indices: &[u32], pad_to: usize) -> Batch {
        assert!(indices.len() <= pad_to, "{} > {}", indices.len(), pad_to);
        let f = self.feat_len();
        let mut x = Vec::with_capacity(pad_to * f);
        let mut y_f32 = Vec::new();
        let mut y_i32 = Vec::new();
        let mut w = Vec::with_capacity(pad_to);
        for &i in indices {
            let i = i as usize;
            x.extend_from_slice(&self.x[i * f..(i + 1) * f]);
            w.push(1.0);
        }
        for _ in indices.len()..pad_to {
            x.extend_from_slice(&self.x[0..f]);
            w.push(0.0);
        }
        match &self.y {
            Labels::Float(v) => {
                y_f32.reserve(pad_to);
                for &i in indices {
                    y_f32.push(v[i as usize]);
                }
                y_f32.resize(pad_to, 0.0);
            }
            Labels::Int(v) => {
                y_i32.reserve(pad_to);
                for &i in indices {
                    y_i32.push(v[i as usize]);
                }
                y_i32.resize(pad_to, 0);
            }
        }
        Batch {
            x,
            y_f32,
            y_i32,
            w,
            real: indices.len(),
            pad_to,
        }
    }

    /// Gather into caller-provided buffers (zero-allocation hot path;
    /// see §Perf).  Buffers are resized to the padded extent.
    pub fn gather_into(&self, indices: &[u32], pad_to: usize, out: &mut Batch) {
        assert!(indices.len() <= pad_to);
        let f = self.feat_len();
        out.x.clear();
        out.x.reserve(pad_to * f);
        out.w.clear();
        out.w.reserve(pad_to);
        out.y_f32.clear();
        out.y_i32.clear();
        for &i in indices {
            let i = i as usize;
            out.x.extend_from_slice(&self.x[i * f..(i + 1) * f]);
            out.w.push(1.0);
        }
        for _ in indices.len()..pad_to {
            out.x.extend_from_slice(&self.x[0..f]);
            out.w.push(0.0);
        }
        match &self.y {
            Labels::Float(v) => {
                for &i in indices {
                    out.y_f32.push(v[i as usize]);
                }
                out.y_f32.resize(pad_to, 0.0);
            }
            Labels::Int(v) => {
                for &i in indices {
                    out.y_i32.push(v[i as usize]);
                }
                out.y_i32.resize(pad_to, 0);
            }
        }
        out.real = indices.len();
        out.pad_to = pad_to;
    }
}

impl Batch {
    pub fn empty() -> Batch {
        Batch {
            x: Vec::new(),
            y_f32: Vec::new(),
            y_i32: Vec::new(),
            w: Vec::new(),
            real: 0,
            pad_to: 0,
        }
    }
}

/// One epoch's shuffled batching: yields index slices of size `m`
/// (last batch partial — `ceil(n/m)` batches, matching the paper's
/// epoch definition in section 2.1).
pub struct EpochBatches {
    perm: Vec<u32>,
    m: usize,
    pos: usize,
}

impl EpochBatches {
    pub fn new(n: usize, m: usize, rng: &mut Rng) -> Self {
        assert!(m > 0 && n > 0);
        EpochBatches {
            perm: rng.permutation(n),
            m,
            pos: 0,
        }
    }

    /// Sequential (unshuffled) pass — used by Oracle full-dataset scans
    /// and validation.
    pub fn sequential(n: usize, m: usize) -> Self {
        assert!(m > 0 && n > 0);
        EpochBatches {
            perm: (0..n as u32).collect(),
            m,
            pos: 0,
        }
    }

    pub fn num_batches(&self) -> usize {
        self.perm.len().div_ceil(self.m)
    }

    /// Change the chunk size mid-iteration (step-level batch policies):
    /// the remaining indices are re-chunked at the new size; already
    /// yielded batches are unaffected.
    pub fn set_batch_size(&mut self, m: usize) {
        assert!(m > 0);
        self.m = m;
    }

    /// Current chunk size.
    pub fn batch_size(&self) -> usize {
        self.m
    }
}

impl Iterator for EpochBatches {
    type Item = Vec<u32>;

    fn next(&mut self) -> Option<Vec<u32>> {
        if self.pos >= self.perm.len() {
            return None;
        }
        let end = (self.pos + self.m).min(self.perm.len());
        let out = self.perm[self.pos..end].to_vec();
        self.pos = end;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        Dataset {
            x: (0..n * 3).map(|i| i as f32).collect(),
            y: Labels::Float((0..n).map(|i| (i % 2) as f32).collect()),
            feat_shape: vec![3],
            num_classes: 2,
            name: "toy".into(),
        }
    }

    #[test]
    fn split_preserves_rows() {
        let d = toy(10);
        let (tr, va) = d.split(0.8);
        assert_eq!(tr.n(), 8);
        assert_eq!(va.n(), 2);
        assert_eq!(va.x[0], 24.0); // row 8 starts at 8*3
        assert_eq!(tr.feat_len(), 3);
    }

    #[test]
    fn gather_pads_with_zero_weights() {
        let d = toy(5);
        let b = d.gather(&[4, 1], 4);
        assert_eq!(b.real, 2);
        assert_eq!(b.pad_to, 4);
        assert_eq!(b.w, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&b.x[0..3], &[12.0, 13.0, 14.0]); // row 4
        assert_eq!(&b.x[3..6], &[3.0, 4.0, 5.0]); // row 1
        assert_eq!(b.y_f32, vec![0.0, 1.0, 0.0, 0.0]);
        assert!(b.y_i32.is_empty());
    }

    #[test]
    fn gather_into_matches_gather() {
        let d = toy(6);
        let idx = [0u32, 5, 3];
        let a = d.gather(&idx, 4);
        let mut b = Batch::empty();
        d.gather_into(&idx, 4, &mut b);
        assert_eq!(a.x, b.x);
        assert_eq!(a.w, b.w);
        assert_eq!(a.y_f32, b.y_f32);
        assert_eq!(a.real, b.real);
    }

    #[test]
    fn int_labels_gather() {
        let d = Dataset {
            x: vec![0.0; 12],
            y: Labels::Int(vec![7, 8, 9, 10]),
            feat_shape: vec![3],
            num_classes: 11,
            name: "i".into(),
        };
        let b = d.gather(&[2], 2);
        assert_eq!(b.y_i32, vec![9, 0]);
        assert!(b.y_f32.is_empty());
        assert_eq!(d.y.dtype(), "s32");
    }

    #[test]
    fn epoch_batches_cover_everything_once() {
        let mut rng = Rng::new(0);
        let batches: Vec<_> = EpochBatches::new(103, 16, &mut rng).collect();
        assert_eq!(batches.len(), 7); // ceil(103/16)
        assert_eq!(batches.last().unwrap().len(), 103 - 6 * 16);
        let mut seen = vec![false; 103];
        for b in &batches {
            for &i in b {
                assert!(!seen[i as usize], "duplicate {i}");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn epoch_batches_shuffled_differs_from_sequential() {
        let mut rng = Rng::new(1);
        let shuffled: Vec<u32> = EpochBatches::new(50, 50, &mut rng).next().unwrap();
        let seq: Vec<u32> = EpochBatches::sequential(50, 50).next().unwrap();
        assert_ne!(shuffled, seq);
        assert_eq!(seq, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn set_batch_size_rechunks_remaining_indices() {
        let mut b = EpochBatches::sequential(20, 4);
        assert_eq!(b.next().unwrap(), vec![0, 1, 2, 3]);
        b.set_batch_size(7);
        assert_eq!(b.batch_size(), 7);
        assert_eq!(b.next().unwrap(), vec![4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(b.next().unwrap(), vec![11, 12, 13, 14, 15, 16, 17]);
        assert_eq!(b.next().unwrap(), vec![18, 19]); // tail
        assert!(b.next().is_none());
    }

    #[test]
    fn num_batches_matches_ceil() {
        let mut rng = Rng::new(2);
        assert_eq!(EpochBatches::new(100, 32, &mut rng).num_batches(), 4);
        assert_eq!(EpochBatches::new(96, 32, &mut rng).num_batches(), 3);
    }
}
