//! Synthetic dataset generator — Equation 3 of the paper.
//!
//! For each sample: draw features `x ~ U[-1, 1]^d`, label
//! `y = 1{ sigmoid(w* . x + eps) > 0.5 }` with `w* ~ N(0, I)` and
//! `eps ~ N(0, noise^2)`.  Since `sigmoid(z) > 0.5  <=>  z > 0`, the label
//! is `1{ w* . x + eps > 0 }` — a noisy linear separator, learnable by the
//! convex logreg model and the nonconvex MLP alike (section 5.1 setup:
//! d = 512, n = 20 000, 80/20 train/val split, noise 0.1).

use super::dataset::{Dataset, Labels};
use crate::util::rng::Rng;

/// Configuration for the Eq. 3 generator.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub n: usize,
    pub d: usize,
    /// Std-dev of the label noise `eps` (paper: 0.1).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        // Paper's section 5.1 setup.
        SyntheticSpec {
            n: 20_000,
            d: 512,
            noise: 0.1,
            seed: 0,
        }
    }
}

/// Generate the dataset.  The true weight vector is drawn from a stream
/// forked off the seed, so datasets with the same seed share `w*` across
/// different `n` (useful for scaling studies).
pub fn generate(spec: &SyntheticSpec) -> Dataset {
    let mut root = Rng::new(spec.seed);
    let mut w_rng = root.fork(1);
    let mut x_rng = root.fork(2);
    let mut e_rng = root.fork(3);

    let w_star: Vec<f64> = (0..spec.d).map(|_| w_rng.normal()).collect();

    let mut x = vec![0.0f32; spec.n * spec.d];
    let mut y = vec![0.0f32; spec.n];
    for i in 0..spec.n {
        let row = &mut x[i * spec.d..(i + 1) * spec.d];
        x_rng.fill_uniform_f32(row, -1.0, 1.0);
        let mut z = 0.0f64;
        for j in 0..spec.d {
            z += w_star[j] * row[j] as f64;
        }
        z += e_rng.normal_ms(0.0, spec.noise);
        y[i] = if z > 0.0 { 1.0 } else { 0.0 };
    }
    Dataset {
        x,
        y: Labels::Float(y),
        feat_shape: vec![spec.d],
        num_classes: 2,
        name: format!("synthetic-d{}-n{}-s{}", spec.d, spec.n, spec.seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = generate(&SyntheticSpec {
            n: 200,
            d: 16,
            noise: 0.1,
            seed: 0,
        });
        assert_eq!(d.n(), 200);
        assert_eq!(d.feat_len(), 16);
        assert!(d.x.iter().all(|&v| (-1.0..1.0).contains(&v)));
        match &d.y {
            Labels::Float(y) => assert!(y.iter().all(|&v| v == 0.0 || v == 1.0)),
            _ => panic!("expected float labels"),
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        // w*.x is symmetric around 0, so classes should be ~50/50.
        let d = generate(&SyntheticSpec {
            n: 5000,
            d: 32,
            noise: 0.1,
            seed: 1,
        });
        let ones = match &d.y {
            Labels::Float(y) => y.iter().filter(|&&v| v == 1.0).count(),
            _ => unreachable!(),
        };
        let frac = ones as f64 / 5000.0;
        assert!((0.42..0.58).contains(&frac), "class balance {frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let s = SyntheticSpec {
            n: 50,
            d: 8,
            noise: 0.1,
            seed: 7,
        };
        let a = generate(&s);
        let b = generate(&s);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&SyntheticSpec { seed: 8, ..s });
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn labels_mostly_linearly_predictable() {
        // With small noise the Bayes-optimal linear rule must beat 85%:
        // recompute w*.x sign and compare (noise flips only near-margin
        // samples).  Guards against sign errors in the generator.
        let spec = SyntheticSpec {
            n: 2000,
            d: 16,
            noise: 0.1,
            seed: 3,
        };
        let d = generate(&spec);
        // Re-derive w* the same way generate() does.
        let mut root = Rng::new(spec.seed);
        let mut w_rng = root.fork(1);
        let w_star: Vec<f64> = (0..spec.d).map(|_| w_rng.normal()).collect();
        let y = match &d.y {
            Labels::Float(y) => y,
            _ => unreachable!(),
        };
        let mut agree = 0;
        for i in 0..d.n() {
            let z: f64 = (0..spec.d)
                .map(|j| w_star[j] * d.x[i * spec.d + j] as f64)
                .sum();
            let pred = if z > 0.0 { 1.0 } else { 0.0 };
            if pred == y[i] as f64 {
                agree += 1;
            }
        }
        let acc = agree as f64 / d.n() as f64;
        assert!(acc > 0.85, "linear predictability {acc}");
    }
}
