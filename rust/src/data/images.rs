//! Procedural class-conditional image datasets (CIFAR-like substitutes).
//!
//! The paper evaluates on CIFAR-10 / CIFAR-100 / Tiny-ImageNet, which are
//! not downloadable in this environment (DESIGN.md §3).  This generator
//! produces deterministic image datasets that exercise the identical code
//! path (NHWC image tensors -> residual CNN -> softmax CE -> per-sample
//! gradient statistics) with the structural properties that matter for
//! gradient-diversity dynamics:
//!
//! * each class has a distinct **template** (low-frequency random field +
//!   class-coded sinusoid), so inter-class gradients are diverse;
//! * each sample is a randomly shifted, jittered, noised variant of its
//!   class template, so intra-class gradients correlate but do not
//!   collapse — accuracy is learnable-but-not-trivial, like the originals;
//! * class-count / samples-per-class ratios mirror the real datasets
//!   (10 x many, 100 x fewer, 200 x fewest) via the presets below.

use super::dataset::{Dataset, Labels};
use crate::util::rng::Rng;

/// Configuration for the procedural image generator.
#[derive(Clone, Debug)]
pub struct ImageSpec {
    pub num_classes: usize,
    /// Samples per class (train + val are drawn together; split later).
    pub per_class: usize,
    /// Square image side (matches the resnet_tiny input).
    pub size: usize,
    /// Pixel noise std-dev added per sample.
    pub noise: f64,
    /// Max circular shift (pixels) applied per sample.
    pub max_shift: usize,
    pub seed: u64,
}

impl ImageSpec {
    /// CIFAR-10 analogue: few classes, many samples each.
    pub fn cifar10_like(per_class: usize, seed: u64) -> Self {
        ImageSpec {
            num_classes: 10,
            per_class,
            size: 16,
            noise: 0.45,
            max_shift: 2,
            seed,
        }
    }

    /// CIFAR-100 analogue: 10x the classes, ~1/10 the samples per class.
    pub fn cifar100_like(per_class: usize, seed: u64) -> Self {
        ImageSpec {
            num_classes: 100,
            per_class,
            size: 16,
            noise: 0.45,
            max_shift: 2,
            seed,
        }
    }

    /// Tiny-ImageNet analogue: 200 classes.
    pub fn tiny_imagenet_like(per_class: usize, seed: u64) -> Self {
        ImageSpec {
            num_classes: 200,
            per_class,
            size: 16,
            noise: 0.45,
            max_shift: 2,
            seed,
        }
    }

    pub fn n(&self) -> usize {
        self.num_classes * self.per_class
    }
}

const CHANNELS: usize = 3;
const COARSE: usize = 4;

/// Build one class template: bilinear-upsampled coarse noise field plus a
/// class-coded sinusoid (distinct frequency/phase per class).
fn class_template(spec: &ImageSpec, class: usize, rng: &mut Rng) -> Vec<f32> {
    let s = spec.size;
    let mut coarse = [[[0.0f64; COARSE]; COARSE]; CHANNELS];
    for ch in coarse.iter_mut() {
        for row in ch.iter_mut() {
            for v in row.iter_mut() {
                *v = rng.normal();
            }
        }
    }
    // Class-coded sinusoid parameters.
    let fx = 1.0 + rng.uniform(0.0, 3.0);
    let fy = 1.0 + rng.uniform(0.0, 3.0);
    let phase = rng.uniform(0.0, std::f64::consts::TAU);
    let amp = 0.8;
    let _ = class;

    let mut out = vec![0.0f32; s * s * CHANNELS];
    let scale = (COARSE - 1) as f64 / (s - 1).max(1) as f64;
    for i in 0..s {
        for j in 0..s {
            // Bilinear sample of the coarse grid.
            let fi = i as f64 * scale;
            let fj = j as f64 * scale;
            let (i0, j0) = (fi.floor() as usize, fj.floor() as usize);
            let (i1, j1) = ((i0 + 1).min(COARSE - 1), (j0 + 1).min(COARSE - 1));
            let (di, dj) = (fi - i0 as f64, fj - j0 as f64);
            let wave = amp
                * ((std::f64::consts::TAU * (fx * i as f64 + fy * j as f64) / s as f64) + phase)
                    .sin();
            for c in 0..CHANNELS {
                let g = &coarse[c];
                let v = g[i0][j0] * (1.0 - di) * (1.0 - dj)
                    + g[i1][j0] * di * (1.0 - dj)
                    + g[i0][j1] * (1.0 - di) * dj
                    + g[i1][j1] * di * dj;
                out[(i * s + j) * CHANNELS + c] = (v + wave) as f32;
            }
        }
    }
    out
}

/// Generate the dataset.  Classes are interleaved (sample k has label
/// `k % num_classes`) so any contiguous split is class-balanced.
pub fn generate(spec: &ImageSpec) -> Dataset {
    assert!(spec.size >= 4, "image too small");
    let mut root = Rng::new(spec.seed);
    let templates: Vec<Vec<f32>> = (0..spec.num_classes)
        .map(|c| {
            let mut trng = root.fork(1000 + c as u64);
            class_template(spec, c, &mut trng)
        })
        .collect();

    let s = spec.size;
    let pix = s * s * CHANNELS;
    let n = spec.n();
    let mut x = vec![0.0f32; n * pix];
    let mut y = vec![0i32; n];
    let mut srng = root.fork(2);
    for k in 0..n {
        let class = k % spec.num_classes;
        y[k] = class as i32;
        let t = &templates[class];
        // Per-sample circular shift + contrast jitter + pixel noise.
        let shift = spec.max_shift as i64;
        let (di, dj) = if shift > 0 {
            (
                srng.range(-shift, shift + 1),
                srng.range(-shift, shift + 1),
            )
        } else {
            (0, 0)
        };
        let contrast = srng.normal_ms(1.0, 0.1);
        let out = &mut x[k * pix..(k + 1) * pix];
        for i in 0..s {
            for j in 0..s {
                let si = (i as i64 + di).rem_euclid(s as i64) as usize;
                let sj = (j as i64 + dj).rem_euclid(s as i64) as usize;
                for c in 0..CHANNELS {
                    let v = t[(si * s + sj) * CHANNELS + c] as f64 * contrast
                        + srng.normal_ms(0.0, spec.noise);
                    out[(i * s + j) * CHANNELS + c] = v as f32;
                }
            }
        }
    }
    Dataset {
        x,
        y: Labels::Int(y),
        feat_shape: vec![s, s, CHANNELS],
        num_classes: spec.num_classes,
        name: format!(
            "images-c{}-pc{}-s{}-seed{}",
            spec.num_classes, spec.per_class, s, spec.seed
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> ImageSpec {
        ImageSpec {
            num_classes: 4,
            per_class: 8,
            size: 8,
            noise: 0.3,
            max_shift: 1,
            seed: 0,
        }
    }

    #[test]
    fn shapes_and_labels() {
        let d = generate(&small_spec());
        assert_eq!(d.n(), 32);
        assert_eq!(d.feat_shape, vec![8, 8, 3]);
        assert_eq!(d.feat_len(), 192);
        match &d.y {
            Labels::Int(y) => {
                assert!(y.iter().all(|&v| (0..4).contains(&v)));
                // Interleaved: first 4 labels are 0, 1, 2, 3.
                assert_eq!(&y[0..4], &[0, 1, 2, 3]);
            }
            _ => panic!("expected int labels"),
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.x, b.x);
        let c = generate(&ImageSpec {
            seed: 1,
            ..small_spec()
        });
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_are_separated() {
        // Same-class samples must be closer (L2) than cross-class ones on
        // average — the learnability property.
        let spec = ImageSpec {
            num_classes: 3,
            per_class: 10,
            size: 8,
            noise: 0.3,
            max_shift: 0,
            seed: 2,
        };
        let d = generate(&spec);
        let f = d.feat_len();
        let ys = match &d.y {
            Labels::Int(y) => y.clone(),
            _ => unreachable!(),
        };
        let dist = |a: usize, b: usize| -> f64 {
            d.x[a * f..(a + 1) * f]
                .iter()
                .zip(&d.x[b * f..(b + 1) * f])
                .map(|(p, q)| ((p - q) * (p - q)) as f64)
                .sum()
        };
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0, 0, 0.0, 0);
        for a in 0..d.n() {
            for b in (a + 1)..d.n() {
                if ys[a] == ys[b] {
                    same += dist(a, b);
                    same_n += 1;
                } else {
                    diff += dist(a, b);
                    diff_n += 1;
                }
            }
        }
        let (same, diff) = (same / same_n as f64, diff / diff_n as f64);
        assert!(
            diff > 1.3 * same,
            "classes not separated: same {same}, diff {diff}"
        );
    }

    #[test]
    fn pixel_stats_are_normalized_scale() {
        let d = generate(&small_spec());
        let mean: f64 = d.x.iter().map(|&v| v as f64).sum::<f64>() / d.x.len() as f64;
        let var: f64 =
            d.x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / d.x.len() as f64;
        assert!(mean.abs() < 0.5, "mean {mean}");
        assert!((0.2..6.0).contains(&var), "var {var}");
    }

    #[test]
    fn presets_have_paper_class_counts() {
        assert_eq!(ImageSpec::cifar10_like(10, 0).num_classes, 10);
        assert_eq!(ImageSpec::cifar100_like(10, 0).num_classes, 100);
        assert_eq!(ImageSpec::tiny_imagenet_like(10, 0).num_classes, 200);
    }

    #[test]
    fn zero_shift_samples_differ_only_by_noise() {
        let spec = ImageSpec {
            max_shift: 0,
            noise: 0.01,
            ..small_spec()
        };
        let d = generate(&spec);
        let f = d.feat_len();
        // Two samples of class 0 (rows 0 and num_classes) nearly equal.
        let a = &d.x[0..f];
        let b = &d.x[4 * f..5 * f];
        let dist: f64 = a
            .iter()
            .zip(b)
            .map(|(p, q)| ((p - q) * (p - q)) as f64)
            .sum::<f64>()
            / f as f64;
        assert!(dist < 0.2, "dist {dist}");
    }
}
