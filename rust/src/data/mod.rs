//! Data pipeline: dataset containers, seeded batching, and the two
//! experiment dataset families (synthetic Eq. 3 + procedural images).

pub mod dataset;
pub mod images;
pub mod synthetic;

pub use dataset::{Batch, Dataset, EpochBatches, Labels};
pub use images::ImageSpec;
pub use synthetic::SyntheticSpec;
