//! Adaptive request admission: coalesce queued trial requests into
//! batches sized to the observed queue depth, and dispatch them onto
//! the parallel trial engine.
//!
//! The shape mirrors the batch-size adaptation the *training* side of
//! this repo is about, applied to serving: a lone request should not
//! wait for peers (batch of 1, lowest latency), while a burst should be
//! swept into one engine dispatch so trials share the worker pool and
//! the compile cache warm-up instead of queueing head-to-tail
//! (throughput).  The [`BatchController`] adapts multiplicatively —
//! double toward the backlog when the queue runs ahead of the current
//! batch size, halve when it falls behind — the same grow/shrink
//! discipline as AdaBatch-style schedules, bounded by `batch_max`.
//!
//! One dispatcher thread owns the queue: it sleeps until work arrives,
//! adapts the batch size to the depth it wakes to, drains up to one
//! batch, answers cache hits from the (optional) shared
//! [`ResultsCache`], and runs the misses through a [`TrialRunner`] over
//! the shared [`Runtime`].  Each accepted request holds an mpsc sender;
//! connection threads block on their receiver, so slow trials never
//! block the accept loop.  Every counter a load test needs to *observe*
//! the adaptation (batch sizes, adapt events, hits/misses) is exported
//! via [`Admission::stats`] and served at `/stats`.
//!
//! Shutdown is graceful by contract: [`Admission::shutdown`] flips the
//! queue into draining mode — new submissions are rejected (the server
//! answers 503) while everything already admitted runs to completion
//! before the dispatcher exits.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::config::rescache::ResultsCache;
use crate::engine::{TrialRunner, TrialSpec};
use crate::metrics::RunRecord;
use crate::pool::lock_unpoisoned;
use crate::runtime::Runtime;

/// Multiplicative queue-depth tracker deciding how many queued requests
/// to coalesce per engine dispatch.
#[derive(Debug)]
pub struct BatchController {
    current: usize,
    max: usize,
    /// Number of times `adapt` actually changed the batch size.
    adapt_events: usize,
    /// Largest batch size ever reached — the load test's witness that
    /// adaptation responded to queue depth.
    max_seen: usize,
}

impl BatchController {
    /// Start at batch size 1 (latency-optimal for an idle service).
    pub fn new(max: usize) -> BatchController {
        BatchController {
            current: 1,
            max: max.max(1),
            adapt_events: 0,
            max_seen: 1,
        }
    }

    pub fn current(&self) -> usize {
        self.current
    }

    pub fn max_seen(&self) -> usize {
        self.max_seen
    }

    pub fn adapt_events(&self) -> usize {
        self.adapt_events
    }

    /// Adapt to an observed queue depth, then return the number of
    /// requests to dispatch now: grow (double, jumping straight to the
    /// backlog if it is even deeper) while the queue runs at least 2x
    /// ahead of the batch size; shrink (halve) when the queue falls
    /// below it.  Depth swings therefore move the batch size in a few
    /// dispatches rather than one request at a time.
    pub fn adapt(&mut self, depth: usize) -> usize {
        let before = self.current;
        if depth >= self.current.saturating_mul(2) {
            self.current = depth.min(self.max);
        } else if depth < self.current {
            self.current = (self.current / 2).max(depth).max(1);
        }
        if self.current != before {
            self.adapt_events += 1;
        }
        self.max_seen = self.max_seen.max(self.current);
        depth.min(self.current)
    }
}

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at `max_queue` — back off and retry.
    QueueFull,
    /// The service is shutting down; no new work is admitted.
    Draining,
}

/// Counter snapshot for `/stats`.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionStats {
    pub queue_depth: usize,
    pub batch_size: usize,
    pub batch_size_max_seen: usize,
    pub adapt_events: usize,
    pub batches_dispatched: usize,
    pub submitted: usize,
    pub rejected: usize,
    pub trials_completed: usize,
    pub trials_failed: usize,
    pub results_hits: usize,
}

type TrialResult = Result<RunRecord, String>;

struct Pending {
    spec: TrialSpec,
    tx: mpsc::Sender<TrialResult>,
}

struct QueueState {
    pending: VecDeque<Pending>,
    controller: BatchController,
    draining: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    wake: Condvar,
    rt: Arc<Runtime>,
    results: Option<ResultsCache>,
    jobs: usize,
    max_queue: usize,
    // Monotonic counters (relaxed: they are diagnostics, not fences).
    batches_dispatched: AtomicUsize,
    submitted: AtomicUsize,
    rejected: AtomicUsize,
    trials_completed: AtomicUsize,
    trials_failed: AtomicUsize,
    results_hits: AtomicUsize,
}

/// The admission queue + its dispatcher thread.
pub struct Admission {
    shared: Arc<Shared>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl Admission {
    /// Start the dispatcher.  `jobs` is the engine budget per dispatch
    /// (0 = all cores), `max_queue` bounds admitted-but-unstarted
    /// requests, `batch_max` caps the adaptive batch size, and
    /// `results` (optional) memoizes finished trials by fingerprint.
    pub fn start(
        rt: Arc<Runtime>,
        jobs: usize,
        max_queue: usize,
        batch_max: usize,
        results: Option<ResultsCache>,
    ) -> Admission {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                pending: VecDeque::new(),
                controller: BatchController::new(batch_max),
                draining: false,
            }),
            wake: Condvar::new(),
            rt,
            results,
            jobs,
            max_queue: max_queue.max(1),
            batches_dispatched: AtomicUsize::new(0),
            submitted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            trials_completed: AtomicUsize::new(0),
            trials_failed: AtomicUsize::new(0),
            results_hits: AtomicUsize::new(0),
        });
        let worker = shared.clone();
        let handle = std::thread::Builder::new()
            .name("divebatch-admission".into())
            .spawn(move || dispatcher_loop(&worker))
            .expect("spawning admission dispatcher");
        Admission {
            shared,
            dispatcher: Mutex::new(Some(handle)),
        }
    }

    /// Queue one trial.  On success the caller blocks on the returned
    /// receiver; the result arrives when the trial's batch completes.
    pub fn submit(&self, spec: TrialSpec) -> Result<mpsc::Receiver<TrialResult>, SubmitError> {
        let mut q = lock_unpoisoned(&self.shared.queue);
        if q.draining {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Draining);
        }
        if q.pending.len() >= self.shared.max_queue {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let (tx, rx) = mpsc::channel();
        q.pending.push_back(Pending { spec, tx });
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.shared.wake.notify_one();
        Ok(rx)
    }

    pub fn stats(&self) -> AdmissionStats {
        let q = lock_unpoisoned(&self.shared.queue);
        AdmissionStats {
            queue_depth: q.pending.len(),
            batch_size: q.controller.current(),
            batch_size_max_seen: q.controller.max_seen(),
            adapt_events: q.controller.adapt_events(),
            batches_dispatched: self.shared.batches_dispatched.load(Ordering::Relaxed),
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            trials_completed: self.shared.trials_completed.load(Ordering::Relaxed),
            trials_failed: self.shared.trials_failed.load(Ordering::Relaxed),
            results_hits: self.shared.results_hits.load(Ordering::Relaxed),
        }
    }

    /// Bound/usage counters of the shared results cache, if one is
    /// configured.
    pub fn results_stats(&self) -> Option<crate::config::rescache::ResultsCacheStats> {
        self.shared.results.as_ref().map(|c| c.stats())
    }

    /// Graceful drain: refuse new work, let everything already admitted
    /// finish, then stop the dispatcher.  Idempotent.
    pub fn shutdown(&self) {
        {
            let mut q = lock_unpoisoned(&self.shared.queue);
            q.draining = true;
        }
        self.shared.wake.notify_all();
        if let Some(handle) = lock_unpoisoned(&self.dispatcher).take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Admission {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn dispatcher_loop(shared: &Shared) {
    loop {
        // Sleep until there is work (or we are draining an empty queue,
        // which is the exit condition).
        let batch: Vec<Pending> = {
            let mut q = lock_unpoisoned(&shared.queue);
            loop {
                if !q.pending.is_empty() {
                    break;
                }
                if q.draining {
                    return;
                }
                q = shared.wake.wait(q).unwrap_or_else(|e| e.into_inner());
            }
            let depth = q.pending.len();
            let take = q.controller.adapt(depth);
            q.pending.drain(..take).collect()
        };
        shared.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        run_batch(shared, batch);
    }
}

/// Answer one coalesced batch: cache hits immediately, misses through
/// one engine dispatch sharing the jobs budget.
fn run_batch(shared: &Shared, batch: Vec<Pending>) {
    let mut answers: Vec<Option<TrialResult>> = Vec::with_capacity(batch.len());
    let mut miss_idx: Vec<usize> = Vec::new();
    for (i, p) in batch.iter().enumerate() {
        let cached = shared
            .results
            .as_ref()
            .and_then(|c| c.load(&p.spec.fingerprint(), 1))
            .and_then(|mut recs| if recs.is_empty() { None } else { Some(recs.remove(0)) });
        match cached {
            Some(rec) => {
                shared.results_hits.fetch_add(1, Ordering::Relaxed);
                answers.push(Some(Ok(rec)));
            }
            None => {
                miss_idx.push(i);
                answers.push(None);
            }
        }
    }

    if !miss_idx.is_empty() {
        let specs: Vec<TrialSpec> = miss_idx.iter().map(|&i| batch[i].spec.clone()).collect();
        let results = TrialRunner::new(shared.jobs).run(&shared.rt, &specs);
        for (&i, res) in miss_idx.iter().zip(results) {
            let answer = match res {
                Ok(rec) => {
                    if let Some(cache) = &shared.results {
                        store_masked(cache, &batch[i].spec, &rec);
                    }
                    Ok(rec)
                }
                Err(e) => Err(e.to_string()),
            };
            answers[i] = Some(answer);
        }
    }

    for (p, answer) in batch.into_iter().zip(answers) {
        let answer = answer.expect("every slot answered");
        match &answer {
            Ok(_) => shared.trials_completed.fetch_add(1, Ordering::Relaxed),
            Err(_) => shared.trials_failed.fetch_add(1, Ordering::Relaxed),
        };
        // A receiver gone away (client hung up) is not an error.
        let _ = p.tx.send(answer);
    }
}

/// Store the wall-clock-masked form of a record (the canonical JSON
/// round-tripped back through `from_json`): serve responses are
/// canonical, so a record served from cache tomorrow must be
/// byte-identical to the one computed today — which its real wall-clock
/// columns would break.
fn store_masked(cache: &ResultsCache, spec: &TrialSpec, rec: &RunRecord) {
    let Ok(masked) = RunRecord::from_json(&rec.to_canonical_json()) else {
        return;
    };
    if let Err(e) = cache.store(&spec.fingerprint(), &[masked]) {
        eprintln!("serve: results cache store failed (serving anyway): {e:#}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_grows_toward_backlog_and_caps() {
        let mut c = BatchController::new(32);
        assert_eq!(c.current(), 1);
        // Burst: depth 8 >= 2*1 -> jump to 8, dispatch all 8.
        assert_eq!(c.adapt(8), 8);
        assert_eq!(c.current(), 8);
        // Deeper burst caps at batch_max.
        assert_eq!(c.adapt(100), 32);
        assert_eq!(c.current(), 32);
        assert_eq!(c.max_seen(), 32);
    }

    #[test]
    fn controller_shrinks_when_the_queue_drains() {
        let mut c = BatchController::new(32);
        c.adapt(32);
        assert_eq!(c.current(), 32);
        // Queue fell to 3: halve (floored at the depth itself).
        assert_eq!(c.adapt(3), 3);
        assert_eq!(c.current(), 16);
        assert_eq!(c.adapt(1), 1);
        assert_eq!(c.current(), 8);
        // Repeated single requests decay back to 1.
        for _ in 0..4 {
            c.adapt(1);
        }
        assert_eq!(c.current(), 1);
        // Depth 1 at size 1: steady state, no event.
        let before = c.adapt_events();
        c.adapt(1);
        assert_eq!(c.adapt_events(), before);
    }

    #[test]
    fn controller_holds_steady_in_band() {
        // Depth within [current, 2*current) neither grows nor shrinks.
        let mut c = BatchController::new(32);
        c.adapt(8);
        let before = c.adapt_events();
        assert_eq!(c.adapt(11), 8, "dispatch is capped by current size");
        assert_eq!(c.current(), 8);
        assert_eq!(c.adapt_events(), before);
    }
}
