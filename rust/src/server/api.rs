//! Request schema + strict validation for the `divebatch serve` API.
//!
//! Turns a POSTed JSON body into [`TrialSpec`]s or a typed
//! [`ApiError`].  Validation is strict in the same way the CLI's policy
//! parser is: **unknown fields are rejected** (with a "did you mean"
//! suggestion reusing the policy registry's edit-distance machinery),
//! wrong JSON types and out-of-range values name the offending field,
//! and unknown models/policies suggest the closest known name.  A
//! malformed request can never panic the server or surface as a 500 —
//! every rejection is a structured 400 the client can act on:
//!
//! ```json
//! {"error":{"code":"unknown_field","field":"epochz","message":"...","did_you_mean":"epochs"}}
//! ```
//!
//! Field names deliberately mirror the CLI flags (`decay_every` <->
//! `--decay-every`), and defaults match the CLI defaults, so a request
//! `{}` plus `model`/`policy` behaves like a bare `divebatch train`
//! invocation — with one exception: the default synthetic dataset is
//! the bounded `n = 2000` draw (a service should not synthesize 20k
//! samples because a client sent the empty object).

use crate::config::{flops_per_sample, DatasetSpec};
use crate::coordinator::policy::registry::suggest;
use crate::coordinator::{LrSchedule, PolicyHandle, PolicyRegistry, SgldConfig, TrainConfig};
use crate::data::{ImageSpec, SyntheticSpec};
use crate::engine::TrialSpec;
use crate::runtime::Runtime;
use crate::util::json::{self, Json};
use crate::ClusterSpec;

/// Top-level fields shared by `/trial` and `/sweep` requests.
const SHARED_KEYS: &[&str] = &[
    "model",
    "dataset",
    "epochs",
    "lr",
    "decay",
    "decay_every",
    "rescale_lr",
    "momentum",
    "weight_decay",
    "clip_norm",
    "max_micro",
    "device_update",
    "adam",
    "sgld_sigma",
    "sim_workers",
    "sim_div_overhead",
    "step_jobs",
];

const TRIAL_ONLY_KEYS: &[&str] = &["policy", "seed"];
const SWEEP_ONLY_KEYS: &[&str] = &["policies", "seeds"];

const SYNTH_KEYS: &[&str] = &["kind", "n", "d", "noise", "seed"];
const IMAGE_KEYS: &[&str] = &["kind", "per_class"];

/// Resource caps — generous for every legitimate experiment in
/// DESIGN.md, small enough that one request cannot occupy the service.
const MAX_EPOCHS: usize = 1000;
const MAX_SYNTH_N: usize = 100_000;
const MAX_SYNTH_D: usize = 4096;
const MAX_PER_CLASS: usize = 1000;
const MAX_SEEDS: usize = 64;
const MAX_POLICIES: usize = 16;
const MAX_SIM_WORKERS: usize = 4096;
const MAX_STEP_JOBS: usize = 256;

/// A structured request rejection: HTTP status + machine-readable code
/// + the field at fault + optionally the name the client probably meant.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub status: u16,
    pub code: &'static str,
    pub field: String,
    pub message: String,
    pub did_you_mean: Option<String>,
    /// Seconds the client should wait before retrying; rendered as a
    /// `Retry-After` response header (not in the body) on every
    /// backpressure 503 (connection cap, queue full, draining).
    pub retry_after: Option<u64>,
}

impl ApiError {
    pub fn new(code: &'static str, field: &str, message: impl Into<String>) -> ApiError {
        ApiError {
            status: 400,
            code,
            field: field.to_string(),
            message: message.into(),
            did_you_mean: None,
            retry_after: None,
        }
    }

    pub fn with_status(mut self, status: u16) -> ApiError {
        self.status = status;
        self
    }

    pub fn with_retry_after(mut self, secs: u64) -> ApiError {
        self.retry_after = Some(secs);
        self
    }

    pub fn with_suggestion(mut self, s: Option<String>) -> ApiError {
        self.did_you_mean = s;
        self
    }

    /// `{"error":{...}}` — the wire shape for both full responses and
    /// per-trial JSONL error lines.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::Str(self.code.to_string())),
            ("field", Json::Str(self.field.clone())),
            ("message", Json::Str(self.message.clone())),
        ];
        if let Some(s) = &self.did_you_mean {
            fields.push(("did_you_mean", Json::Str(s.clone())));
        }
        Json::obj(vec![("error", Json::obj(fields))])
    }
}

/// Decode a request body: UTF-8, then strict JSON (the parser enforces
/// its own depth bound, so deeply nested bodies land here as a parse
/// error, not a stack overflow), then require a top-level object.
pub fn parse_body(bytes: &[u8]) -> Result<Json, ApiError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| ApiError::new("bad_json", "(body)", format!("body is not UTF-8: {e}")))?;
    let parsed = json::parse(text)
        .map_err(|e| ApiError::new("bad_json", "(body)", format!("invalid JSON: {e}")))?;
    if parsed.as_obj().is_none() {
        return Err(ApiError::new(
            "bad_type",
            "(body)",
            "request body must be a JSON object",
        ));
    }
    Ok(parsed)
}

/// `/trial`: one model + one policy spec + one seed -> one [`TrialSpec`].
pub fn parse_trial(body: &Json, rt: &Runtime) -> Result<TrialSpec, ApiError> {
    let allowed: Vec<&str> = SHARED_KEYS.iter().chain(TRIAL_ONLY_KEYS).copied().collect();
    check_keys(body, &allowed)?;
    let model = model_of(body, rt)?;
    let policy = policy_of(req_str(body, "policy")?)?;
    let seed = get_usize(body, "seed", 0)?;
    let cfg = cfg_from_obj(body, &model, policy)?;
    let dataset = dataset_from_obj(body)?;
    Ok(TrialSpec {
        flops_per_sample: flops_per_sample(&model),
        cfg,
        dataset,
        trial: seed as u64,
    })
}

/// `/sweep`: policies x seeds -> specs in (policy-major, seed-minor)
/// order — the same order `divebatch sweep` expands trials in, and the
/// order result lines stream back in.
pub fn parse_sweep(body: &Json, rt: &Runtime) -> Result<Vec<TrialSpec>, ApiError> {
    let allowed: Vec<&str> = SHARED_KEYS.iter().chain(SWEEP_ONLY_KEYS).copied().collect();
    check_keys(body, &allowed)?;
    let model = model_of(body, rt)?;
    let seeds = get_usize(body, "seeds", 3)?;
    in_range(seeds, 1, MAX_SEEDS, "seeds")?;
    let specs_json = body
        .get("policies")
        .ok_or_else(|| ApiError::new("missing_field", "policies", "field \"policies\" is required"))?;
    let Some(arr) = specs_json.as_arr() else {
        return Err(ApiError::new(
            "bad_type",
            "policies",
            "\"policies\" must be an array of policy-spec strings",
        ));
    };
    in_range(arr.len(), 1, MAX_POLICIES, "policies")?;
    let dataset = dataset_from_obj(body)?;

    let mut out = Vec::with_capacity(arr.len() * seeds);
    for (i, p) in arr.iter().enumerate() {
        let Some(spec) = p.as_str() else {
            return Err(ApiError::new(
                "bad_type",
                "policies",
                format!("policies[{i}] must be a string"),
            ));
        };
        let policy = policy_of(spec)?;
        let cfg = cfg_from_obj(body, &model, policy)?;
        for seed in 0..seeds {
            out.push(TrialSpec {
                flops_per_sample: flops_per_sample(&model),
                cfg: cfg.clone(),
                dataset: dataset.clone(),
                trial: seed as u64,
            });
        }
    }
    Ok(out)
}

// ------------------------------------------------------------ pieces

/// Reject any key outside `allowed`, suggesting the closest known one.
fn check_keys(obj: &Json, allowed: &[&str]) -> Result<(), ApiError> {
    let map = obj.as_obj().expect("parse_body guarantees an object");
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(ApiError::new(
                "unknown_field",
                key,
                format!("unknown field {key:?}"),
            )
            .with_suggestion(suggest(key, allowed.iter().copied())));
        }
    }
    Ok(())
}

fn model_of(body: &Json, rt: &Runtime) -> Result<String, ApiError> {
    let name = req_str(body, "model")?;
    if rt.model(name).is_err() {
        let known = rt.manifest.names();
        return Err(ApiError::new(
            "unknown_model",
            "model",
            format!("unknown model {name:?} (known: {})", known.join(", ")),
        )
        .with_suggestion(suggest(name, known.into_iter())));
    }
    Ok(name.to_string())
}

/// Parse a policy spec through the strict registry; its errors already
/// carry their own "did you mean" text.
fn policy_of(spec: &str) -> Result<PolicyHandle, ApiError> {
    PolicyRegistry::builtin()
        .parse(spec)
        .map_err(|e| ApiError::new("bad_policy", "policy", e.to_string()))
}

fn cfg_from_obj(obj: &Json, model: &str, policy: PolicyHandle) -> Result<TrainConfig, ApiError> {
    let epochs = get_usize(obj, "epochs", 40)?;
    in_range(epochs, 1, MAX_EPOCHS, "epochs")?;
    let schedule = LrSchedule {
        base: pos_f64(obj, "lr", 0.1)?,
        decay: pos_f64(obj, "decay", 0.75)?,
        every: in_range(get_usize(obj, "decay_every", 20)?, 1, MAX_EPOCHS, "decay_every")?,
        rescale_with_batch: get_bool(obj, "rescale_lr", false)?,
    };
    let mut cfg = TrainConfig::new(model, policy, schedule, epochs);
    cfg.momentum = nonneg_f64(obj, "momentum", 0.0)?;
    cfg.weight_decay = nonneg_f64(obj, "weight_decay", 0.0)?;
    let clip = nonneg_f64(obj, "clip_norm", 0.0)?;
    cfg.clip_norm = if clip > 0.0 { Some(clip) } else { None };
    let max_micro = get_usize(obj, "max_micro", 0)?;
    cfg.max_micro = if max_micro > 0 { Some(max_micro) } else { None };
    cfg.use_adam = get_bool(obj, "adam", false)?;
    cfg.device_update = get_bool(obj, "device_update", false)?;
    cfg.sgld = SgldConfig {
        sigma: nonneg_f64(obj, "sgld_sigma", 0.0)?,
    };
    cfg.cluster = ClusterSpec {
        workers: in_range(get_usize(obj, "sim_workers", 4)?, 1, MAX_SIM_WORKERS, "sim_workers")?,
        div_overhead: nonneg_f64(obj, "sim_div_overhead", 0.9)?,
        ..ClusterSpec::default()
    };
    cfg.step_jobs = in_range(get_usize(obj, "step_jobs", 0)?, 0, MAX_STEP_JOBS, "step_jobs")?;
    // A service must not write per-epoch progress to its own stderr.
    cfg.verbose = false;
    Ok(cfg)
}

fn dataset_from_obj(body: &Json) -> Result<DatasetSpec, ApiError> {
    let Some(ds) = body.get("dataset") else {
        return Ok(DatasetSpec::Synthetic(SyntheticSpec {
            n: 2000,
            d: 512,
            noise: 0.1,
            seed: 1000,
        }));
    };
    if ds.as_obj().is_none() {
        return Err(ApiError::new(
            "bad_type",
            "dataset",
            "\"dataset\" must be an object with a \"kind\" field",
        ));
    }
    let kind = req_str_at(ds, "dataset.kind", "kind")?;
    match kind {
        "synthetic" => {
            check_keys_at(ds, SYNTH_KEYS, "dataset")?;
            Ok(DatasetSpec::Synthetic(SyntheticSpec {
                n: in_range(get_usize(ds, "n", 2000)?, 1, MAX_SYNTH_N, "dataset.n")?,
                d: in_range(get_usize(ds, "d", 512)?, 1, MAX_SYNTH_D, "dataset.d")?,
                noise: nonneg_f64(ds, "noise", 0.1)?,
                seed: get_usize(ds, "seed", 1000)? as u64,
            }))
        }
        "cifar10" | "cifar100" | "tin" => {
            check_keys_at(ds, IMAGE_KEYS, "dataset")?;
            let per_class =
                in_range(get_usize(ds, "per_class", 100)?, 1, MAX_PER_CLASS, "dataset.per_class")?;
            Ok(DatasetSpec::Images(match kind {
                "cifar10" => ImageSpec::cifar10_like(per_class, 2000),
                "cifar100" => ImageSpec::cifar100_like(per_class, 3000),
                _ => ImageSpec::tiny_imagenet_like(per_class, 4000),
            }))
        }
        other => Err(ApiError::new(
            "out_of_range",
            "dataset.kind",
            format!("unknown dataset kind {other:?} (synthetic | cifar10 | cifar100 | tin)"),
        )
        .with_suggestion(suggest(other, ["synthetic", "cifar10", "cifar100", "tin"].into_iter()))),
    }
}

fn check_keys_at(obj: &Json, allowed: &[&str], prefix: &str) -> Result<(), ApiError> {
    let map = obj.as_obj().expect("caller checked");
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(ApiError::new(
                "unknown_field",
                &format!("{prefix}.{key}"),
                format!("unknown field {key:?} in {prefix:?}"),
            )
            .with_suggestion(suggest(key, allowed.iter().copied())));
        }
    }
    Ok(())
}

// ----------------------------------------------- typed field accessors

fn req_str<'a>(obj: &'a Json, key: &str) -> Result<&'a str, ApiError> {
    req_str_at(obj, key, key)
}

fn req_str_at<'a>(obj: &'a Json, field: &str, key: &str) -> Result<&'a str, ApiError> {
    let Some(v) = obj.get(key) else {
        return Err(ApiError::new(
            "missing_field",
            field,
            format!("field {field:?} is required"),
        ));
    };
    v.as_str().ok_or_else(|| {
        ApiError::new("bad_type", field, format!("field {field:?} must be a string"))
    })
}

fn get_usize(obj: &Json, key: &str, default: usize) -> Result<usize, ApiError> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| {
            ApiError::new(
                "bad_type",
                key,
                format!("field {key:?} must be a non-negative integer"),
            )
        }),
    }
}

fn get_f64(obj: &Json, key: &str, default: f64) -> Result<f64, ApiError> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| ApiError::new("bad_type", key, format!("field {key:?} must be a number"))),
    }
}

fn get_bool(obj: &Json, key: &str, default: bool) -> Result<bool, ApiError> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v.as_bool().ok_or_else(|| {
            ApiError::new("bad_type", key, format!("field {key:?} must be a boolean"))
        }),
    }
}

/// Finite and > 0.
fn pos_f64(obj: &Json, key: &str, default: f64) -> Result<f64, ApiError> {
    let v = get_f64(obj, key, default)?;
    if !v.is_finite() || v <= 0.0 {
        return Err(ApiError::new(
            "out_of_range",
            key,
            format!("field {key:?} must be a finite number > 0, got {v}"),
        ));
    }
    Ok(v)
}

/// Finite and >= 0.
fn nonneg_f64(obj: &Json, key: &str, default: f64) -> Result<f64, ApiError> {
    let v = get_f64(obj, key, default)?;
    if !v.is_finite() || v < 0.0 {
        return Err(ApiError::new(
            "out_of_range",
            key,
            format!("field {key:?} must be a finite number >= 0, got {v}"),
        ));
    }
    Ok(v)
}

fn in_range(v: usize, lo: usize, hi: usize, field: &str) -> Result<usize, ApiError> {
    if v < lo || v > hi {
        return Err(ApiError::new(
            "out_of_range",
            field,
            format!("field {field:?} must be in {lo}..={hi}, got {v}"),
        ));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(text: &str) -> Json {
        json::parse(text).expect("test JSON")
    }

    #[test]
    fn unknown_field_suggests_the_near_miss() {
        let e = check_keys(&obj(r#"{"epochz": 3}"#), SHARED_KEYS).unwrap_err();
        assert_eq!(e.code, "unknown_field");
        assert_eq!(e.field, "epochz");
        assert_eq!(e.did_you_mean.as_deref(), Some("epochs"));
    }

    #[test]
    fn error_json_shape_is_stable() {
        let e = ApiError::new("out_of_range", "epochs", "too big").with_suggestion(None);
        let j = e.to_json();
        let inner = j.get("error").expect("error envelope");
        assert_eq!(inner.get("code").unwrap().as_str(), Some("out_of_range"));
        assert_eq!(inner.get("field").unwrap().as_str(), Some("epochs"));
        assert!(inner.get("did_you_mean").is_none(), "absent when None");
    }

    #[test]
    fn body_must_be_an_object() {
        assert!(parse_body(b"[1,2,3]").is_err());
        assert!(parse_body(b"not json at all").is_err());
        assert!(parse_body(&[0xff, 0xfe]).is_err());
        assert!(parse_body(b"{}").is_ok());
    }

    #[test]
    fn numeric_fields_reject_wrong_types_and_ranges() {
        let o = obj(r#"{"epochs": "forty"}"#);
        assert_eq!(get_usize(&o, "epochs", 1).unwrap_err().code, "bad_type");
        let o = obj(r#"{"lr": -0.5}"#);
        assert_eq!(pos_f64(&o, "lr", 0.1).unwrap_err().code, "out_of_range");
        assert_eq!(in_range(0, 1, 10, "seeds").unwrap_err().code, "out_of_range");
        assert_eq!(in_range(5, 1, 10, "seeds").unwrap(), 5);
    }

    #[test]
    fn dataset_defaults_and_validation() {
        let ds = dataset_from_obj(&obj("{}")).expect("default dataset");
        match ds {
            DatasetSpec::Synthetic(s) => {
                assert_eq!((s.n, s.d, s.seed), (2000, 512, 1000));
            }
            _ => panic!("default must be synthetic"),
        }
        let e = dataset_from_obj(&obj(r#"{"dataset":{"kind":"synthetik"}}"#)).unwrap_err();
        assert_eq!(e.did_you_mean.as_deref(), Some("synthetic"));
        let e = dataset_from_obj(&obj(r#"{"dataset":{"kind":"synthetic","n":0}}"#)).unwrap_err();
        assert_eq!(e.code, "out_of_range");
    }
}
