//! Training as a service: the `divebatch serve` subsystem.
//!
//! A std-only HTTP/1.1 trial server over the existing engine — no web
//! framework, no async runtime, no new dependencies.  Clients POST
//! trial and sweep requests as JSON; an adaptive admission layer
//! ([`admission`]) coalesces queued requests into engine dispatches
//! sized to the observed queue depth; results stream back as JSONL —
//! one **canonical** [`crate::metrics::RunRecord`] line per trial
//! (byte-identical to what an offline `divebatch train` of the same
//! spec produces, at any `--jobs`/`--step-jobs` level), with typed
//! error objects for failures.  The layers:
//!
//! * [`http`] — request framing with hard caps (head/body size,
//!   timeouts); one request per connection, `Connection: close`.
//! * [`api`] — strict validation: unknown fields get did-you-mean
//!   suggestions, bad values get structured 400s naming the field.
//! * [`admission`] — the adaptive batcher + dispatcher thread feeding
//!   [`crate::engine::TrialRunner`], with an optional shared
//!   [`crate::config::rescache::ResultsCache`] memoizing trials.
//!
//! Concurrency model: the accept loop is single-threaded and
//! non-blocking; each accepted connection takes an
//! [`crate::pool::OwnedSemaphorePermit`] from a `--max-clients`
//! semaphore (or is answered 503 inline) and runs on its own thread,
//! which blocks on its trial's result channel — so slow trials consume
//! connection slots, never the accept loop.  Both shared caches (the
//! runtime's compiled-executable cache and the results cache) are
//! eviction-bounded with hit/miss/eviction counters, all exported at
//! `GET /stats`.
//!
//! Endpoints:
//!
//! * `POST /trial`  — one spec -> one JSONL line (200), or a structured
//!   400/503, or a `trial_failed` error body (500).
//! * `POST /sweep`  — policies x seeds -> a close-delimited JSONL
//!   stream in policy-major, seed-minor order.
//! * `GET /stats`   — admission + cache + server gauges.
//! * `GET /healthz` — liveness.
//!
//! Shutdown is graceful: SIGTERM/SIGINT (or [`ServerHandle::stop`])
//! stops the accept loop, new submissions are refused with 503 while
//! every admitted trial runs to completion, then the process exits 0.
//!
//! Robustness: every backpressure 503 (connection cap, queue full,
//! draining) carries a `Retry-After` header; `--trial-timeout` bounds
//! the `/trial` wait with a 504; and the connection handler hosts the
//! `conn-drop@cN` fault-injection scope (see [`crate::fault`]) so chaos
//! tests can drop exact connections deterministically.

pub mod admission;
pub mod api;
pub mod http;

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::rescache::ResultsCache;
use crate::fault::{self, FaultPoint};
use crate::pool::Semaphore;
use crate::runtime::Runtime;
use crate::util::json::Json;
use admission::{Admission, SubmitError};
use api::ApiError;

/// Process-wide stop flag, set by the SIGTERM/SIGINT handlers.
pub static STOP: AtomicBool = AtomicBool::new(false);

/// Accept-loop poll period while idle (and stop-flag latency bound).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Everything `divebatch serve` is configured by.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 picks a free port).
    pub addr: String,
    /// Artifacts directory (manifest + compiled entries).
    pub artifacts: String,
    /// Engine jobs budget per admission dispatch (0 = all cores).
    pub jobs: usize,
    /// Concurrent connection cap; excess connections are answered 503.
    pub max_clients: usize,
    /// Admitted-but-unstarted request cap; excess submissions get 503.
    pub max_queue: usize,
    /// Upper bound for the adaptive admission batch size.
    pub batch_max: usize,
    /// Executable-cache entry cap (0 = unbounded).
    pub exec_cache_entries: usize,
    /// Executable-cache approximate-bytes cap (0 = unbounded).
    pub exec_cache_bytes: usize,
    /// Results-cache directory; `None` disables trial memoization.
    pub results_dir: Option<String>,
    /// Results-cache entry cap (0 = unbounded).
    pub results_max_entries: usize,
    /// Results-cache byte cap (0 = unbounded).
    pub results_max_bytes: u64,
    /// Per-trial wall-clock budget on `/trial`; a trial still running
    /// when it elapses is answered 504 (the dispatcher finishes it in
    /// the background — results-cache clients see it memoized).
    /// `None` = wait forever (the historical behaviour).
    pub trial_timeout: Option<Duration>,
}

impl ServeConfig {
    /// Defaults matching the `divebatch serve` CLI defaults.
    pub fn new(addr: impl Into<String>, artifacts: impl Into<String>) -> ServeConfig {
        ServeConfig {
            addr: addr.into(),
            artifacts: artifacts.into(),
            jobs: 0,
            max_clients: 64,
            max_queue: 256,
            batch_max: 32,
            exec_cache_entries: 64,
            exec_cache_bytes: 0,
            results_dir: None,
            results_max_entries: 256,
            results_max_bytes: 0,
            trial_timeout: None,
        }
    }
}

/// Shared state every connection thread sees.
struct Ctx {
    rt: Arc<Runtime>,
    admission: Admission,
    clients: Arc<Semaphore>,
    trial_timeout: Option<Duration>,
    /// Monotone accepted-connection counter — the identity the
    /// `conn-drop@cN` fault-injection scope selects on.
    conns: AtomicU64,
}

/// A bound-but-not-yet-running server.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<Ctx>,
    stop: Arc<AtomicBool>,
}

/// Handle to a server running on a background thread (tests, mostly).
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: JoinHandle<Result<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Trigger graceful shutdown and wait for the drain to finish.
    pub fn stop(self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        self.thread
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
}

impl Server {
    /// Load the runtime, install cache bounds, start the admission
    /// dispatcher, and bind the listener.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let rt = Arc::new(Runtime::load(&cfg.artifacts)?);
        rt.set_exec_cache_limits(cfg.exec_cache_entries, cfg.exec_cache_bytes);
        let results = cfg.results_dir.as_ref().map(|dir| {
            ResultsCache::with_limits(dir, cfg.results_max_entries, cfg.results_max_bytes)
        });
        let admission = Admission::start(
            rt.clone(),
            cfg.jobs,
            cfg.max_queue,
            cfg.batch_max,
            results,
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        Ok(Server {
            listener,
            ctx: Arc::new(Ctx {
                rt,
                admission,
                clients: Arc::new(Semaphore::new(cfg.max_clients)),
                trial_timeout: cfg.trial_timeout,
                conns: AtomicU64::new(0),
            }),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// This server's stop flag: setting it makes [`Server::run`] drain
    /// and return within one poll period.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop.  Returns after a graceful drain once the stop flag
    /// (or the process-wide [`STOP`]) is set.
    pub fn run(self) -> Result<()> {
        self.listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) && !STOP.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // Connection sockets must not inherit O_NONBLOCK.
                    let _ = stream.set_nonblocking(false);
                    match self.ctx.clients.try_acquire_owned() {
                        Some(permit) => {
                            let ctx = self.ctx.clone();
                            let mut stream = stream;
                            conns.push(std::thread::spawn(move || {
                                handle_connection(&mut stream, &ctx);
                                drop(permit);
                            }));
                        }
                        None => {
                            let mut stream = stream;
                            respond_error(
                                &mut stream,
                                &ApiError::new(
                                    "too_many_clients",
                                    "(server)",
                                    "connection limit reached; retry",
                                )
                                .with_status(503)
                                .with_retry_after(1),
                            );
                        }
                    }
                    conns.retain(|h| !h.is_finished());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    conns.retain(|h| !h.is_finished());
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        }
        // Graceful drain: stop accepting, refuse new submissions while
        // everything already admitted runs to completion, then wait for
        // connection threads to finish writing their responses.
        drop(self.listener);
        self.ctx.admission.shutdown();
        for h in conns {
            let _ = h.join();
        }
        Ok(())
    }

    /// Bind + run on a background thread; returns once the listener is
    /// accepting.  In-process integration tests drive the server
    /// through this.
    pub fn spawn(cfg: ServeConfig) -> Result<ServerHandle> {
        let server = Server::bind(cfg)?;
        let addr = server.local_addr()?;
        let stop = server.stop_flag();
        let thread = std::thread::Builder::new()
            .name("divebatch-serve".into())
            .spawn(move || server.run())
            .context("spawning server thread")?;
        Ok(ServerHandle { addr, stop, thread })
    }
}

/// Install SIGTERM/SIGINT handlers that set [`STOP`], so `divebatch
/// serve` drains instead of dying mid-trial.  Raw `signal(2)` through
/// one extern declaration — this repo links no libc crate.
#[cfg(unix)]
pub fn install_signal_handlers() {
    type SigHandler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: SigHandler) -> usize;
    }
    extern "C" fn on_signal(_signum: i32) {
        STOP.store(true, Ordering::SeqCst);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        let _ = signal(SIGINT, on_signal);
        let _ = signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
pub fn install_signal_handlers() {}

// ------------------------------------------------------------ routing

fn handle_connection(stream: &mut TcpStream, ctx: &Ctx) {
    // `conn-drop@cN` injection scope: the selected connection is
    // dropped before any byte is read or written — clients see a reset,
    // exactly like a crashed connection handler — and the permit is
    // still released by the caller (no slot leak).
    let index = ctx.conns.fetch_add(1, Ordering::Relaxed);
    if fault::check(FaultPoint::Conn { index }).is_err() {
        return;
    }
    let req = match http::read_request(stream) {
        Ok(req) => req,
        Err(e) => {
            if e.status != 0 {
                respond_error(
                    stream,
                    &ApiError::new("bad_request", "(http)", e.message).with_status(e.status),
                );
            }
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = Json::obj(vec![("ok", Json::Bool(true))]).to_string();
            let _ = http::write_response(stream, 200, "application/json", body.as_bytes());
        }
        ("GET", "/stats") => {
            let body = stats_json(ctx).to_string();
            let _ = http::write_response(stream, 200, "application/json", body.as_bytes());
        }
        ("POST", "/trial") => handle_trial(stream, ctx, &req.body),
        ("POST", "/sweep") => handle_sweep(stream, ctx, &req.body),
        (_, "/healthz" | "/stats" | "/trial" | "/sweep") => respond_error(
            stream,
            &ApiError::new(
                "method_not_allowed",
                "(http)",
                format!("method {} not allowed on {}", req.method, req.path),
            )
            .with_status(405),
        ),
        (_, path) => respond_error(
            stream,
            &ApiError::new("not_found", "(http)", format!("no route {path:?}"))
                .with_status(404),
        ),
    }
}

fn respond_error(stream: &mut TcpStream, err: &ApiError) {
    let mut body = err.to_json().to_string();
    body.push('\n');
    let retry_after = err.retry_after.map(|s| s.to_string());
    let extra: Vec<(&str, &str)> = match &retry_after {
        Some(s) => vec![("Retry-After", s.as_str())],
        None => Vec::new(),
    };
    let _ = http::write_response_with(
        stream,
        err.status,
        "application/json",
        &extra,
        body.as_bytes(),
    );
}

fn submit_error(kind: SubmitError) -> ApiError {
    match kind {
        SubmitError::QueueFull => {
            ApiError::new("queue_full", "(server)", "admission queue full; retry")
                .with_status(503)
                .with_retry_after(1)
        }
        SubmitError::Draining => ApiError::new("draining", "(server)", "server is shutting down")
            .with_status(503)
            .with_retry_after(1),
    }
}

fn handle_trial(stream: &mut TcpStream, ctx: &Ctx, body: &[u8]) {
    let spec = match api::parse_body(body).and_then(|j| api::parse_trial(&j, &ctx.rt)) {
        Ok(spec) => spec,
        Err(e) => return respond_error(stream, &e),
    };
    let rx = match ctx.admission.submit(spec) {
        Ok(rx) => rx,
        Err(kind) => return respond_error(stream, &submit_error(kind)),
    };
    // Bounded wait when `--trial-timeout` is set: a trial that overruns
    // its budget is answered 504 (the dispatcher still finishes it, so
    // a retried request with a results cache lands a hit).
    let received = match ctx.trial_timeout {
        Some(budget) => match rx.recv_timeout(budget) {
            Ok(r) => Ok(r),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                return respond_error(
                    stream,
                    &ApiError::new(
                        "trial_timeout",
                        "(trial)",
                        format!("trial exceeded the {:.1}s budget", budget.as_secs_f64()),
                    )
                    .with_status(504)
                    .with_retry_after(budget.as_secs().max(1)),
                )
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Err(()),
        },
        None => rx.recv().map_err(|_| ()),
    };
    match received {
        Ok(Ok(rec)) => {
            let mut line = rec.to_canonical_json().to_string();
            line.push('\n');
            let _ = http::write_response(stream, 200, "application/x-ndjson", line.as_bytes());
        }
        Ok(Err(msg)) => respond_error(
            stream,
            &ApiError::new("trial_failed", "(trial)", msg).with_status(500),
        ),
        Err(()) => respond_error(
            stream,
            &ApiError::new("internal", "(server)", "dispatcher unavailable").with_status(500),
        ),
    }
}

fn handle_sweep(stream: &mut TcpStream, ctx: &Ctx, body: &[u8]) {
    let specs = match api::parse_body(body).and_then(|j| api::parse_sweep(&j, &ctx.rt)) {
        Ok(specs) => specs,
        Err(e) => return respond_error(stream, &e),
    };
    // Admit the whole sweep up front: a partial admission would answer
    // with a JSONL stream missing trials, which no client could tell
    // apart from success.  (Receivers of already-admitted trials are
    // simply dropped on failure; the dispatcher's sends go nowhere.)
    let mut rxs = Vec::with_capacity(specs.len());
    for spec in specs {
        match ctx.admission.submit(spec) {
            Ok(rx) => rxs.push(rx),
            Err(kind) => return respond_error(stream, &submit_error(kind)),
        }
    }
    if http::write_stream_head(stream, 200, "application/x-ndjson").is_err() {
        return;
    }
    for rx in rxs {
        let line = match rx.recv() {
            Ok(Ok(rec)) => rec.to_canonical_json().to_string(),
            Ok(Err(msg)) => ApiError::new("trial_failed", "(trial)", msg).to_json().to_string(),
            Err(_) => ApiError::new("internal", "(server)", "dispatcher unavailable")
                .to_json()
                .to_string(),
        };
        if stream.write_all(line.as_bytes()).is_err() || stream.write_all(b"\n").is_err() {
            return; // client hung up; remaining results are dropped
        }
        let _ = stream.flush();
    }
}

/// The `/stats` document: server gauges + admission counters + both
/// cache services' bound/usage counters.
fn stats_json(ctx: &Ctx) -> Json {
    let n = |v: usize| Json::Num(v as f64);
    let a = ctx.admission.stats();
    let e = ctx.rt.exec_cache_stats();
    let server = Json::obj(vec![
        ("max_clients", n(ctx.clients.capacity())),
        (
            "active_clients",
            n(ctx.clients.capacity() - ctx.clients.available()),
        ),
    ]);
    let admission = Json::obj(vec![
        ("queue_depth", n(a.queue_depth)),
        ("batch_size", n(a.batch_size)),
        ("batch_size_max_seen", n(a.batch_size_max_seen)),
        ("adapt_events", n(a.adapt_events)),
        ("batches_dispatched", n(a.batches_dispatched)),
        ("submitted", n(a.submitted)),
        ("rejected", n(a.rejected)),
        ("trials_completed", n(a.trials_completed)),
        ("trials_failed", n(a.trials_failed)),
        ("results_hits", n(a.results_hits)),
    ]);
    let exec_cache = Json::obj(vec![
        ("entries", n(e.entries)),
        ("bytes", n(e.bytes)),
        ("hits", n(e.hits)),
        ("misses", n(e.misses)),
        ("evictions", n(e.evictions)),
        ("max_entries", n(e.max_entries)),
        ("max_bytes", n(e.max_bytes)),
    ]);
    let results_cache = match ctx.admission.results_stats() {
        None => Json::Null,
        Some(r) => Json::obj(vec![
            ("entries", n(r.entries)),
            ("bytes", Json::Num(r.bytes as f64)),
            ("hits", n(r.hits)),
            ("misses", n(r.misses)),
            ("stores", n(r.stores)),
            ("evictions", n(r.evictions)),
            ("max_entries", n(r.max_entries)),
            ("max_bytes", Json::Num(r.max_bytes as f64)),
        ]),
    };
    Json::obj(vec![
        ("server", server),
        ("admission", admission),
        ("exec_cache", exec_cache),
        ("results_cache", results_cache),
    ])
}
