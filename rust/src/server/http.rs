//! Minimal HTTP/1.1 framing for `divebatch serve` — std only.
//!
//! This is deliberately not a general web server: it implements exactly
//! the slice of HTTP/1.1 the trial API needs (request-line + headers +
//! `Content-Length` bodies in; fixed responses or close-delimited JSONL
//! streams out), with hard caps everywhere a client could make us
//! allocate:
//!
//! * request head (request-line + headers) is capped at
//!   [`MAX_HEAD_BYTES`] — longer heads are a 431;
//! * bodies require `Content-Length` and are capped at
//!   [`MAX_BODY_BYTES`] — larger declared or actual bodies are a 413;
//! * `Transfer-Encoding: chunked` is rejected (411) rather than parsed;
//! * every connection gets read/write timeouts so a stalled client
//!   cannot pin a connection slot forever.
//!
//! Responses always send `Connection: close`: one request per
//! connection keeps framing trivial and matches the trial-submission
//! usage pattern (a client POSTs work and reads results to EOF).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Cap on the request head (request-line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Cap on request bodies — far above any legitimate sweep request.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Per-connection socket timeout (both directions).
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// A parsed request: method + path + lowercased headers + raw body.
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a (lowercase) header name, if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read.  `status` is what we answer with.
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError {
            status,
            message: message.into(),
        }
    }
}

/// Read and frame one request from `stream`.
///
/// `Err` carries the status to answer with; an `Err` with status 0
/// means the peer vanished (nothing useful to write back).
pub fn read_request(stream: &mut TcpStream) -> Result<Request, HttpError> {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));

    // Accumulate until the blank line ending the head; bytes past it
    // are the start of the body.
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() >= MAX_HEAD_BYTES {
            return Err(HttpError::new(431, "request head too large"));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| HttpError::new(0, format!("read: {e}")))?;
        if n == 0 {
            return Err(HttpError::new(0, "connection closed mid-request"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(HttpError::new(400, "malformed request line"));
    }

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(HttpError::new(400, format!("malformed header {line:?}")));
        };
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    if headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && !v.eq_ignore_ascii_case("identity"))
    {
        return Err(HttpError::new(411, "chunked bodies unsupported; send Content-Length"));
    }

    let content_length = match headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.as_str())
    {
        None => 0usize,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::new(400, format!("bad Content-Length {v:?}")))?,
    };
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError::new(413, "request body too large"));
    }

    // Body: whatever followed the head in `buf`, then read the rest.
    let mut body: Vec<u8> = buf[head_end + 4..].to_vec();
    if body.len() > content_length {
        return Err(HttpError::new(400, "body longer than Content-Length"));
    }
    while body.len() < content_length {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| HttpError::new(0, format!("read body: {e}")))?;
        if n == 0 {
            return Err(HttpError::new(400, "connection closed mid-body"));
        }
        body.extend_from_slice(&chunk[..n]);
        if body.len() > content_length {
            return Err(HttpError::new(400, "body longer than Content-Length"));
        }
    }

    Ok(Request {
        method,
        path,
        headers,
        body,
    })
}

/// Offset of the `\r\n\r\n` head terminator, if complete.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Reason phrase for the statuses this server emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response and flush it.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_response_with(stream, status, content_type, &[], body)
}

/// [`write_response`] with extra response headers (name, value) — the
/// overload the 503 paths use to attach `Retry-After`.
pub fn write_response_with(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        status_text(status),
        body.len(),
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("Connection: close\r\n\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Start a close-delimited streaming response (no `Content-Length`):
/// the caller writes body lines and signals the end by closing the
/// connection.  Used for sweep JSONL streams, where results are written
/// as trials finish.
pub fn write_stream_head(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nConnection: close\r\n\r\n",
        status_text(status),
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(16));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n"), None);
        assert_eq!(find_head_end(b""), None);
    }

    #[test]
    fn status_texts_cover_emitted_codes() {
        for s in [200, 400, 404, 405, 411, 413, 431, 500, 503, 504] {
            assert_ne!(status_text(s), "Unknown", "status {s} needs a phrase");
        }
    }
}
