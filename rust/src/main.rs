//! `divebatch` — the training launcher (L3 entrypoint).
//!
//! Subcommands:
//!
//! * `list`                      — show manifest models + experiment presets
//! * `policies`                  — list batch-size policies + spec grammar
//! * `train <model> [opts]`      — one training run with an explicit policy
//! * `sweep <model> [opts]`      — cross policies x seeds through the
//!   parallel trial engine (`--jobs N`, 0 = all cores)
//! * `preset <id> [opts]`        — run a DESIGN.md §5 experiment preset
//!
//! Multi-trial work (`train --trials K --jobs N`, `sweep`) fans trials
//! across a scoped worker pool over one shared runtime/compile cache
//! ([`divebatch::engine`]); records are identical at any `--jobs` level
//! (wall-clock columns measure contended time under parallelism — use
//! `--jobs 1` when they matter).  The simulated-cluster scenario is per
//! run: `--sim-workers` / `--sim-div-overhead` (paper testbed: 4 / 0.9).
//!
//! Policies are resolved through the [`divebatch::PolicyRegistry`]: specs
//! are `[wrapper/...]base` segments with `key=value` params (leftmost
//! wrapper outermost), e.g. `divebatch:m0=128,delta=1,mmax=4096` or
//! `warmup:epochs=5,m=64/divebatch:m0=128,mmax=4096`.  Parsing is strict:
//! unknown policies and parameters are rejected with a "did you mean"
//! suggestion.  Adding a policy is one file + one registry registration —
//! this launcher does not change.
//!
//! Examples:
//!
//! ```bash
//! divebatch list
//! divebatch policies
//! divebatch train logreg512 --policy divebatch:m0=128,delta=1,mmax=4096 \
//!     --dataset synthetic --epochs 40 --lr 16 --rescale-lr
//! divebatch train logreg512 --policy clamp:min=64,max=1024/divebatch:m0=128,mmax=4096
//! divebatch sweep logreg512 --seeds 5 --jobs 0 \
//!     --policies "sgd:m=128;adabatch:m0=128,mmax=4096;divebatch:m0=128,delta=1,mmax=4096"
//! divebatch preset fig1-convex --scale quick --out runs/fig1
//! ```

use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Result};

use divebatch::config::presets::{preset, preset_ids, Scale};
use divebatch::config::{flops_per_sample, DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, PolicyHandle, PolicyRegistry, TrainConfig};
use divebatch::data::{ImageSpec, SyntheticSpec};
use divebatch::engine::{sweep_fingerprint, SweepJournal, TrialRunner, TrialSpec};
use divebatch::util::args::{ArgSpec, Args};
use divebatch::util::plot::{render, Series};
use divebatch::util::stats;
use divebatch::util::table::{pm, Table};
use divebatch::{ClusterSpec, Runtime};

fn main() {
    // `DIVEBATCH_FAULTS` installs a process-wide fault-injection plan
    // before any subsystem runs (the chaos harness uses this to reach
    // scopes the `--inject` flag is parsed too late for, e.g. the
    // server accept loop).  A malformed plan is a usage error.
    if let Err(e) = divebatch::fault::init_from_env() {
        eprintln!("error: DIVEBATCH_FAULTS: {e}");
        std::process::exit(2);
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("list") => cmd_list(),
        Some("policies") | Some("--list-policies") => cmd_policies(),
        Some("train") => cmd_train(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("preset") => cmd_preset(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("--help") | Some("-h") | None => {
            eprintln!("{}", usage());
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "divebatch — gradient-diversity aware batch-size adaptation (paper repro)\n\n\
     usage: divebatch <list|policies|train|sweep|preset> [options]\n\n\
     subcommands:\n  \
     list                 show manifest models and experiment presets\n  \
     policies             list batch-size policies, wrappers, and the spec grammar\n  \
     train <model>        run one training configuration (see train --help)\n  \
     sweep <model>        cross policies x seeds on the parallel trial engine (see sweep --help)\n  \
     preset <id>          run a paper experiment preset (see preset --help)\n  \
     serve                run the trial server: POST /trial and /sweep, canonical JSONL back (see serve --help)\n"
        .to_string()
}

fn cmd_policies() -> Result<()> {
    println!("{}", PolicyRegistry::builtin().help());
    Ok(())
}

fn cmd_list() -> Result<()> {
    let rt = Runtime::load_default()?;
    println!("platform: {}", rt.platform());
    println!("\nmodels (artifacts/manifest.json):");
    for (name, info) in &rt.manifest.models {
        println!(
            "  {name:<14} P={:<7} ladder={:?} labels={:?} classes={}",
            info.param_count, info.ladder, info.label_dtype, info.num_classes
        );
    }
    println!("\nexperiment presets (DESIGN.md §5):");
    for id in preset_ids() {
        let e = preset(id, Scale::quick()).unwrap();
        println!("  {id:<16} {} ({} arms)", e.title, e.runs.len());
    }
    Ok(())
}

/// Options shared by `train` and `sweep` (dataset, optimization,
/// simulated-cluster scenario, engine jobs).
fn run_opts(s: ArgSpec) -> ArgSpec {
    s.opt("dataset", Some("synthetic"), "synthetic | cifar10 | cifar100 | tin")
        .opt("n", Some("20000"), "synthetic dataset size")
        .opt("per-class", Some("100"), "images per class (image datasets)")
        .opt("epochs", Some("40"), "training epochs")
        .opt("lr", Some("0.1"), "base learning rate")
        .opt("decay", Some("0.75"), "lr step-decay factor")
        .opt("decay-every", Some("20"), "lr step-decay period (epochs)")
        .opt("momentum", Some("0"), "SGD momentum")
        .opt("weight-decay", Some("0"), "L2 weight decay")
        .opt("clip", Some("0"), "global-norm grad clipping (0 = off)")
        .opt("max-micro", Some("0"), "cap planner micro-batch rung (0 = whole ladder)")
        .opt("jobs", Some("0"), "trial-engine worker threads (0 = all cores)")
        .opt(
            "step-jobs",
            Some("0"),
            "step-executor lanes per trial (0 = auto: split the --jobs budget; DIVEBATCH_STEP_JOBS overrides auto)",
        )
        .opt("dim", Some("512"), "synthetic dataset feature dimension")
        .opt("sim-workers", Some("4"), "simulated cluster: data-parallel workers")
        .opt("sim-div-overhead", Some("0.9"), "simulated cluster: per-sample diversity surcharge")
        .opt("sim-heterogeneity", Some("0"), "simulated cluster: per-worker speed spread in [0, 1)")
        .opt("sim-straggler-factor", Some("1"), "simulated cluster: straggler compute multiplier (>= 1)")
        .opt("sim-straggler-prob", Some("0"), "simulated cluster: per-(step,worker) straggler probability")
        .opt("sim-preempt-prob", Some("0"), "simulated cluster: per-(step,worker) preemption probability")
        .opt("sim-fault-seed", Some("0"), "simulated cluster: seed for the deterministic regime draws")
        .opt(
            "inject",
            Some(""),
            "fault-injection plan, e.g. \"trial-panic@t1,io-error@store:2,stall@t0:50ms\" (see the src/fault grammar)",
        )
        .opt("inject-seed", Some("0"), "seed for probabilistic (pN) fault rules")
        .opt("out", Some(""), "write per-trial CSVs under this directory")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("sgld-sigma", Some("0"), "SGLD per-sample grad-noise std (0 = off; boosts diversity)")
        .flag("adam", "use Adam instead of SGD (paper §6 extension)")
        .flag("rescale-lr", "Goyal linear lr<->batch rescaling")
        .flag("device-update", "use the fused on-device update executable")
        .flag("quiet", "suppress per-epoch progress")
}

fn train_spec() -> ArgSpec {
    run_opts(
        ArgSpec::new("divebatch train", "run one training configuration")
            .pos("model", "manifest model name (e.g. logreg512)")
            .opt("policy", None, "policy spec, e.g. divebatch:m0=..,delta=..,mmax=.. or warmup:epochs=..,m=../divebatch:.. (see `divebatch policies`)")
            .opt("trials", Some("1"), "number of seeded trials"),
    )
}

fn sweep_spec() -> ArgSpec {
    run_opts(
        ArgSpec::new(
            "divebatch sweep",
            "cross policies x seeds through the parallel trial engine",
        )
        .pos("model", "manifest model name (e.g. logreg512)")
        .opt(
            "policies",
            None,
            "';'-separated policy specs, e.g. \"sgd:m=128;adabatch:m0=128,mmax=4096;divebatch:m0=128,mmax=4096\"",
        )
        .opt("seeds", Some("3"), "trials per policy (seeds 0..N-1)")
        .opt("jsonl", Some(""), "append one summary line per trial to this JSONL file")
        .opt(
            "journal",
            Some(""),
            "record completed trials to this crash-safe journal (canonical JSONL; resumable)",
        )
        .opt(
            "resume",
            Some(""),
            "resume an interrupted sweep from this journal: validates the spec fingerprint, skips recorded trials, keeps journaling",
        ),
    )
}

fn dataset_from_args(a: &Args) -> Result<DatasetSpec> {
    Ok(match a.str("dataset") {
        "synthetic" => {
            let d = a.usize("dim");
            if d == 0 {
                bail!("--dim must be >= 1");
            }
            DatasetSpec::Synthetic(SyntheticSpec {
                n: a.usize("n"),
                d,
                noise: 0.1,
                seed: 1000,
            })
        }
        "cifar10" => DatasetSpec::Images(ImageSpec::cifar10_like(a.usize("per-class"), 2000)),
        "cifar100" => DatasetSpec::Images(ImageSpec::cifar100_like(a.usize("per-class"), 3000)),
        "tin" => DatasetSpec::Images(ImageSpec::tiny_imagenet_like(a.usize("per-class"), 4000)),
        other => bail!("unknown dataset {other:?}"),
    })
}

fn cfg_from_args(a: &Args, model: &str, policy: PolicyHandle) -> Result<TrainConfig> {
    let schedule = LrSchedule {
        base: a.f64("lr"),
        decay: a.f64("decay"),
        every: a.usize("decay-every"),
        rescale_with_batch: a.flag("rescale-lr"),
    };
    let mut cfg = TrainConfig::new(model, policy, schedule, a.usize("epochs"));
    cfg.momentum = a.f64("momentum");
    cfg.weight_decay = a.f64("weight-decay");
    let clip = a.f64("clip");
    cfg.clip_norm = if clip > 0.0 { Some(clip) } else { None };
    let max_micro = a.usize("max-micro");
    cfg.max_micro = if max_micro > 0 { Some(max_micro) } else { None };
    cfg.use_adam = a.flag("adam");
    cfg.sgld = divebatch::coordinator::SgldConfig {
        sigma: a.f64("sgld-sigma"),
    };
    cfg.device_update = a.flag("device-update");
    let workers = a.usize("sim-workers");
    if workers == 0 {
        bail!("--sim-workers must be >= 1");
    }
    let div_overhead = a.f64("sim-div-overhead");
    if !div_overhead.is_finite() || div_overhead < 0.0 {
        bail!("--sim-div-overhead must be a finite value >= 0 (0 = free instrumentation)");
    }
    let heterogeneity = a.f64("sim-heterogeneity");
    if !heterogeneity.is_finite() || !(0.0..1.0).contains(&heterogeneity) {
        bail!("--sim-heterogeneity must be in [0, 1)");
    }
    let straggler_factor = a.f64("sim-straggler-factor");
    if !straggler_factor.is_finite() || straggler_factor < 1.0 {
        bail!("--sim-straggler-factor must be >= 1");
    }
    let straggler_prob = a.f64("sim-straggler-prob");
    let preempt_prob = a.f64("sim-preempt-prob");
    for (flag, v) in [
        ("--sim-straggler-prob", straggler_prob),
        ("--sim-preempt-prob", preempt_prob),
    ] {
        if !v.is_finite() || !(0.0..=1.0).contains(&v) {
            bail!("{flag} must be a probability in [0, 1]");
        }
    }
    cfg.cluster = ClusterSpec {
        workers,
        div_overhead,
        heterogeneity,
        straggler_factor,
        straggler_prob,
        preempt_prob,
        fault_seed: a.usize("sim-fault-seed") as u64,
    };
    cfg.step_jobs = a.usize("step-jobs");
    cfg.verbose = !a.flag("quiet");
    Ok(cfg)
}

/// Install the `--inject` fault plan for this process, if given.  The
/// env-var plan (`DIVEBATCH_FAULTS`) was installed in `main`; an
/// explicit CLI plan replaces it.
fn install_inject(a: &Args) -> Result<()> {
    let spec = a.str("inject");
    if spec.is_empty() {
        return Ok(());
    }
    let seed = a.usize("inject-seed") as u64;
    let plan = divebatch::fault::FaultPlan::parse(spec, seed)
        .map_err(|e| anyhow::anyhow!("--inject: {e}"))?;
    divebatch::fault::install(Some(std::sync::Arc::new(plan)));
    Ok(())
}

fn cmd_train(tokens: &[String]) -> Result<()> {
    let a = match train_spec().parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    install_inject(&a)?;
    let model = a.positional(0).to_string();
    let Some(policy_spec) = a.get("policy") else {
        bail!("--policy is required (see `divebatch policies` for the grammar)");
    };
    let policy = PolicyRegistry::builtin()
        .parse(policy_spec)
        .map_err(anyhow::Error::new)?;
    let run = RunSpec {
        flops_per_sample: flops_per_sample(&model),
        cfg: cfg_from_args(&a, &model, policy)?,
        dataset: dataset_from_args(&a)?,
        trials: a.usize("trials"),
    };

    let rt = Runtime::load(a.str("artifacts"))?;
    let records = run.run_jobs(&rt, a.usize("jobs"))?;
    print_run_summary(&records);
    let out = a.str("out");
    if !out.is_empty() {
        for (i, r) in records.iter().enumerate() {
            let path = format!("{out}/{}_trial{i}.csv", r.policy_kind);
            r.write_csv(&path)?;
            println!("wrote {path}");
        }
    }
    Ok(())
}

/// `divebatch sweep`: the full policies x seeds cross through one
/// [`TrialRunner`] pool.  Per-trial failures (including panics) are
/// isolated — the rest of the sweep completes and is summarized — and
/// reported collectively through the exit status.
fn cmd_sweep(tokens: &[String]) -> Result<()> {
    let a = match sweep_spec().parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    install_inject(&a)?;
    let model = a.positional(0).to_string();
    let Some(raw_policies) = a.get("policies") else {
        bail!("--policies is required: ';'-separated specs (see `divebatch policies`)");
    };
    let policy_specs: Vec<&str> = raw_policies
        .split(';')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if policy_specs.is_empty() {
        bail!("--policies needs at least one spec (see `divebatch policies`)");
    }
    let seeds = a.usize("seeds");
    if seeds == 0 {
        bail!("--seeds must be >= 1");
    }
    let registry = PolicyRegistry::builtin();
    let dataset = dataset_from_args(&a)?;

    let mut runs = Vec::new();
    let mut trial_specs = Vec::new();
    let mut arm_of = Vec::new();
    for (ai, ps) in policy_specs.iter().enumerate() {
        let policy = registry.parse(ps).map_err(anyhow::Error::new)?;
        let run = RunSpec {
            flops_per_sample: flops_per_sample(&model),
            cfg: cfg_from_args(&a, &model, policy)?,
            dataset: dataset.clone(),
            trials: seeds,
        };
        for spec in TrialSpec::expand(&run) {
            trial_specs.push(spec);
            arm_of.push(ai);
        }
        runs.push(run);
    }

    // Crash-safe journaling: `--journal` records each completed trial's
    // canonical line as it finishes; `--resume` validates the journal
    // against this invocation's spec fingerprint and runs only the
    // trials it is missing.  An uninterrupted `--journal` run and a
    // killed-then-resumed one produce byte-identical journals.
    let fp = sweep_fingerprint(&trial_specs);
    let resume_path = a.str("resume").to_string();
    let journal_path = a.str("journal").to_string();
    if !resume_path.is_empty() && !journal_path.is_empty() && resume_path != journal_path {
        bail!("--journal and --resume name different files; pass just --resume");
    }
    let journal = if !resume_path.is_empty() {
        Some(SweepJournal::resume(Path::new(&resume_path), &fp, trial_specs.len())?)
    } else if !journal_path.is_empty() {
        Some(SweepJournal::create(Path::new(&journal_path), &fp, trial_specs.len())?)
    } else {
        None
    };
    let pending: Vec<(usize, TrialSpec)> = match &journal {
        Some(j) => {
            let done = j.completed();
            if done > 0 {
                eprintln!("resume: {done} of {} trials already journaled", trial_specs.len());
            }
            j.pending()
                .into_iter()
                .map(|i| (i, trial_specs[i].clone()))
                .collect()
        }
        None => trial_specs.iter().cloned().enumerate().collect(),
    };
    let journal = journal.map(Mutex::new);

    let rt = Runtime::load(a.str("artifacts"))?;
    let runner = TrialRunner::new(a.usize("jobs"));
    eprintln!(
        "sweep: {} policies x {} seeds = {} trials ({} pending) on {} workers",
        policy_specs.len(),
        seeds,
        trial_specs.len(),
        pending.len(),
        runner.jobs_for(pending.len())
    );
    let t = divebatch::util::timer::Timer::start();
    let pending_results = runner.run_indexed_with(&rt, &pending, |i, spec, res| match res {
        Ok(rec) => {
            eprintln!("  trial done: {}", spec.label());
            if let Some(j) = &journal {
                let mut j = j.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                if let Err(e) = j.append(i, rec) {
                    eprintln!("  journal write failed for {}: {e:#}", spec.label());
                }
            }
        }
        Err(e) => eprintln!("  trial FAILED: {}: {e}", spec.label()),
    });
    eprintln!("sweep finished in {:.1}s", t.seconds());
    let journal = journal.map(|m| m.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner));

    // Merge journaled records with this invocation's results into the
    // canonical trial order (policy-major, seed-minor).
    let mut slots: Vec<Option<std::result::Result<divebatch::RunRecord, divebatch::TrialError>>> =
        (0..trial_specs.len()).map(|_| None).collect();
    for ((i, _), res) in pending.iter().zip(pending_results) {
        slots[*i] = Some(res);
    }
    if let Some(j) = &journal {
        for (i, slot) in slots.iter_mut().enumerate() {
            if slot.is_none() {
                if let Some(rec) = j.record(i) {
                    *slot = Some(Ok(rec.clone()));
                }
            }
        }
    }

    let mut arms: Vec<Vec<divebatch::RunRecord>> = Vec::new();
    arms.resize_with(runs.len(), Vec::new);
    let mut failures = Vec::new();
    for ((slot, spec), &ai) in slots.into_iter().zip(&trial_specs).zip(&arm_of) {
        match slot.expect("every trial is either journaled or pending") {
            Ok(rec) => arms[ai].push(rec),
            Err(e) => failures.push(format!("{}: {e}", spec.label())),
        }
    }
    if let Some(j) = &journal {
        eprintln!(
            "journal: {} of {} trials recorded at {}",
            j.completed(),
            trial_specs.len(),
            j.path().display()
        );
    }

    let out = a.str("out");
    let jsonl = a.str("jsonl");
    let mut table = Table::new(
        &format!("sweep: {model} ({} seeds/policy)", seeds),
        &["policy", "final acc", "t±1% sim(s)", "end m", "trials"],
    );
    for (ai, records) in arms.iter().enumerate() {
        if records.is_empty() {
            table.row(vec![
                policy_specs[ai].to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "0".into(),
            ]);
            continue;
        }
        print_run_summary(records);
        let finals: Vec<f64> = records.iter().map(|r| r.final_val_acc()).collect();
        let times: Vec<f64> = records
            .iter()
            .filter_map(|r| r.time_within_final(1.0, true))
            .collect();
        table.row(vec![
            records[0].label.clone(),
            pm(stats::mean(&finals), stats::stderr(&finals)),
            if times.is_empty() {
                "-".into()
            } else {
                format!("{:.2}", stats::mean(&times))
            },
            format!("{}", records[0].end_batch_size()),
            format!("{}", records.len()),
        ]);
        for r in records {
            if !out.is_empty() {
                let path = format!("{out}/arm{ai}_{}_seed{}.csv", r.policy_kind, r.seed);
                r.write_csv(&path)?;
            }
            if !jsonl.is_empty() {
                r.append_jsonl(jsonl)?;
            }
        }
    }
    println!("{}", table.render());
    if !out.is_empty() {
        println!("per-trial CSVs under {out}/");
    }

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAILED: {f}");
        }
        bail!(
            "{} of {} trials failed (results above cover the rest)",
            failures.len(),
            trial_specs.len()
        );
    }
    Ok(())
}

fn serve_spec() -> ArgSpec {
    ArgSpec::new(
        "divebatch serve",
        "training as a service: an HTTP trial server with adaptive request batching",
    )
    .opt("addr", Some("127.0.0.1:8080"), "bind address (port 0 picks a free port)")
    .opt(
        "jobs",
        Some("0"),
        "engine worker threads per admission dispatch (0 = all cores; DIVEBATCH_STEP_JOBS still applies inside trials)",
    )
    .opt("max-clients", Some("64"), "concurrent connection cap (excess connections get 503)")
    .opt("max-queue", Some("256"), "admitted-request queue cap (excess submissions get 503)")
    .opt("batch-max", Some("32"), "adaptive admission batch-size ceiling")
    .opt("exec-cache-entries", Some("64"), "executable-cache entry cap (0 = unbounded)")
    .opt("exec-cache-bytes", Some("0"), "executable-cache approx-bytes cap (0 = unbounded)")
    .opt("results-dir", Some(""), "results-cache directory (empty = no trial memoization)")
    .opt("results-max-entries", Some("256"), "results-cache entry cap (0 = unbounded)")
    .opt("results-max-bytes", Some("0"), "results-cache byte cap (0 = unbounded)")
    .opt(
        "trial-timeout",
        Some("0"),
        "per-trial wall-clock budget on /trial in seconds; overruns get 504 (0 = wait forever)",
    )
    .opt("artifacts", Some("artifacts"), "artifacts directory")
}

/// `divebatch serve`: bind, announce the resolved address on stdout
/// (load tests parse that line), then serve until SIGTERM/SIGINT —
/// which drains admitted work before exiting 0.
fn cmd_serve(tokens: &[String]) -> Result<()> {
    let a = match serve_spec().parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let mut cfg = divebatch::ServeConfig::new(a.str("addr"), a.str("artifacts"));
    cfg.jobs = a.usize("jobs");
    cfg.max_clients = a.usize("max-clients");
    cfg.max_queue = a.usize("max-queue");
    cfg.batch_max = a.usize("batch-max");
    cfg.exec_cache_entries = a.usize("exec-cache-entries");
    cfg.exec_cache_bytes = a.usize("exec-cache-bytes");
    let results_dir = a.str("results-dir");
    cfg.results_dir = if results_dir.is_empty() {
        None
    } else {
        Some(results_dir.to_string())
    };
    cfg.results_max_entries = a.usize("results-max-entries");
    cfg.results_max_bytes = a.usize("results-max-bytes") as u64;
    let trial_timeout = a.usize("trial-timeout");
    cfg.trial_timeout = if trial_timeout > 0 {
        Some(std::time::Duration::from_secs(trial_timeout as u64))
    } else {
        None
    };

    divebatch::server::install_signal_handlers();
    let server = divebatch::Server::bind(cfg)?;
    let addr = server.local_addr()?;
    println!("serving on {addr}");
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    server.run()?;
    eprintln!("serve: drained, exiting");
    Ok(())
}

fn preset_spec() -> ArgSpec {
    ArgSpec::new("divebatch preset", "run a paper experiment preset")
        .pos("id", "preset id (divebatch list)")
        .opt("scale", Some("quick"), "quick | bench | paper")
        .opt("jobs", Some("0"), "trial-engine worker threads (0 = all cores)")
        .opt(
            "step-jobs",
            Some("0"),
            "step-executor lanes per trial (0 = auto: split the --jobs budget)",
        )
        .opt("out", Some(""), "write per-trial CSVs under this directory")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .flag("quiet", "suppress per-epoch progress")
}

fn cmd_preset(tokens: &[String]) -> Result<()> {
    let a = match preset_spec().parse_tokens(tokens) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let scale = match a.str("scale") {
        "quick" => Scale::quick(),
        "bench" => Scale::bench(),
        "paper" => Scale::paper(),
        other => bail!("unknown scale {other:?}"),
    };
    let id = a.positional(0);
    let Some(exp) = preset(id, scale) else {
        bail!("unknown preset {id:?}; see `divebatch list`");
    };
    println!("== {} ==", exp.title);
    let rt = Runtime::load(a.str("artifacts"))?;
    let mut acc_series = Vec::new();
    let mut all_records = Vec::new();
    for mut run in exp.runs {
        run.cfg.verbose = !a.flag("quiet");
        run.cfg.step_jobs = a.usize("step-jobs");
        let records = run.run_jobs(&rt, a.usize("jobs"))?;
        let curve = stats::mean_curve(
            &records.iter().map(|r| r.val_acc_curve()).collect::<Vec<_>>(),
        );
        acc_series.push(Series::new(&records[0].label, curve));
        all_records.push(records);
    }
    for records in &all_records {
        print_run_summary(records);
        let out = a.str("out");
        if !out.is_empty() {
            for (i, r) in records.iter().enumerate() {
                r.write_csv(format!("{out}/{}/{}_trial{i}.csv", exp.id, r.policy_kind))?;
            }
        }
    }
    println!(
        "{}",
        render("validation accuracy (mean over trials)", "epoch", &acc_series, 72, 16)
    );
    Ok(())
}

/// Per-arm summary table.  The time-to-±1% columns report the simulated
/// cluster clock AND the measured wall clock side by side: with a
/// parallel step executor (`--step-jobs`) the measured column now bends
/// with batch size the same way the simulation predicts (run with
/// `--jobs 1` if the wall column matters — contended trials inflate it).
fn print_run_summary(records: &[divebatch::RunRecord]) {
    if records.is_empty() {
        return;
    }
    let mut t = Table::new(
        &records[0].label,
        &[
            "metric",
            "25%",
            "50%",
            "75%",
            "100%",
            "t±1% sim(s)",
            "t±1% wall(s)",
            "end m",
        ],
    );
    let at = |f: f64| -> Vec<f64> { records.iter().map(|r| r.val_acc_at_frac(f)).collect() };
    let time_col = |simulated: bool| -> String {
        let times: Vec<f64> = records
            .iter()
            .filter_map(|r| r.time_within_final(1.0, simulated))
            .collect();
        if times.is_empty() {
            "-".into()
        } else {
            format!("{:.2}", stats::mean(&times))
        }
    };
    t.row(vec![
        "val acc".into(),
        pm(stats::mean(&at(0.25)), stats::stderr(&at(0.25))),
        pm(stats::mean(&at(0.5)), stats::stderr(&at(0.5))),
        pm(stats::mean(&at(0.75)), stats::stderr(&at(0.75))),
        pm(stats::mean(&at(1.0)), stats::stderr(&at(1.0))),
        time_col(true),
        time_col(false),
        format!("{}", records[0].end_batch_size()),
    ]);
    println!("{}", t.render());
}
