//! Deterministic mutation fuzzer for the HLO text parser and lowerer.
//!
//! Seeds from the committed fixture corpus (`tests/fixtures/artifacts/`),
//! applies small textual mutations, and feeds each result to the interp
//! backend's compile path.  The invariant under test: malformed input may
//! be *rejected* (`Err`) but must never panic.  Compilation only — a
//! mutated `while` body need not terminate, so nothing is executed.
//!
//! No external fuzzing dependency: the mutation engine is the crate's own
//! deterministic [`Rng`], so any failure reproduces exactly from
//! `--seed`/`--iters`.  On startup the unmutated corpus must compile, so
//! the binary doubles as a fixture-validity check.
//!
//! Usage: `hlo_fuzz [--iters N] [--seed S] [--verbose]`

use divebatch::util::rng::Rng;
use std::path::PathBuf;

/// Grammar fragments spliced into mutated modules so the parser's attribute
/// paths (conv windows, while bodies, slices, batched dots) see adversarial
/// input even when a byte flip alone would miss them.
const DICT: &[&str] = &[
    "f32[",
    "s32[",
    "pred[]",
    "while(",
    "condition=region_0.1",
    "body=region_0.1",
    "convolution(",
    "window={size=3x3 pad=1_1x1_1}",
    "dim_labels=b01f_01io->b01f",
    "feature_group_count=3",
    "batch_group_count=2",
    "dynamic_slice_sizes={1,4}",
    "lhs_batch_dims={0}",
    "rhs_contracting_dims={1}",
    "slice={[0:4],[1:3:2]}",
    "padding=1_1x0_2",
    "to_apply=",
    "/*index=7*/",
    "ROOT ",
    "tuple(",
    "->",
    "%",
];

/// Skip mutants whose declared shapes multiply out past this many elements.
/// Lowering allocates index maps proportional to declared shape sizes; the
/// guard keeps a lucky digit merge from turning the fuzz loop into an OOM
/// test.  Everything under the cap must still compile or reject cleanly.
const MAX_FUZZ_ELEMENTS: u64 = 1 << 22;

struct FuzzStats {
    compiled: u64,
    rejected: u64,
    skipped: u64,
}

fn corpus_dir() -> PathBuf {
    PathBuf::from(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/artifacts"
    ))
}

/// Every `.hlo.txt` under the fixture artifact tree, sorted by path so the
/// fuzz sequence is independent of directory iteration order.
fn load_corpus() -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut stack = vec![corpus_dir()];
    while let Some(dir) = stack.pop() {
        let Ok(rd) = std::fs::read_dir(&dir) else {
            continue;
        };
        for entry in rd.flatten() {
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.to_string_lossy().ends_with(".hlo.txt") {
                if let Ok(text) = std::fs::read_to_string(&path) {
                    out.push((path.display().to_string(), text));
                }
            }
        }
    }
    out.sort();
    out
}

/// Textual pre-filter: scan `[dims]` groups (pure digits/commas/spaces) and
/// reject the mutant if any declared shape exceeds [`MAX_FUZZ_ELEMENTS`].
/// Groups containing anything else (slice specs, layouts) are left to the
/// real parser.
fn declared_elements_ok(bytes: &[u8]) -> bool {
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] != b'[' {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        let mut prod: u64 = 1;
        let mut cur: u64 = 0;
        let mut any_digit = false;
        let mut dims_only = true;
        while j < bytes.len() && bytes[j] != b']' {
            match bytes[j] {
                b'0'..=b'9' => {
                    cur = cur.saturating_mul(10).saturating_add(u64::from(bytes[j] - b'0'));
                    any_digit = true;
                }
                b',' => {
                    if any_digit {
                        prod = prod.saturating_mul(cur.max(1));
                    }
                    cur = 0;
                    any_digit = false;
                }
                b' ' => {}
                _ => {
                    dims_only = false;
                    break;
                }
            }
            j += 1;
        }
        if dims_only {
            if any_digit {
                prod = prod.saturating_mul(cur.max(1));
            }
            if prod > MAX_FUZZ_ELEMENTS {
                return false;
            }
        }
        i = j + 1;
    }
    true
}

fn printable(rng: &mut Rng) -> u8 {
    b' ' + rng.below(95) as u8
}

/// One line-level mutation: delete, duplicate, or swap lines, or splice in
/// a random line from a donor module.
fn mutate_lines(lines: &mut Vec<String>, donor: &str, rng: &mut Rng) {
    if lines.is_empty() {
        return;
    }
    let n = lines.len() as u64;
    match rng.below(4) {
        0 => {
            lines.remove(rng.below(n) as usize);
        }
        1 => {
            let i = rng.below(n) as usize;
            let dup = lines[i].clone();
            lines.insert(i, dup);
        }
        2 => {
            let i = rng.below(n) as usize;
            let j = rng.below(n) as usize;
            lines.swap(i, j);
        }
        _ => {
            let donor_lines: Vec<&str> = donor.lines().collect();
            if !donor_lines.is_empty() {
                let src = donor_lines[rng.below(donor_lines.len() as u64) as usize];
                lines[rng.below(n) as usize] = src.to_string();
            }
        }
    }
}

/// One byte-level mutation: flip a byte to a printable, tweak a digit in
/// place, insert a dictionary token, or truncate the tail.
fn mutate_bytes(bytes: &mut Vec<u8>, rng: &mut Rng) {
    if bytes.is_empty() {
        return;
    }
    let n = bytes.len() as u64;
    match rng.below(5) {
        0 => {
            let i = rng.below(n) as usize;
            bytes[i] = printable(rng);
        }
        1 => {
            let digits: Vec<usize> = bytes
                .iter()
                .enumerate()
                .filter(|(_, b)| b.is_ascii_digit())
                .map(|(i, _)| i)
                .collect();
            if !digits.is_empty() {
                let i = digits[rng.below(digits.len() as u64) as usize];
                bytes[i] = b'0' + rng.below(10) as u8;
            }
        }
        2 | 3 => {
            let tok = DICT[rng.below(DICT.len() as u64) as usize].as_bytes();
            let at = rng.below(n + 1) as usize;
            bytes.splice(at..at, tok.iter().copied());
        }
        _ => {
            bytes.truncate(rng.below(n) as usize);
        }
    }
}

fn mutant(corpus: &[(String, String)], rng: &mut Rng) -> (usize, Vec<u8>) {
    let pick = rng.below(corpus.len() as u64) as usize;
    let donor = &corpus[rng.below(corpus.len() as u64) as usize].1;
    let mut lines: Vec<String> = corpus[pick].1.lines().map(str::to_string).collect();
    for _ in 0..rng.below(3) {
        mutate_lines(&mut lines, donor, rng);
    }
    let mut bytes = lines.join("\n").into_bytes();
    for _ in 0..=rng.below(3) {
        mutate_bytes(&mut bytes, rng);
    }
    (pick, bytes)
}

fn run_fuzz(corpus: &[(String, String)], iters: u64, seed: u64, verbose: bool) -> FuzzStats {
    let client = xla::PjRtClient::interp();
    let mut rng = Rng::new(seed);
    let mut stats = FuzzStats {
        compiled: 0,
        rejected: 0,
        skipped: 0,
    };
    for it in 0..iters {
        let (pick, bytes) = mutant(corpus, &mut rng);
        if !declared_elements_ok(&bytes) {
            stats.skipped += 1;
            if verbose {
                println!("iter {it}: {} -> skipped (oversize shape)", corpus[pick].0);
            }
            continue;
        }
        let text = String::from_utf8_lossy(&bytes);
        let proto = xla::HloModuleProto::from_text(&text);
        let comp = xla::XlaComputation::from_proto(&proto);
        match client.compile(&comp) {
            Ok(_) => {
                stats.compiled += 1;
                if verbose {
                    println!("iter {it}: {} -> compiled", corpus[pick].0);
                }
            }
            Err(e) => {
                stats.rejected += 1;
                if verbose {
                    println!("iter {it}: {} -> rejected: {e}", corpus[pick].0);
                }
            }
        }
    }
    stats
}

fn die(msg: &str) -> ! {
    eprintln!("hlo_fuzz: {msg}");
    std::process::exit(2)
}

fn main() {
    let mut iters: u64 = 500;
    let mut seed: u64 = 0xD1EB;
    let mut verbose = false;
    let mut argv = std::env::args().skip(1);
    while let Some(arg) = argv.next() {
        match arg.as_str() {
            "--iters" => match argv.next().and_then(|v| v.parse().ok()) {
                Some(v) => iters = v,
                None => die("--iters needs an integer"),
            },
            "--seed" => match argv.next().and_then(|v| v.parse().ok()) {
                Some(v) => seed = v,
                None => die("--seed needs an integer"),
            },
            "--verbose" => verbose = true,
            other => die(&format!(
                "unknown argument {other:?} (usage: hlo_fuzz [--iters N] [--seed S] [--verbose])"
            )),
        }
    }

    let corpus = load_corpus();
    if corpus.is_empty() {
        die(&format!("no .hlo.txt corpus under {:?}", corpus_dir()));
    }

    // The pristine corpus must compile — a failure here is a broken fixture,
    // not a fuzz finding.
    let client = xla::PjRtClient::interp();
    for (name, text) in &corpus {
        let comp = xla::XlaComputation::from_proto(&xla::HloModuleProto::from_text(text));
        if let Err(e) = client.compile(&comp) {
            die(&format!("seed corpus entry {name} fails to compile: {e}"));
        }
    }

    let stats = run_fuzz(&corpus, iters, seed, verbose);
    println!(
        "hlo_fuzz: corpus {} files, {iters} iters, seed {seed}: {} compiled, {} rejected, {} skipped (oversize guard)",
        corpus.len(),
        stats.compiled,
        stats.rejected,
        stats.skipped
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The committed fixtures are the seed corpus; all of them must parse
    /// and lower, and a short deterministic fuzz run must come back without
    /// panicking.  CI runs a longer sweep via the release binary.
    #[test]
    fn corpus_compiles_and_short_fuzz_run_is_panic_free() {
        let corpus = load_corpus();
        assert!(
            corpus.len() >= 20,
            "expected the full fixture zoo as seed corpus, got {} files",
            corpus.len()
        );
        let client = xla::PjRtClient::interp();
        for (name, text) in &corpus {
            let comp = xla::XlaComputation::from_proto(&xla::HloModuleProto::from_text(text));
            client
                .compile(&comp)
                .unwrap_or_else(|e| panic!("seed corpus entry {name} fails to compile: {e}"));
        }
        let stats = run_fuzz(&corpus, 64, 7, false);
        assert_eq!(stats.compiled + stats.rejected + stats.skipped, 64);
    }

    #[test]
    fn oversize_guard_trips_on_merged_digit_runs() {
        assert!(declared_elements_ok(b"x = f32[8,16] parameter(0)"));
        assert!(declared_elements_ok(b"slice={[0:99999999]}"));
        assert!(!declared_elements_ok(b"x = f32[99999,99999] parameter(0)"));
        assert!(declared_elements_ok(b"tail = f32[4"));
    }
}
