//! Crash-safe sweep journal: every completed trial's canonical record,
//! one JSONL line each, surviving SIGKILL at any instant.
//!
//! Layout (strictly index-ordered after the header):
//!
//! ```text
//! {"fingerprint":"<sweep fp>","kind":"header","total":N,"version":1}
//! {"index":0,"kind":"trial","record":{...canonical RunRecord...}}
//! {"index":2,"kind":"trial","record":{...}}
//! ```
//!
//! The journal is append-only in content — entries are only ever added
//! — but each append publishes a complete new snapshot via atomic
//! tmp+rename under the shared directory lock
//! ([`crate::util::fslock::DirLock`], the same single-writer discipline
//! the results cache uses).  Two consequences do all the crash-safety
//! work:
//!
//! * **No torn lines, ever.**  A reader (or a resume) sees either the
//!   previous snapshot or the new one, never a half-written line; a
//!   SIGKILL between tmp-write and rename leaves only a stale `.tmp`
//!   that the next writer ignores and replaces.
//! * **Byte-determinism.**  Lines are kept in trial-index order (not
//!   completion order, which varies with `--jobs`), so a journal from a
//!   killed-then-resumed sweep is byte-identical to one from an
//!   uninterrupted run — the property `tests/chaos.rs` gates.
//!
//! Records are journaled in canonical form
//! ([`RunRecord::to_canonical_json`]: wall-clock columns masked), which
//! is exactly what `sweep --jsonl` emits and what the resume path
//! replays — machine-varying timings never enter the byte comparison.
//!
//! `sweep --resume <journal>` validates the header's sweep fingerprint
//! (FNV-1a over every [`TrialSpec`] fingerprint, so any change to the
//! spec grid, policies, seeds, dataset, or cluster regime is caught)
//! and the trial count, then skips completed indices.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::engine::TrialSpec;
use crate::metrics::RunRecord;
use crate::util::fslock::DirLock;
use crate::util::json::{self, Json};

/// Journal format version; bumped on any layout change.
const VERSION: usize = 1;

/// Fingerprint of an entire sweep: FNV-1a over every trial's own
/// fingerprint (which covers config, dataset, cluster spec, and trial
/// id), in spec order.  Resume refuses a journal whose fingerprint does
/// not match the invocation's expanded specs.
pub fn sweep_fingerprint(specs: &[TrialSpec]) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |b: u8| h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    for s in specs {
        for b in s.fingerprint().bytes() {
            mix(b);
        }
        mix(b'|');
    }
    format!("{h:016x}")
}

/// An on-disk sweep journal plus its in-memory completed-record state.
pub struct SweepJournal {
    path: PathBuf,
    fingerprint: String,
    total: usize,
    records: Vec<Option<RunRecord>>,
}

impl SweepJournal {
    /// Start a fresh journal at `path` (truncating any existing file)
    /// and persist the header immediately.
    pub fn create(path: impl Into<PathBuf>, fingerprint: &str, total: usize) -> Result<SweepJournal> {
        let mut j = SweepJournal {
            path: path.into(),
            fingerprint: fingerprint.to_string(),
            total,
            records: vec![None; total],
        };
        j.persist()?;
        Ok(j)
    }

    /// Resume from `path`: load and validate an existing journal, or
    /// start fresh if the file does not exist yet.
    pub fn resume(path: impl Into<PathBuf>, fingerprint: &str, total: usize) -> Result<SweepJournal> {
        let path = path.into();
        if !path.exists() {
            return SweepJournal::create(path, fingerprint, total);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading journal {}", path.display()))?;
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("journal {} is empty", path.display()))?;
        let header = json::parse(header)
            .map_err(|e| anyhow::anyhow!("journal {} header: {e}", path.display()))?;
        if header.get("kind").and_then(|k| k.as_str()) != Some("header") {
            bail!("journal {}: first line is not a header", path.display());
        }
        let version = header.req_usize("version")?;
        if version != VERSION {
            bail!(
                "journal {}: version {version} (this binary writes {VERSION})",
                path.display()
            );
        }
        let got_fp = header.req_str("fingerprint")?;
        if got_fp != fingerprint {
            bail!(
                "journal {}: sweep fingerprint {got_fp} does not match this \
                 invocation's {fingerprint} — the spec grid changed; refusing to resume",
                path.display()
            );
        }
        let got_total = header.req_usize("total")?;
        if got_total != total {
            bail!(
                "journal {}: {got_total} trials recorded, invocation expands to {total}",
                path.display()
            );
        }
        let mut records: Vec<Option<RunRecord>> = vec![None; total];
        for (lineno, line) in lines.enumerate() {
            let entry = json::parse(line)
                .map_err(|e| anyhow::anyhow!("journal {} line {}: {e}", path.display(), lineno + 2))?;
            if entry.get("kind").and_then(|k| k.as_str()) != Some("trial") {
                bail!("journal {} line {}: unknown kind", path.display(), lineno + 2);
            }
            let index = entry.req_usize("index")?;
            if index >= total {
                bail!(
                    "journal {} line {}: index {index} out of range 0..{total}",
                    path.display(),
                    lineno + 2
                );
            }
            let rec = RunRecord::from_json(entry.req("record")?)
                .with_context(|| format!("journal {} line {}", path.display(), lineno + 2))?;
            records[index] = Some(rec);
        }
        Ok(SweepJournal {
            path,
            fingerprint: fingerprint.to_string(),
            total,
            records,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The completed record at `index`, if journaled.
    pub fn record(&self, index: usize) -> Option<&RunRecord> {
        self.records.get(index).and_then(|r| r.as_ref())
    }

    /// How many trials have completed.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.is_some()).count()
    }

    /// Indices still to run, in order.
    pub fn pending(&self) -> Vec<usize> {
        self.records
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// Record trial `index` as completed and publish a new snapshot.
    pub fn append(&mut self, index: usize, record: &RunRecord) -> Result<()> {
        anyhow::ensure!(
            index < self.total,
            "journal append index {index} out of range 0..{}",
            self.total
        );
        self.records[index] = Some(record.clone());
        self.persist()
    }

    /// Render the full journal: header, then completed trials in index
    /// order, canonical records only.
    fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &Json::obj(vec![
                ("fingerprint", Json::Str(self.fingerprint.clone())),
                ("kind", Json::Str("header".to_string())),
                ("total", Json::Num(self.total as f64)),
                ("version", Json::Num(VERSION as f64)),
            ])
            .to_string(),
        );
        out.push('\n');
        for (i, rec) in self.records.iter().enumerate() {
            if let Some(r) = rec {
                out.push_str(
                    &Json::obj(vec![
                        ("index", Json::Num(i as f64)),
                        ("kind", Json::Str("trial".to_string())),
                        ("record", r.to_canonical_json()),
                    ])
                    .to_string(),
                );
                out.push('\n');
            }
        }
        out
    }

    /// Atomic snapshot publish: tmp+rename under the directory lock.
    fn persist(&self) -> Result<()> {
        let dir = self
            .path
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .unwrap_or_else(|| Path::new("."))
            .to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        let _lock = DirLock::acquire(&dir)?;
        let name = self
            .path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("sweep.journal");
        let tmp = dir.join(format!(".{name}.tmp"));
        std::fs::write(&tmp, self.render())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("publishing {}", self.path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochRecord;

    fn tmppath(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("divebatch-journal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir.join("sweep.journal")
    }

    fn record(seed: u64) -> RunRecord {
        let mut r = RunRecord::new("t", "m", "sgd", "d", seed);
        r.epochs.push(EpochRecord {
            epoch: 0,
            batch_size: 8,
            lr: 0.1,
            steps: 4,
            train_loss: 1.0,
            train_acc: 0.5,
            val_loss: 1.0,
            val_acc: 0.5,
            delta_hat: None,
            n_delta: None,
            exact_delta: None,
            wall_s: 7.0, // masked by canonicalization
            sim_s: 0.1,
            cum_wall_s: 7.0,
            cum_sim_s: 0.1,
            mem_mb: 1.0,
            dispatches: 1,
            pad_waste: 0.0,
            par_util: 1.0,
        });
        r
    }

    #[test]
    fn create_append_resume_roundtrip() {
        let path = tmppath("roundtrip");
        let mut j = SweepJournal::create(&path, "fp", 3).unwrap();
        assert_eq!(j.pending(), vec![0, 1, 2]);
        j.append(2, &record(2)).unwrap();
        j.append(0, &record(0)).unwrap();
        drop(j);
        let j = SweepJournal::resume(&path, "fp", 3).unwrap();
        assert_eq!(j.completed(), 2);
        assert_eq!(j.pending(), vec![1]);
        assert_eq!(j.record(0).unwrap().seed, 0);
        assert_eq!(j.record(2).unwrap().seed, 2);
        // Canonical form: wall columns masked on disk.
        assert_eq!(j.record(2).unwrap().epochs[0].wall_s, 0.0);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn bytes_are_completion_order_invariant() {
        let path_a = tmppath("order-a");
        let path_b = tmppath("order-b");
        let mut a = SweepJournal::create(&path_a, "fp", 3).unwrap();
        let mut b = SweepJournal::create(&path_b, "fp", 3).unwrap();
        for i in [0usize, 1, 2] {
            a.append(i, &record(i as u64)).unwrap();
        }
        for i in [2usize, 0, 1] {
            b.append(i, &record(i as u64)).unwrap();
        }
        assert_eq!(
            std::fs::read(&path_a).unwrap(),
            std::fs::read(&path_b).unwrap(),
            "journal bytes must not depend on completion order"
        );
        let _ = std::fs::remove_dir_all(path_a.parent().unwrap());
        let _ = std::fs::remove_dir_all(path_b.parent().unwrap());
    }

    #[test]
    fn resume_validates_fingerprint_total_and_shape() {
        let path = tmppath("validate");
        let mut j = SweepJournal::create(&path, "fp", 2).unwrap();
        j.append(0, &record(0)).unwrap();
        drop(j);
        let e = SweepJournal::resume(&path, "other", 2).unwrap_err();
        assert!(e.to_string().contains("fingerprint"), "{e}");
        let e = SweepJournal::resume(&path, "fp", 5).unwrap_err();
        assert!(e.to_string().contains("trials"), "{e}");
        // Garbage file: typed error, not a panic.
        std::fs::write(&path, "not json\n").unwrap();
        assert!(SweepJournal::resume(&path, "fp", 2).is_err());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn resume_of_missing_file_starts_fresh() {
        let path = tmppath("fresh");
        let j = SweepJournal::resume(&path, "fp", 2).unwrap();
        assert_eq!(j.completed(), 0);
        assert!(path.exists(), "header persisted immediately");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn sweep_fingerprint_is_order_and_content_sensitive() {
        use crate::config::DatasetSpec;
        use crate::coordinator::{LrSchedule, PolicyRegistry, TrainConfig};
        use crate::data::SyntheticSpec;
        let spec = |seed: u64| {
            let policy = PolicyRegistry::builtin().parse("sgd:m=4").unwrap();
            let cfg = TrainConfig::new(
                "m",
                policy,
                LrSchedule {
                    base: 0.1,
                    decay: 0.75,
                    every: 20,
                    rescale_with_batch: false,
                },
                2,
            );
            TrialSpec {
                cfg,
                dataset: DatasetSpec::Synthetic(SyntheticSpec {
                    n: 40,
                    d: 8,
                    noise: 0.1,
                    seed: 1000,
                }),
                flops_per_sample: 1.0,
                trial: seed,
            }
        };
        let a = sweep_fingerprint(&[spec(0), spec(1)]);
        assert_eq!(a, sweep_fingerprint(&[spec(0), spec(1)]));
        assert_ne!(a, sweep_fingerprint(&[spec(1), spec(0)]));
        assert_ne!(a, sweep_fingerprint(&[spec(0)]));
    }
}
