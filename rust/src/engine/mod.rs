//! The parallel trial engine: fan a set of independent training trials
//! across a scoped worker pool sharing one thread-safe [`Runtime`].
//!
//! The paper's headline claim is established by multi-seed, multi-policy
//! sweeps; this module is what makes those sweeps run as fast as the
//! hardware allows.  Design contract:
//!
//! * **Unit of work** — a [`TrialSpec`]: one `(TrainConfig, dataset,
//!   seed)` triple.  Trials are fully independent: each builds its own
//!   dataset draw, RNG streams, optimizer state, and policy instance, so
//!   records are *identical at any `--jobs` level* (wall-clock fields
//!   excepted — see [`crate::metrics::RunRecord::to_canonical_json`]).
//!   One caveat: `AdaptContext::wall_elapsed` exposes real (contended)
//!   time to policies, so a *custom* policy that keys decisions off it
//!   forfeits jobs-invariance for its runs; no built-in policy reads it.
//! * **Scheduling** — a [`TrialRunner`] with a `jobs` knob (0 = all
//!   available cores).  Workers pull trial indices from an atomic
//!   counter; results land in per-index slots, so the returned vector is
//!   always in **spec order** regardless of completion order.
//! * **Isolation** — each trial runs under `catch_unwind`: a panicking
//!   trial reports [`TrialError::Panicked`] and the rest of the sweep
//!   completes (the runtime's locks are poison-tolerant for the same
//!   reason).  Trial errors are captured as [`TrialError::Failed`].
//!
//! The generic core ([`run_indexed`]) is independent of training so the
//! scheduling/ordering/isolation contract is testable without artifacts;
//! [`TrialRunner`] specializes it to `TrialSpec -> RunRecord` over a
//! shared `&Runtime`.  `RunSpec::run_jobs`, the figure/table bench
//! harness, the sweep examples, and the `divebatch train/sweep` CLI all
//! route through here.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::config::{DatasetSpec, RunSpec};
use crate::coordinator::{TrainConfig, Trainer};
use crate::metrics::RunRecord;
use crate::runtime::Runtime;
use crate::util::timer::Profiler;

/// Why one trial of a sweep produced no record.
#[derive(Clone, Debug, PartialEq)]
pub enum TrialError {
    /// The trial returned an error (message carries the anyhow chain).
    Failed(String),
    /// The trial panicked; the payload is the panic message.
    Panicked(String),
}

impl std::fmt::Display for TrialError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrialError::Failed(m) => write!(f, "trial failed: {m}"),
            TrialError::Panicked(m) => write!(f, "trial panicked: {m}"),
        }
    }
}

impl std::error::Error for TrialError {}

/// Number of worker threads the platform offers (>= 1).
pub fn available_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a user-facing jobs knob: 0 means "all available cores".
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        available_jobs()
    } else {
        jobs
    }
}

/// Jobs level from the `DIVEBATCH_JOBS` environment variable, used by
/// the bench harnesses (which have no CLI): unset/invalid = 0 = auto.
pub fn jobs_from_env() -> usize {
    std::env::var("DIVEBATCH_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f` over every item of `items` on up to `jobs` worker threads
/// (0 = all cores), returning results **in item order**.  Each call is
/// panic-isolated; `on_done` fires from worker threads in completion
/// order (progress reporting — item index identifies the trial).
pub fn run_indexed_with<T, R, F, C>(
    items: &[T],
    jobs: usize,
    f: F,
    on_done: C,
) -> Vec<std::result::Result<R, TrialError>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
    C: Fn(usize, &std::result::Result<R, TrialError>) + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = effective_jobs(jobs).min(n).max(1);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<std::result::Result<R, TrialError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i, &items[i])));
                let res = match out {
                    Ok(Ok(r)) => Ok(r),
                    Ok(Err(e)) => Err(TrialError::Failed(format!("{e:#}"))),
                    Err(payload) => Err(TrialError::Panicked(panic_message(payload.as_ref()))),
                };
                on_done(i, &res);
                *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(res);
            });
        }
    });

    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("every index was claimed by a worker")
        })
        .collect()
}

/// [`run_indexed_with`] without a progress callback.
pub fn run_indexed<T, R, F>(
    items: &[T],
    jobs: usize,
    f: F,
) -> Vec<std::result::Result<R, TrialError>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
{
    run_indexed_with(items, jobs, f, |_, _| {})
}

/// One schedulable training trial: a configuration over a dataset draw
/// at one seed.  `trial` selects both the dataset generator offset and
/// the run seed (init params + shuffling stream), exactly as the serial
/// `RunSpec::run` loop always did.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub cfg: TrainConfig,
    pub dataset: DatasetSpec,
    /// fwd+bwd FLOPs per sample — feeds the simulated cluster model.
    pub flops_per_sample: f64,
    /// Trial index = seed.
    pub trial: u64,
}

impl TrialSpec {
    /// The trial at index `trial` of a [`RunSpec`].
    pub fn from_run(run: &RunSpec, trial: u64) -> TrialSpec {
        TrialSpec {
            cfg: run.cfg.clone(),
            dataset: run.dataset.clone(),
            flops_per_sample: run.flops_per_sample,
            trial,
        }
    }

    /// All `run.trials` trials of a [`RunSpec`], in seed order.
    pub fn expand(run: &RunSpec) -> Vec<TrialSpec> {
        (0..run.trials as u64)
            .map(|t| TrialSpec::from_run(run, t))
            .collect()
    }

    /// Progress label, e.g. `DiveBatch (128 - 2048) [seed 3]`.
    pub fn label(&self) -> String {
        format!("{} [seed {}]", self.cfg.policy.label(), self.trial)
    }

    /// Execute this trial on `rt`; returns the record and stage profile.
    pub fn execute_profiled(&self, rt: &Runtime) -> Result<(RunRecord, Profiler)> {
        let (train, val) = self.dataset.build(self.trial);
        let info = rt.model(&self.cfg.model)?;
        let cluster = self
            .cfg
            .cluster
            .model(info.param_count, self.flops_per_sample);
        let mut cfg = self.cfg.clone();
        cfg.seed = self.trial;
        let trainer = Trainer::new(rt, cfg, train, val, cluster)?;
        let out = trainer.run()?;
        Ok((out.record, out.profile))
    }

    /// Execute this trial on `rt`.
    pub fn execute(&self, rt: &Runtime) -> Result<RunRecord> {
        Ok(self.execute_profiled(rt)?.0)
    }
}

/// Fans [`TrialSpec`]s across a worker pool sharing one [`Runtime`].
#[derive(Clone, Copy, Debug)]
pub struct TrialRunner {
    jobs: usize,
}

impl TrialRunner {
    /// `jobs = 0` uses every available core.
    pub fn new(jobs: usize) -> TrialRunner {
        TrialRunner { jobs }
    }

    /// Resolved worker count for `n` trials.
    pub fn jobs_for(&self, n: usize) -> usize {
        effective_jobs(self.jobs).min(n.max(1))
    }

    /// Run every spec; results are in spec order, one per spec, with
    /// per-trial errors/panics captured rather than aborting the sweep.
    pub fn run(
        &self,
        rt: &Runtime,
        specs: &[TrialSpec],
    ) -> Vec<std::result::Result<RunRecord, TrialError>> {
        self.run_with(rt, specs, |_, _| {})
    }

    /// [`TrialRunner::run`] with a completion callback (fired from
    /// worker threads, in completion order) for progress reporting.
    pub fn run_with<C>(
        &self,
        rt: &Runtime,
        specs: &[TrialSpec],
        on_done: C,
    ) -> Vec<std::result::Result<RunRecord, TrialError>>
    where
        C: Fn(&TrialSpec, &std::result::Result<RunRecord, TrialError>) + Sync,
    {
        run_indexed_with(
            specs,
            self.jobs,
            |_, spec| spec.execute(rt),
            |i, res| on_done(&specs[i], res),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_item_order() {
        // Work sized inversely to index so later items finish first.
        let items: Vec<u64> = (0..16).collect();
        let out = run_indexed(&items, 4, |i, &v| {
            std::thread::sleep(std::time::Duration::from_millis(16 - v));
            Ok(i as u64 * 100 + v)
        });
        let got: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        let want: Vec<u64> = (0..16).map(|v| v * 100 + v).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn jobs_level_does_not_change_results() {
        let items: Vec<u64> = (0..40).collect();
        let work = |_: usize, &v: &u64| -> Result<u64> {
            // Deterministic pseudo-work (splitmix-style scramble).
            let mut x = v.wrapping_mul(0x9E3779B97F4A7C15);
            x ^= x >> 30;
            Ok(x)
        };
        let serial: Vec<_> = run_indexed(&items, 1, work);
        for jobs in [2, 4, 8, 0] {
            assert_eq!(run_indexed(&items, jobs, work), serial, "jobs={jobs}");
        }
    }

    #[test]
    fn panics_and_errors_are_isolated_per_item() {
        let items: Vec<usize> = (0..8).collect();
        let out = run_indexed(&items, 4, |_, &v| -> Result<usize> {
            match v {
                3 => panic!("boom at {v}"),
                5 => anyhow::bail!("bad input {v}"),
                _ => Ok(v * 2),
            }
        });
        assert_eq!(out.len(), 8);
        for (i, r) in out.iter().enumerate() {
            match i {
                3 => assert_eq!(*r, Err(TrialError::Panicked("boom at 3".into()))),
                5 => match r {
                    Err(TrialError::Failed(m)) => assert!(m.contains("bad input 5"), "{m}"),
                    other => panic!("expected Failed, got {other:?}"),
                },
                _ => assert_eq!(*r, Ok(i * 2)),
            }
        }
    }

    #[test]
    fn completion_callback_sees_every_item_once() {
        let items: Vec<usize> = (0..10).collect();
        let seen = Mutex::new(vec![0usize; 10]);
        let _ = run_indexed_with(
            &items,
            3,
            |_, &v| Ok(v),
            |i, res| {
                assert!(res.is_ok());
                seen.lock().unwrap()[i] += 1;
            },
        );
        assert_eq!(*seen.lock().unwrap(), vec![1; 10]);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let none: Vec<u8> = Vec::new();
        assert!(run_indexed(&none, 4, |_, _| Ok(())).is_empty());
        let one = [7u8];
        let out = run_indexed(&one, 0, |_, &v| Ok(v));
        assert_eq!(out, vec![Ok(7)]);
        assert!(available_jobs() >= 1);
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
    }

    #[test]
    fn trial_error_display() {
        let f = TrialError::Failed("x".into());
        let p = TrialError::Panicked("y".into());
        assert!(f.to_string().contains("failed"));
        assert!(p.to_string().contains("panicked"));
    }

    #[test]
    fn runner_jobs_resolution() {
        assert_eq!(TrialRunner::new(4).jobs_for(2), 2);
        assert_eq!(TrialRunner::new(2).jobs_for(100), 2);
        assert!(TrialRunner::new(0).jobs_for(64) >= 1);
    }
}
