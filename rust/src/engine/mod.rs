//! The parallel trial engine: fan a set of independent training trials
//! across a worker pool sharing one thread-safe [`Runtime`].
//!
//! The paper's headline claim is established by multi-seed, multi-policy
//! sweeps; this module is what makes those sweeps run as fast as the
//! hardware allows.  The generic scheduling/ordering/isolation core
//! lives in the shared pool layer ([`crate::pool`], re-exported here
//! under its historical names) so trial-level and step-level parallelism
//! ([`crate::coordinator::StepExecutor`]) compose under **one** jobs
//! budget; this module specializes it to `TrialSpec -> RunRecord` over a
//! shared `&Runtime`.  Design contract:
//!
//! * **Unit of work** — a [`TrialSpec`]: one `(TrainConfig, dataset,
//!   seed)` triple.  Trials are fully independent: each builds its own
//!   dataset draw, RNG streams, optimizer state, and policy instance, so
//!   records are *identical at any `--jobs` level* (wall-clock fields
//!   excepted — see [`crate::metrics::RunRecord::to_canonical_json`]).
//!   One caveat: `AdaptContext::wall_elapsed` exposes real (contended)
//!   time to policies, so a *custom* policy that keys decisions off it
//!   forfeits jobs-invariance for its runs; no built-in policy reads it.
//! * **Scheduling** — a [`TrialRunner`] with a `jobs` knob (0 = all
//!   available cores).  Workers pull trial indices from an atomic
//!   counter; results land in per-index slots, so the returned vector is
//!   always in **spec order** regardless of completion order.
//! * **Budget composition** — `jobs` is the budget for the whole sweep:
//!   when fewer trials than budget run concurrently, the spare cores are
//!   handed to each trial's step executor (`step allowance =
//!   budget / trial workers`), so `train --trials 1 --jobs 8` runs one
//!   trial with 8 step lanes while `sweep` with 16 trials runs 8 serial
//!   trials — never 8 x 8 threads.  An explicit `TrainConfig::step_jobs`
//!   or `DIVEBATCH_STEP_JOBS` overrides the allowance
//!   ([`crate::pool::resolve_step_jobs`]).
//! * **Isolation + retry** — each trial attempt runs under
//!   `catch_unwind`: a failing trial never aborts the sweep (the
//!   runtime's locks are poison-tolerant for the same reason).  The
//!   runner's [`RetryPolicy`] classifies each failure: **injected /
//!   transient** failures (a [`crate::fault::FaultError`] anywhere in
//!   the chain, or a panic carrying [`crate::fault::PANIC_PREFIX`]) are
//!   retried up to `max_attempts` with capped exponential backoff on
//!   the runner's [`Clock`]; a **non-injected panic** is presumed a
//!   deterministic compute failure and fails fast after one retry; a
//!   **plain error** is never retried.  A single failed attempt
//!   surfaces as [`TrialError::Failed`] / [`TrialError::Panicked`]
//!   exactly as before; multiple attempts surface as
//!   [`TrialError::Exhausted`] carrying the full attempt history.
//!
//! `RunSpec::run_jobs`, the figure/table bench harness, the sweep
//! examples, and the `divebatch train/sweep` CLI all route through
//! here.  The crash-safe sweep journal lives in [`journal`].

use std::panic::{catch_unwind, AssertUnwindSafe};

use anyhow::Result;

use crate::config::{DatasetSpec, RunSpec};
use crate::coordinator::{TrainConfig, Trainer};
use crate::fault::{self, Clock, FaultPoint, RetryPolicy};
use crate::metrics::RunRecord;
use crate::runtime::Runtime;
use crate::util::timer::Profiler;

pub mod journal;
pub use journal::{sweep_fingerprint, SweepJournal};

pub use crate::pool::JobError as TrialError;
pub use crate::pool::{
    available_jobs, effective_jobs, jobs_from_env, run_indexed, run_indexed_with,
};

/// One schedulable training trial: a configuration over a dataset draw
/// at one seed.  `trial` selects both the dataset generator offset and
/// the run seed (init params + shuffling stream), exactly as the serial
/// `RunSpec::run` loop always did.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub cfg: TrainConfig,
    pub dataset: DatasetSpec,
    /// fwd+bwd FLOPs per sample — feeds the simulated cluster model.
    pub flops_per_sample: f64,
    /// Trial index = seed.
    pub trial: u64,
}

impl TrialSpec {
    /// The trial at index `trial` of a [`RunSpec`].
    pub fn from_run(run: &RunSpec, trial: u64) -> TrialSpec {
        TrialSpec {
            cfg: run.cfg.clone(),
            dataset: run.dataset.clone(),
            flops_per_sample: run.flops_per_sample,
            trial,
        }
    }

    /// All `run.trials` trials of a [`RunSpec`], in seed order.
    pub fn expand(run: &RunSpec) -> Vec<TrialSpec> {
        (0..run.trials as u64)
            .map(|t| TrialSpec::from_run(run, t))
            .collect()
    }

    /// Progress label, e.g. `DiveBatch (128 - 2048) [seed 3]`.
    pub fn label(&self) -> String {
        format!("{} [seed {}]", self.cfg.policy.label(), self.trial)
    }

    /// Stable per-trial results-cache key: the single-trial
    /// [`RunSpec::fingerprint`] of this spec's configuration plus the
    /// trial index (which selects the dataset draw and run seed).  The
    /// serve admission layer memoizes individual trials under this key.
    pub fn fingerprint(&self) -> String {
        let run = RunSpec {
            cfg: self.cfg.clone(),
            dataset: self.dataset.clone(),
            trials: 1,
            flops_per_sample: self.flops_per_sample,
        };
        format!("{}-t{}", run.fingerprint(), self.trial)
    }

    /// Execute this trial on `rt`; returns the record and stage profile.
    /// `step_allowance` is this trial's share of the engine's jobs
    /// budget, applied only when the config leaves `step_jobs` on auto
    /// (see [`crate::pool::resolve_step_jobs`]).
    pub fn execute_profiled_with(
        &self,
        rt: &Runtime,
        step_allowance: usize,
    ) -> Result<(RunRecord, Profiler)> {
        // Trial-boundary injection scope: a `trial-error` rule lands
        // here as a typed transient failure, a `trial-panic` rule
        // panics — both are caught and classified by the retry loop.
        fault::check(FaultPoint::Trial { trial: self.trial }).map_err(anyhow::Error::new)?;
        let (train, val) = self.dataset.build(self.trial);
        let info = rt.model(&self.cfg.model)?;
        let cluster = self
            .cfg
            .cluster
            .model(info.param_count, self.flops_per_sample);
        let mut cfg = self.cfg.clone();
        cfg.seed = self.trial;
        if cfg.step_jobs == 0 {
            cfg.step_jobs = crate::pool::resolve_step_jobs(0, step_allowance);
        }
        let trainer = Trainer::new(rt, cfg, train, val, cluster)?;
        let out = trainer.run()?;
        Ok((out.record, out.profile))
    }

    /// [`TrialSpec::execute_profiled_with`] with a serial step allowance.
    pub fn execute_profiled(&self, rt: &Runtime) -> Result<(RunRecord, Profiler)> {
        self.execute_profiled_with(rt, 1)
    }

    /// Execute this trial on `rt`.
    pub fn execute(&self, rt: &Runtime) -> Result<RunRecord> {
        Ok(self.execute_profiled(rt)?.0)
    }
}

/// Fans [`TrialSpec`]s across a worker pool sharing one [`Runtime`],
/// retrying transient failures per its [`RetryPolicy`].
#[derive(Clone, Debug)]
pub struct TrialRunner {
    jobs: usize,
    retry: RetryPolicy,
    clock: Clock,
}

impl TrialRunner {
    /// `jobs = 0` uses every available core.  Retries default to
    /// [`RetryPolicy::default`] on the real clock.
    pub fn new(jobs: usize) -> TrialRunner {
        TrialRunner {
            jobs,
            retry: RetryPolicy::default(),
            clock: Clock::Real,
        }
    }

    /// Replace the retry policy ([`RetryPolicy::none`] disables retry).
    pub fn with_retry(mut self, retry: RetryPolicy) -> TrialRunner {
        self.retry = retry;
        self
    }

    /// Replace the backoff clock (tests use [`crate::fault::SimClock`]
    /// so retry schedules are asserted, not slept).
    pub fn with_clock(mut self, clock: Clock) -> TrialRunner {
        self.clock = clock;
        self
    }

    /// Resolved worker count for `n` trials.
    pub fn jobs_for(&self, n: usize) -> usize {
        effective_jobs(self.jobs).min(n.max(1))
    }

    /// Per-trial step-executor allowance for `n` trials: the cores of
    /// the jobs budget left over once `jobs_for(n)` trials run
    /// concurrently (>= 1).  Applies only to configs with `step_jobs`
    /// on auto.
    pub fn step_allowance(&self, n: usize) -> usize {
        (effective_jobs(self.jobs) / self.jobs_for(n)).max(1)
    }

    /// Run every spec; results are in spec order, one per spec, with
    /// per-trial errors/panics captured rather than aborting the sweep.
    pub fn run(
        &self,
        rt: &Runtime,
        specs: &[TrialSpec],
    ) -> Vec<std::result::Result<RunRecord, TrialError>> {
        self.run_with(rt, specs, |_, _| {})
    }

    /// [`TrialRunner::run`] with a completion callback (fired from
    /// worker threads, in completion order) for progress reporting.
    pub fn run_with<C>(
        &self,
        rt: &Runtime,
        specs: &[TrialSpec],
        on_done: C,
    ) -> Vec<std::result::Result<RunRecord, TrialError>>
    where
        C: Fn(&TrialSpec, &std::result::Result<RunRecord, TrialError>) + Sync,
    {
        let allowance = self.step_allowance(specs.len());
        run_indexed_with(
            specs,
            self.jobs,
            |_, spec| self.run_one(rt, spec, allowance),
            |i, res| on_done(&specs[i], res),
        )
    }

    /// Like [`TrialRunner::run_with`], but over `(original index,
    /// spec)` pairs — the resume path runs only a sweep's pending
    /// trials while reporting and journaling under their original
    /// indices.
    pub fn run_indexed_with<C>(
        &self,
        rt: &Runtime,
        specs: &[(usize, TrialSpec)],
        on_done: C,
    ) -> Vec<std::result::Result<RunRecord, TrialError>>
    where
        C: Fn(usize, &TrialSpec, &std::result::Result<RunRecord, TrialError>) + Sync,
    {
        let allowance = self.step_allowance(specs.len());
        run_indexed_with(
            specs,
            self.jobs,
            |_, (_, spec)| self.run_one(rt, spec, allowance),
            |i, res| on_done(specs[i].0, &specs[i].1, res),
        )
    }

    /// One trial through the retry loop.  Returns the record, or an
    /// `anyhow` error that *is* a [`TrialError`] (the pool's downcast
    /// passthrough surfaces it unwrapped): the raw failure for a
    /// single attempt, [`TrialError::Exhausted`] with the oldest-first
    /// attempt history otherwise.
    fn run_one(&self, rt: &Runtime, spec: &TrialSpec, allowance: usize) -> Result<RunRecord> {
        let mut history: Vec<TrialError> = Vec::new();
        loop {
            let attempt = history.len() as u32 + 1;
            let out = catch_unwind(AssertUnwindSafe(|| {
                spec.execute_profiled_with(rt, allowance)
            }));
            let (err, allowed) = match out {
                Ok(Ok((record, _))) => return Ok(record),
                Ok(Err(e)) => {
                    // Transient (injected / cache I/O) errors get the
                    // full budget; plain errors are deterministic and
                    // get exactly one attempt.  An injected step-block
                    // panic reaches here as a block-annotated *error*
                    // (the pool caught it), so the prefix check applies
                    // to the message too.
                    let msg = format!("{e:#}");
                    let allowed = if fault::is_injected(&e) || msg.contains(fault::PANIC_PREFIX) {
                        self.retry.max_attempts
                    } else {
                        1
                    };
                    (TrialError::Failed(msg), allowed)
                }
                Err(payload) => {
                    let msg = crate::pool::panic_message(payload.as_ref());
                    // An injected panic is transient; a real compute
                    // panic is presumed deterministic — fail fast after
                    // one retry.
                    let allowed = if msg.contains(fault::PANIC_PREFIX) {
                        self.retry.max_attempts
                    } else {
                        self.retry.max_attempts.min(2)
                    };
                    (TrialError::Panicked(msg), allowed)
                }
            };
            history.push(err);
            if attempt >= allowed {
                let err = if history.len() == 1 {
                    history.pop().expect("one attempt recorded")
                } else {
                    TrialError::Exhausted(history)
                };
                return Err(anyhow::Error::new(err));
            }
            self.clock.sleep(self.retry.backoff(attempt));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trial_error_display() {
        let f = TrialError::Failed("x".into());
        let p = TrialError::Panicked("y".into());
        assert!(f.to_string().contains("failed"));
        assert!(p.to_string().contains("panicked"));
    }

    #[test]
    fn runner_jobs_resolution() {
        assert_eq!(TrialRunner::new(4).jobs_for(2), 2);
        assert_eq!(TrialRunner::new(2).jobs_for(100), 2);
        assert!(TrialRunner::new(0).jobs_for(64) >= 1);
    }

    #[test]
    fn step_allowance_shares_the_budget() {
        // 8-core budget over 2 trials: 2 workers x 4 step lanes.
        assert_eq!(TrialRunner::new(8).step_allowance(2), 4);
        // Saturated by trials: serial steps.
        assert_eq!(TrialRunner::new(4).step_allowance(16), 1);
        // Single trial gets the whole budget.
        assert_eq!(TrialRunner::new(6).step_allowance(1), 6);
        // Degenerate inputs stay >= 1.
        assert!(TrialRunner::new(0).step_allowance(0) >= 1);
    }
}
