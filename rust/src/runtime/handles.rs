//! Per-worker executable-handle caches.
//!
//! The runtime's central cache ([`Runtime::entry`]) is keyed by a
//! formatted string behind an `RwLock` — cheap, but not free: a lookup
//! allocates the key and takes the read lock.  A training run touches at
//! most a handful of entries (the ladder x {train, eval} x {plain,
//! instrumented}), so each step-executor lane owns an [`ExecCache`]: a
//! linear-scan `Vec` of `Arc<Executable>` handles making the per-block
//! lookup allocation- and lock-free after first touch.  Lanes never
//! share one (that is the point), which also means a dynamic-need policy
//! flipping the instrumentation variant between epochs just adds a
//! second entry per rung rather than invalidating anything.

use std::sync::Arc;

use anyhow::Result;

use super::cache::Runtime;
use super::executable::Executable;

/// Lane-local handle cache over the shared [`Runtime`] compile cache.
#[derive(Default)]
pub struct ExecCache {
    /// Train entries keyed by (micro, instrumented).
    train: Vec<((usize, bool), Arc<Executable>)>,
    /// Eval entries keyed by micro.
    eval: Vec<(usize, Arc<Executable>)>,
}

impl ExecCache {
    pub fn new() -> ExecCache {
        ExecCache::default()
    }

    /// Train-step executable for (model, instrumented, micro), fetched
    /// from the runtime once and linear-scanned afterwards.
    pub fn train(
        &mut self,
        rt: &Runtime,
        model: &str,
        instrumented: bool,
        micro: usize,
    ) -> Result<Arc<Executable>> {
        let key = (micro, instrumented);
        if let Some((_, e)) = self.train.iter().find(|(k, _)| *k == key) {
            return Ok(e.clone());
        }
        let e = rt.train_exec(model, instrumented, micro)?;
        self.train.push((key, e.clone()));
        Ok(e)
    }

    /// Eval-step executable for (model, micro).
    pub fn eval(&mut self, rt: &Runtime, model: &str, micro: usize) -> Result<Arc<Executable>> {
        if let Some((_, e)) = self.eval.iter().find(|(k, _)| *k == micro) {
            return Ok(e.clone());
        }
        let e = rt.eval_exec(model, micro)?;
        self.eval.push((micro, e.clone()));
        Ok(e)
    }

    /// Distinct handles held (test/introspection aid).
    pub fn len(&self) -> usize {
        self.train.len() + self.eval.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (hit/miss behaviour over the fixture runtime,
    // shared-Arc identity with the central cache) in
    // rust/tests/step_parallel.rs, which runs everywhere over the
    // committed interpreter fixtures.
}
