//! Typed wrappers around compiled PJRT executables.
//!
//! An [`Executable`] pairs an `xla::PjRtLoadedExecutable` with its manifest
//! [`EntryInfo`]; inputs are validated against the recorded tensor specs
//! before upload, and the tuple output is decomposed into typed results.
//! Three facades cover the interface contract of python/compile/model.py:
//! train (4 outputs), eval (2 outputs), update (2 outputs).
//!
//! `Executable` is immutable after construction apart from its execution
//! counter (an `AtomicU64`), so it is `Send + Sync` and one compiled
//! entry is shared by every trial-engine worker concurrently.  Under the
//! interp backend the wrapped executable is the **compiled register
//! program** (lowered at `Runtime::entry` time, cached by the runtime),
//! and `execute` borrows the input literals built here — the interpreter
//! never clones them; its per-call scratch comes from a reusable buffer
//! arena, so the steady-state allocations of a train step are just these
//! input vectors and the decomposed outputs.

use anyhow::{bail, Context, Result};

use super::manifest::{Dtype, EntryInfo};
use crate::data::Batch;

/// Outputs of a train-step executable (sample sums — see DESIGN.md §2).
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub loss_sum: f64,
    pub correct: f64,
    pub grad_sum: Vec<f32>,
    pub sqnorm_sum: f64,
}

/// Outputs of an eval-step executable.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    pub loss_sum: f64,
    pub correct: f64,
}

/// A compiled entry plus its metadata.
pub struct Executable {
    pub key: String,
    pub info: EntryInfo,
    exe: xla::PjRtLoadedExecutable,
    /// Static batch dimension (rows) for batch entries; 0 for `update`.
    pub micro: usize,
    /// Approximate resident footprint of this compiled entry, used for
    /// the executable cache's byte-bound accounting: 4 bytes per tensor
    /// element across the declared inputs and outputs (the interpreter's
    /// buffer arena is sized by these) plus a fixed program overhead.
    /// An approximation on purpose — cache bounding needs a stable
    /// relative ordering, not exact heap profiling.
    pub approx_bytes: usize,
    /// Cumulative execute() invocations (runtime stats / perf accounting);
    /// atomic so concurrent trials keep the count exact.
    executions: std::sync::atomic::AtomicU64,
}

impl Executable {
    pub fn new(key: String, info: EntryInfo, exe: xla::PjRtLoadedExecutable) -> Executable {
        // Batch entries carry x with leading dim = micro; update has none.
        let micro = info
            .inputs
            .iter()
            .find(|t| t.name == "x")
            .map(|t| t.shape[0])
            .unwrap_or(0);
        let approx_bytes = 1024
            + 4 * info
                .inputs
                .iter()
                .chain(info.outputs.iter())
                .map(|t| t.elements())
                .sum::<usize>();
        Executable {
            key,
            info,
            exe,
            micro,
            approx_bytes,
            executions: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Cumulative execute() invocations so far.
    pub fn executions(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Raw execute over literals; returns the decomposed output tuple.
    fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "entry {}: {} inputs given, {} expected",
                self.key,
                inputs.len(),
                self.info.inputs.len()
            );
        }
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.key))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} outputs", self.key))?;
        let parts = tuple
            .decompose_tuple()
            .with_context(|| format!("decomposing {} output tuple", self.key))?;
        if parts.len() != self.info.outputs.len() {
            bail!(
                "{}: {} outputs, {} expected",
                self.key,
                parts.len(),
                self.info.outputs.len()
            );
        }
        Ok(parts)
    }

    /// `entry <key>: input '<tensor>' has N elements, expected M (shape)` —
    /// every validation failure names the entry and the offending tensor
    /// with its manifest spec, so the error is actionable without a
    /// debugger (the specs come straight from `artifacts/manifest.json`).
    fn input_mismatch(&self, spec: &super::manifest::TensorSpec, got: usize) -> anyhow::Error {
        anyhow::anyhow!(
            "entry {}: input {:?} has {got} elements, expected {} (shape {:?})",
            self.key,
            spec.name,
            spec.elements(),
            spec.shape
        )
    }

    /// Build the standard (params, x, y, w) literal list for a batch entry.
    fn batch_inputs(&self, params: &[f32], batch: &Batch) -> Result<Vec<xla::Literal>> {
        let spec = &self.info.inputs;
        if spec.len() != 4 {
            bail!(
                "entry {}: not a batch entry ({} inputs, expected params/x/y/w)",
                self.key,
                spec.len()
            );
        }
        if params.len() != spec[0].elements() {
            return Err(self.input_mismatch(&spec[0], params.len()));
        }
        if batch.pad_to != self.micro {
            bail!(
                "entry {}: batch padded to {} rows, executable expects {}",
                self.key,
                batch.pad_to,
                self.micro
            );
        }
        if batch.x.len() != spec[1].elements() {
            return Err(self.input_mismatch(&spec[1], batch.x.len()));
        }
        let dims: Vec<i64> = spec[1].shape.iter().map(|&d| d as i64).collect();
        let x = xla::Literal::vec1(&batch.x)
            .reshape(&dims)
            .with_context(|| format!("entry {}: reshaping input \"x\"", self.key))?;
        let y = match spec[2].dtype {
            Dtype::F32 => {
                if batch.y_f32.len() != self.micro {
                    return Err(self.input_mismatch(&spec[2], batch.y_f32.len()));
                }
                xla::Literal::vec1(&batch.y_f32)
            }
            Dtype::S32 => {
                if batch.y_i32.len() != self.micro {
                    return Err(self.input_mismatch(&spec[2], batch.y_i32.len()));
                }
                xla::Literal::vec1(&batch.y_i32)
            }
        };
        if batch.w.len() != spec[3].elements() {
            return Err(self.input_mismatch(&spec[3], batch.w.len()));
        }
        let w = xla::Literal::vec1(&batch.w);
        Ok(vec![xla::Literal::vec1(params), x, y, w])
    }

    /// Run a train entry: (params, batch) -> TrainOut.
    pub fn run_train(&self, params: &[f32], batch: &Batch) -> Result<TrainOut> {
        let inputs = self.batch_inputs(params, batch)?;
        let parts = self.execute(&inputs)?;
        Ok(TrainOut {
            loss_sum: parts[0].get_first_element::<f32>()? as f64,
            correct: parts[1].get_first_element::<f32>()? as f64,
            grad_sum: parts[2].to_vec::<f32>()?,
            sqnorm_sum: parts[3].get_first_element::<f32>()? as f64,
        })
    }

    /// Run an eval entry: (params, batch) -> EvalOut.
    pub fn run_eval(&self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        let inputs = self.batch_inputs(params, batch)?;
        let parts = self.execute(&inputs)?;
        Ok(EvalOut {
            loss_sum: parts[0].get_first_element::<f32>()? as f64,
            correct: parts[1].get_first_element::<f32>()? as f64,
        })
    }

    /// Run the fused on-device SGD update entry:
    /// (params, velocity, grad_sum, [lr, mu, wd, 1/m]) -> (params', velocity').
    pub fn run_update(
        &self,
        params: &[f32],
        velocity: &[f32],
        grad_sum: &[f32],
        lr: f32,
        momentum: f32,
        weight_decay: f32,
        inv_m: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if self.info.inputs.len() != 4 || self.info.inputs[3].name != "scalars" {
            bail!(
                "entry {}: not an update entry (expected params/velocity/grad_sum/scalars)",
                self.key
            );
        }
        let p = self.info.inputs[0].elements();
        for (name, len) in [
            ("params", params.len()),
            ("velocity", velocity.len()),
            ("grad_sum", grad_sum.len()),
        ] {
            if len != p {
                bail!(
                    "entry {}: input {name:?} has {len} elements, expected {p}",
                    self.key
                );
            }
        }
        let scalars = [lr, momentum, weight_decay, inv_m];
        let inputs = vec![
            xla::Literal::vec1(params),
            xla::Literal::vec1(velocity),
            xla::Literal::vec1(grad_sum),
            xla::Literal::vec1(&scalars),
        ];
        let parts = self.execute(&inputs)?;
        Ok((parts[0].to_vec::<f32>()?, parts[1].to_vec::<f32>()?))
    }
}

#[cfg(test)]
mod tests {
    // Executable behaviour — numerics, padding no-ops, additivity, the
    // actionable input-validation error messages — is covered end-to-end
    // by rust/tests/integration_runtime.rs over the committed interpreter
    // fixtures (rust/tests/fixtures), which run on every machine.
}
