//! Typed view of `artifacts/manifest.json` (emitted by python/compile/aot.py).
//!
//! The manifest is the single source of truth about what was AOT-compiled:
//! per model — parameter count, feature shape, label dtype, the micro-batch
//! ladder, parameter layout (for the Table 2 memory model), init-params
//! files, and per-entry tensor specs the executable wrapper validates
//! against at execute time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type of an executable input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One tensor in an entry signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            dtype: Dtype::parse(j.req_str("dtype")?)?,
            shape: usize_vec(j.req_arr("shape")?)?,
        })
    }
}

/// One AOT-lowered executable entry.
#[derive(Clone, Debug)]
pub struct EntryInfo {
    /// Path relative to the artifacts root.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_bytes: usize,
}

/// One named parameter tensor (layout of the flat vector).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Everything the manifest records about one model.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub param_count: usize,
    pub input_shape: Vec<usize>,
    pub label_dtype: Dtype,
    pub num_classes: usize,
    /// Compiled micro-batch sizes, ascending.
    pub ladder: Vec<usize>,
    pub chunk: usize,
    pub tags: Vec<String>,
    pub param_specs: Vec<ParamSpec>,
    /// Relative paths of the seeded init-params files.
    pub init_params: Vec<String>,
    pub entries: BTreeMap<String, EntryInfo>,
}

impl ModelInfo {
    pub fn feat_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Entry key for a train variant at micro-batch `m`.
    pub fn train_key(diversity: bool, m: usize) -> String {
        if diversity {
            format!("train_div_b{m}")
        } else {
            format!("train_plain_b{m}")
        }
    }

    pub fn eval_key(m: usize) -> String {
        format!("eval_b{m}")
    }

    pub fn entry(&self, key: &str) -> Result<&EntryInfo> {
        self.entries
            .get(key)
            .with_context(|| format!("model {:?} has no entry {key:?}", self.name))
    }

    /// Largest ladder micro-batch `<= m`, or the smallest rung if `m`
    /// is below all of them.
    pub fn best_micro(&self, m: usize) -> usize {
        let mut best = self.ladder[0];
        for &b in &self.ladder {
            if b <= m {
                best = b;
            }
        }
        best
    }

    pub fn max_micro(&self) -> usize {
        *self.ladder.last().expect("empty ladder")
    }

    pub fn min_micro(&self) -> usize {
        self.ladder[0]
    }
}

/// The parsed manifest plus its filesystem root.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub version: usize,
    pub models: BTreeMap<String, ModelInfo>,
}

fn usize_vec(arr: &[Json]) -> Result<Vec<usize>> {
    arr.iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| anyhow::anyhow!("expected unsigned integer, got {v:?}"))
        })
        .collect()
}

fn string_vec(arr: &[Json]) -> Vec<String> {
    arr.iter()
        .filter_map(|v| v.as_str().map(str::to_string))
        .collect()
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, root)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, root: PathBuf) -> Result<Manifest> {
        let doc = json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let version = doc.req_usize("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut models = BTreeMap::new();
        let model_obj = doc
            .req("models")?
            .as_obj()
            .context("manifest `models` is not an object")?;
        for (name, m) in model_obj {
            let mut entries = BTreeMap::new();
            let entry_obj = m
                .req("entries")?
                .as_obj()
                .context("`entries` is not an object")?;
            for (key, e) in entry_obj {
                let inputs = e
                    .req_arr("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = e
                    .req_arr("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                entries.insert(
                    key.clone(),
                    EntryInfo {
                        file: e.req_str("file")?.to_string(),
                        inputs,
                        outputs,
                        hlo_bytes: e.req_usize("hlo_bytes").unwrap_or(0),
                    },
                );
            }
            let param_specs = m
                .req_arr("param_specs")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req_str("name")?.to_string(),
                        shape: usize_vec(p.req_arr("shape")?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let info = ModelInfo {
                name: name.clone(),
                param_count: m.req_usize("param_count")?,
                input_shape: usize_vec(m.req_arr("input_shape")?)?,
                label_dtype: Dtype::parse(m.req_str("label_dtype")?)?,
                num_classes: m.req_usize("num_classes")?,
                ladder: usize_vec(m.req_arr("ladder")?)?,
                chunk: m.req_usize("chunk")?,
                tags: string_vec(m.req_arr("tags")?),
                param_specs,
                init_params: string_vec(m.req_arr("init_params")?),
                entries,
            };
            // Sanity invariants the runtime relies on.
            if info.ladder.is_empty() {
                bail!("model {name}: empty ladder");
            }
            if info.ladder.windows(2).any(|w| w[0] >= w[1]) {
                bail!("model {name}: ladder not strictly ascending");
            }
            let spec_total: usize = info.param_specs.iter().map(|s| s.size()).sum();
            if spec_total != info.param_count {
                bail!(
                    "model {name}: param_specs total {spec_total} != param_count {}",
                    info.param_count
                );
            }
            models.insert(name.clone(), info);
        }
        Ok(Manifest {
            root,
            version,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model {name:?} (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    /// Absolute path of an artifact-relative file.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Read a raw little-endian f32 init-params file for (model, seed).
    /// Seeds beyond the emitted files wrap around (documented behaviour
    /// for trial counts > n_init_seeds).
    pub fn load_init_params(&self, model: &str, seed: usize) -> Result<Vec<f32>> {
        let info = self.model(model)?;
        if info.init_params.is_empty() {
            bail!("model {model}: no init_params files");
        }
        let rel = &info.init_params[seed % info.init_params.len()];
        let path = self.path(rel);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != 4 * info.param_count {
            bail!(
                "{path:?}: {} bytes, expected {}",
                bytes.len(),
                4 * info.param_count
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        r#"{"version": 1, "models": {"m": {
            "param_count": 6,
            "input_shape": [2],
            "label_dtype": "f32",
            "num_classes": 2,
            "ladder": [4, 8, 32],
            "chunk": 4,
            "tags": ["tiny"],
            "param_specs": [{"name": "w", "shape": [2, 2]}, {"name": "b", "shape": [2]}],
            "init_params": ["m/init_s0.bin"],
            "entries": {
                "train_div_b4": {"file": "m/train_div_b4.hlo.txt", "hlo_bytes": 10,
                    "inputs": [{"name": "params", "dtype": "f32", "shape": [6]},
                               {"name": "x", "dtype": "f32", "shape": [4, 2]},
                               {"name": "y", "dtype": "f32", "shape": [4]},
                               {"name": "w", "dtype": "f32", "shape": [4]}],
                    "outputs": [{"name": "loss_sum", "dtype": "f32", "shape": []},
                                {"name": "correct", "dtype": "f32", "shape": []},
                                {"name": "grad_sum", "dtype": "f32", "shape": [6]},
                                {"name": "sqnorm_sum", "dtype": "f32", "shape": []}]}
            }}}}"#
            .to_string()
    }

    #[test]
    fn parses_model_info() {
        let m = Manifest::parse(&sample_manifest(), PathBuf::from("/tmp")).unwrap();
        let info = m.model("m").unwrap();
        assert_eq!(info.param_count, 6);
        assert_eq!(info.ladder, vec![4, 8, 32]);
        assert_eq!(info.label_dtype, Dtype::F32);
        assert_eq!(info.feat_len(), 2);
        let e = info.entry("train_div_b4").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.outputs[2].shape, vec![6]);
        assert_eq!(e.inputs[1].elements(), 8);
        assert_eq!(e.inputs[1].bytes(), 32);
    }

    #[test]
    fn entry_keys() {
        assert_eq!(ModelInfo::train_key(true, 128), "train_div_b128");
        assert_eq!(ModelInfo::train_key(false, 8), "train_plain_b8");
        assert_eq!(ModelInfo::eval_key(4), "eval_b4");
    }

    #[test]
    fn best_micro_selection() {
        let m = Manifest::parse(&sample_manifest(), PathBuf::from("/tmp")).unwrap();
        let info = m.model("m").unwrap();
        assert_eq!(info.best_micro(100), 32);
        assert_eq!(info.best_micro(32), 32);
        assert_eq!(info.best_micro(31), 8);
        assert_eq!(info.best_micro(5), 4);
        assert_eq!(info.best_micro(1), 4); // below the ladder -> smallest rung
        assert_eq!(info.max_micro(), 32);
        assert_eq!(info.min_micro(), 4);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"version": 2, "models": {}}"#, PathBuf::new()).is_err());
        // Ladder not ascending.
        let bad = sample_manifest().replace("[4, 8, 32]", "[8, 4]");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
        // Param specs inconsistent with param_count.
        let bad = sample_manifest().replace(r#""param_count": 6"#, r#""param_count": 7"#);
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn unknown_model_error_lists_names() {
        let m = Manifest::parse(&sample_manifest(), PathBuf::from("/tmp")).unwrap();
        let err = format!("{:#}", m.model("nope").unwrap_err());
        assert!(err.contains("nope") && err.contains('m'), "{err}");
    }
}
