//! PJRT runtime: manifest parsing, executable compilation cache, and typed
//! execute wrappers over the AOT artifacts (DESIGN.md §4.2).
//!
//! The interchange format is HLO **text**: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that this crate's xla_extension (0.5.1)
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The whole layer is thread-safe: [`Runtime`] and [`Executable`] are
//! `Send + Sync`, the compile cache hands out `Arc<Executable>` handles,
//! and concurrent first access to one entry compiles it exactly once —
//! this is what the parallel trial engine ([`crate::engine`]) builds on.
//! When the `xla` dependency is the vendored stub (rust/vendor/xla),
//! compilation/caching works everywhere but execution is unavailable;
//! see [`Runtime::has_execution_backend`].

pub mod cache;
pub mod executable;
pub mod manifest;

pub use cache::{Runtime, RuntimeStats};
pub use executable::{EvalOut, Executable, TrainOut};
pub use manifest::{Dtype, EntryInfo, Manifest, ModelInfo, ParamSpec, TensorSpec};
