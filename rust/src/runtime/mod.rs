//! PJRT runtime: manifest parsing, executable compilation cache, and typed
//! execute wrappers over the AOT artifacts (DESIGN.md §4.2).
//!
//! The interchange format is HLO **text**: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that this crate's xla_extension (0.5.1)
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The whole layer is thread-safe: [`Runtime`] and [`Executable`] are
//! `Send + Sync`, the compile cache hands out `Arc<Executable>` handles,
//! and concurrent first access to one entry compiles it exactly once —
//! this is what the parallel trial engine ([`crate::engine`]) builds on.
//!
//! Execution comes in three backend tiers (see rust/vendor/xla):
//!
//! 1. **Interpreter** (default): a pure-Rust HLO evaluator inside the
//!    vendored `xla` crate.  Compiled entries execute on every machine —
//!    the full numeric test suite runs in plain `cargo test` over the
//!    committed fixtures in rust/tests/fixtures, with no AOT build and
//!    no native XLA.
//! 2. **Stub** (`DIVEBATCH_BACKEND=stub`): compile/cache-only; execution
//!    fails and [`Runtime::has_execution_backend`] reports `false`.
//! 3. **Real PJRT**: point the `xla` dependency in rust/Cargo.toml at the
//!    real xla_extension binding for native CPU/TPU execution over
//!    `make artifacts` output (integration tests opt in with
//!    `DIVEBATCH_TEST_ARTIFACTS=<dir>`).

pub mod cache;
pub mod executable;
pub mod handles;
pub mod manifest;

pub use cache::{ExecCacheStats, Runtime, RuntimeStats};
pub use executable::{EvalOut, Executable, TrainOut};
pub use handles::ExecCache;
pub use manifest::{Dtype, EntryInfo, Manifest, ModelInfo, ParamSpec, TensorSpec};
