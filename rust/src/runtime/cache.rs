//! The runtime: PJRT client + lazily-compiled executable cache.
//!
//! `Runtime::load` parses the manifest once; `Executable`s are compiled on
//! first use (HLO text -> `HloModuleProto::from_text_file` -> XlaComputation
//! -> PJRT compile) and cached by entry key, so a training run only pays
//! compilation for the ladder rungs its batch-size policy actually visits.
//! Compile times are recorded for the perf report.
//!
//! The whole type is `Send + Sync` (statically asserted in
//! rust/tests/engine.rs): one `Runtime` — and therefore one compile cache —
//! is shared by every worker of the parallel trial engine
//! ([`crate::engine`]).  Concurrency contract:
//!
//! * the cache map is behind an `RwLock`, so steady-state lookups are
//!   read-locked and scale across workers;
//! * first access to an entry compiles it **exactly once**: compilation
//!   runs under a per-key lock (not the map lock), so two workers racing
//!   on the same rung serialize on that rung only, while different rungs
//!   compile concurrently;
//! * [`RuntimeStats`] are plain atomic counters (no mutex): stats
//!   bookkeeping never serializes parallel sweeps, and nothing in the
//!   execute hot path takes a lock — the only locks in this module guard
//!   compilation (cold path) and the cache map itself;
//! * per-executable execution counts are atomic too (executable.rs);
//! * locks are poison-tolerant: a panicking trial (isolated by the
//!   engine) never wedges the shared cache for the rest of the sweep.
//!
//! What a cache hit hands back is the **compiled register program**
//! (`xla::PjRtLoadedExecutable` wraps `interp::Compiled` — the lowered
//! slot/plan form, not the HLO text), so a trainer step pays zero
//! parse/lower cost after first touch of a rung.
//!
//! Execution capability depends on the backend tier the `xla` crate
//! provides (see rust/vendor/xla): the pure-Rust **interpreter** (the
//! default — [`Runtime::has_execution_backend`] is true everywhere), the
//! compile-only **stub** (`DIVEBATCH_BACKEND=stub`), or a **real PJRT**
//! binding swapped in via rust/Cargo.toml.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};

use anyhow::{Context, Result};

use super::executable::Executable;
use super::manifest::{Manifest, ModelInfo};
use crate::util::timer::Timer;

/// Cumulative runtime statistics.  Snapshots are built from two
/// independent relaxed atomic loads, so a reader racing a compile may see
/// `compiles` already bumped while `compile_seconds` has not caught up —
/// fine for the progress/report consumers this feeds (the old mutex's
/// pairwise consistency is deliberately traded for a lock-free hot path).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_seconds: f64,
}

/// Lock-free stats storage: parallel sweep workers (`--jobs N`) bump
/// these without ever contending on a mutex.  Durations are accumulated
/// in integer nanoseconds so the add is a single atomic op.
#[derive(Debug, Default)]
struct StatsCells {
    compiles: AtomicUsize,
    compile_nanos: AtomicU64,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
    /// Sum of `approx_bytes` over currently-cached executables,
    /// maintained on insert/evict (both under the write lock).
    cached_bytes: AtomicUsize,
    /// Monotonic recency clock: every cache hit/insert stamps its entry
    /// with the next tick, so eviction can pick the least-recently-used
    /// entry without taking the write lock on the hit path.
    tick: AtomicU64,
}

/// One cached compiled entry plus its LRU recency stamp.  The stamp is
/// atomic so hits (under the map's **read** lock) can refresh it without
/// write-locking the map — the hot path stays read-scalable.
struct CacheSlot {
    exe: Arc<Executable>,
    stamp: AtomicU64,
}

/// Snapshot of the executable cache's bound/usage counters, surfaced by
/// the serve `/stats` endpoint and asserted by the cache-bound tests.
#[derive(Clone, Debug, Default)]
pub struct ExecCacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    /// Configured caps; 0 = unbounded (the CLI default).
    pub max_entries: usize,
    pub max_bytes: usize,
}

/// Lock, recovering from poisoning: the protected state here (cache map,
/// stats, per-key compile guards) is always left consistent — writers
/// never panic mid-update — so a panic elsewhere in a worker thread must
/// not disable the shared runtime.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// PJRT client + manifest + compile cache.
///
/// The cache is **eviction-bounded**: `set_exec_cache_limits` installs
/// an entry-count and/or byte cap (both default 0 = unbounded, the CLI
/// behaviour since PR 1), and every insert evicts least-recently-used
/// entries until the bounds hold again.  A long-running `divebatch
/// serve` process sets the caps so multi-tenant traffic across many
/// models/rungs cannot grow the cache without bound.  Eviction is safe
/// by construction: in-flight users (including the step executor's
/// per-lane [`super::ExecCache`] handle caches) hold `Arc`s, so an
/// evicted entry stays alive until its last user drops it — eviction
/// only forfeits reuse (a later request recompiles).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RwLock<HashMap<String, CacheSlot>>,
    /// Per-entry compile guards: racing first accesses to one key
    /// serialize here while other keys proceed.
    compiling: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    stats: StatsCells,
    /// Eviction bounds (0 = unbounded).  Atomics so a server can install
    /// caps on a shared runtime without exclusive access.
    max_entries: AtomicUsize,
    max_bytes: AtomicUsize,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RwLock::new(HashMap::new()),
            compiling: Mutex::new(HashMap::new()),
            stats: StatsCells::default(),
            max_entries: AtomicUsize::new(0),
            max_bytes: AtomicUsize::new(0),
        })
    }

    /// Default artifacts location: `$DIVEBATCH_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("DIVEBATCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether the linked `xla` crate can actually execute compiled
    /// entries.  True under the default pure-Rust interpreter backend
    /// (platform `"interp"`) and under a real PJRT binding; false only
    /// under the compile-only stub (`DIVEBATCH_BACKEND=stub`; see
    /// rust/vendor/xla for the three backend tiers).
    ///
    /// Compared as a string literal on purpose: the real xla_extension
    /// binding exports no `STUB_PLATFORM` const, and swapping it in must
    /// stay a one-line Cargo.toml change.
    pub fn has_execution_backend(&self) -> bool {
        self.platform() != "stub"
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    pub fn stats(&self) -> RuntimeStats {
        RuntimeStats {
            compiles: self.stats.compiles.load(Ordering::Relaxed),
            compile_seconds: self.stats.compile_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    /// Install executable-cache eviction bounds: keep at most
    /// `max_entries` compiled entries / `max_bytes` approximate bytes
    /// (0 = unbounded).  At least one entry is always retained so the
    /// entry just compiled for a caller can never be evicted before the
    /// caller's own insert returns.
    pub fn set_exec_cache_limits(&self, max_entries: usize, max_bytes: usize) {
        self.max_entries.store(max_entries, Ordering::Relaxed);
        self.max_bytes.store(max_bytes, Ordering::Relaxed);
    }

    /// Bound/usage counters of the executable cache (serve `/stats`).
    pub fn exec_cache_stats(&self) -> ExecCacheStats {
        ExecCacheStats {
            entries: self.cached_executables(),
            bytes: self.stats.cached_bytes.load(Ordering::Relaxed),
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            evictions: self.stats.evictions.load(Ordering::Relaxed),
            max_entries: self.max_entries.load(Ordering::Relaxed),
            max_bytes: self.max_bytes.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Read-locked lookup; refreshes the entry's LRU stamp on hit.
    fn lookup(&self, cache_key: &str) -> Option<Arc<Executable>> {
        let map = self.cache.read().unwrap_or_else(|e| e.into_inner());
        map.get(cache_key).map(|slot| {
            slot.stamp
                .store(self.stats.tick.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
            slot.exe.clone()
        })
    }

    /// Evict least-recently-used entries until the configured bounds
    /// hold, never touching `keep` (the entry being inserted) and never
    /// dropping below one retained entry.  Caller holds the write lock.
    fn evict_over_caps(&self, map: &mut HashMap<String, CacheSlot>, keep: &str) {
        let max_entries = self.max_entries.load(Ordering::Relaxed);
        let max_bytes = self.max_bytes.load(Ordering::Relaxed);
        loop {
            let over_entries = max_entries > 0 && map.len() > max_entries;
            let over_bytes =
                max_bytes > 0 && self.stats.cached_bytes.load(Ordering::Relaxed) > max_bytes;
            if (!over_entries && !over_bytes) || map.len() <= 1 {
                return;
            }
            let victim = map
                .iter()
                .filter(|(k, _)| k.as_str() != keep)
                .min_by_key(|(_, slot)| slot.stamp.load(Ordering::Relaxed))
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { return };
            if let Some(slot) = map.remove(&victim) {
                self.stats
                    .cached_bytes
                    .fetch_sub(slot.exe.approx_bytes, Ordering::Relaxed);
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Fetch (compiling on first use) the executable for `model/entry_key`.
    pub fn entry(&self, model: &str, entry_key: &str) -> Result<Arc<Executable>> {
        let cache_key = format!("{model}/{entry_key}");
        if let Some(e) = self.lookup(&cache_key) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(e);
        }
        // Miss: take this entry's compile guard so concurrent first
        // accesses compile exactly once (other entries stay unblocked).
        let guard = lock_unpoisoned(&self.compiling)
            .entry(cache_key.clone())
            .or_default()
            .clone();
        let _compiling = lock_unpoisoned(&guard);
        // A racing worker may have compiled while we waited for the
        // guard; that still counts as a hit (served without compiling).
        if let Some(e) = self.lookup(&cache_key) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(e);
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        let compiled = (|| -> Result<Arc<Executable>> {
            let info = self.manifest.model(model)?.entry(entry_key)?.clone();
            let path = self.manifest.path(&info.file);
            let t = Timer::start();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .with_context(|| format!("non-utf8 path {path:?}"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {cache_key}"))?;
            self.stats.compiles.fetch_add(1, Ordering::Relaxed);
            self.stats
                .compile_nanos
                .fetch_add((t.seconds() * 1e9) as u64, Ordering::Relaxed);
            let wrapped = Arc::new(Executable::new(cache_key.clone(), info, exe));
            // Publish to the cache BEFORE the guard entry is dropped, so
            // a waiter's re-check always finds it; then evict down to the
            // configured bounds (LRU, never the entry just inserted).
            let mut map = self.cache.write().unwrap_or_else(|e| e.into_inner());
            self.stats
                .cached_bytes
                .fetch_add(wrapped.approx_bytes, Ordering::Relaxed);
            map.insert(
                cache_key.clone(),
                CacheSlot {
                    exe: wrapped.clone(),
                    stamp: AtomicU64::new(self.stats.tick.fetch_add(1, Ordering::Relaxed)),
                },
            );
            self.evict_over_caps(&mut map, &cache_key);
            Ok(wrapped)
        })();
        // Drop the guard entry on success AND failure — later lookups hit
        // the cache fast path (or retry a failed compile afresh) and the
        // guard map never accumulates dead keys.
        lock_unpoisoned(&self.compiling).remove(&cache_key);
        compiled
    }

    /// Train-step executable for (model, diversity?, micro-batch).
    pub fn train_exec(&self, model: &str, diversity: bool, micro: usize) -> Result<Arc<Executable>> {
        self.entry(model, &ModelInfo::train_key(diversity, micro))
    }

    /// Eval-step executable for (model, micro-batch).
    pub fn eval_exec(&self, model: &str, micro: usize) -> Result<Arc<Executable>> {
        self.entry(model, &ModelInfo::eval_key(micro))
    }

    /// Fused on-device update executable for a model.
    pub fn update_exec(&self, model: &str) -> Result<Arc<Executable>> {
        self.entry(model, "update")
    }

    /// Pre-compile every entry a run can touch for a model: **both**
    /// train variants (plain + diversity-instrumented) at every ladder
    /// rung, the eval ladder, and — when the model ships one — the fused
    /// `update` entry.  Benches call this so no JIT compile lands inside
    /// a measured region, and the trainer calls it before spinning up a
    /// parallel step executor so its worker lanes never serialize on the
    /// per-entry first-compile guards at step one (a dynamic-need policy
    /// can flip the train variant mid-run, hence both).
    pub fn warmup(&self, model: &str) -> Result<()> {
        let info = self.model(model)?;
        let ladder = info.ladder.clone();
        let has_update = info.entries.contains_key("update");
        for m in ladder {
            self.train_exec(model, true, m)?;
            self.train_exec(model, false, m)?;
            self.eval_exec(model, m)?;
        }
        if has_update {
            self.update_exec(model)?;
        }
        Ok(())
    }

    /// Total executions across all cached executables.
    pub fn total_executions(&self) -> u64 {
        self.cache
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .map(|s| s.exe.executions())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Compilation requires an artifact tree; cache behaviour — reuse,
    // concurrent compile-once, Send + Sync — is covered by
    // rust/tests/engine.rs, and the numeric path by
    // rust/tests/integration_runtime.rs, both over the committed
    // interpreter fixtures (rust/tests/fixtures/artifacts).

    /// Stats bookkeeping is lock-free: concurrent updates from many
    /// threads go straight to atomics (no mutex to serialize a parallel
    /// sweep on) and the snapshot sees every increment.  The execute hot
    /// path itself takes no lock in this module — only `entry()` misses
    /// (cold compiles) and the cache map do.
    #[test]
    fn stats_updates_are_atomic_and_exact() {
        let cells = StatsCells::default();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        cells.compiles.fetch_add(1, Ordering::Relaxed);
                        cells.compile_nanos.fetch_add(500, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(cells.compiles.load(Ordering::Relaxed), 8000);
        assert_eq!(cells.compile_nanos.load(Ordering::Relaxed), 4_000_000);
    }
}
