//! The runtime: PJRT client + lazily-compiled executable cache.
//!
//! `Runtime::load` parses the manifest once; `Executable`s are compiled on
//! first use (HLO text -> `HloModuleProto::from_text_file` -> XlaComputation
//! -> PJRT compile) and cached by entry key, so a training run only pays
//! compilation for the ladder rungs its batch-size policy actually visits.
//! Compile times are recorded for the perf report.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::executable::Executable;
use super::manifest::{Manifest, ModelInfo};
use crate::util::timer::Timer;

/// Cumulative runtime statistics.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_seconds: f64,
}

/// PJRT client + manifest + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Default artifacts location: `$DIVEBATCH_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("DIVEBATCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Number of distinct compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Fetch (compiling on first use) the executable for `model/entry_key`.
    pub fn entry(&self, model: &str, entry_key: &str) -> Result<Rc<Executable>> {
        let cache_key = format!("{model}/{entry_key}");
        if let Some(e) = self.cache.borrow().get(&cache_key) {
            return Ok(e.clone());
        }
        let info = self.manifest.model(model)?.entry(entry_key)?.clone();
        let path = self.manifest.path(&info.file);
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .with_context(|| format!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {cache_key}"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_seconds += t.seconds();
        }
        let wrapped = Rc::new(Executable::new(cache_key.clone(), info, exe));
        self.cache
            .borrow_mut()
            .insert(cache_key, wrapped.clone());
        Ok(wrapped)
    }

    /// Train-step executable for (model, diversity?, micro-batch).
    pub fn train_exec(&self, model: &str, diversity: bool, micro: usize) -> Result<Rc<Executable>> {
        self.entry(model, &ModelInfo::train_key(diversity, micro))
    }

    /// Eval-step executable for (model, micro-batch).
    pub fn eval_exec(&self, model: &str, micro: usize) -> Result<Rc<Executable>> {
        self.entry(model, &ModelInfo::eval_key(micro))
    }

    /// Fused on-device update executable for a model.
    pub fn update_exec(&self, model: &str) -> Result<Rc<Executable>> {
        self.entry(model, "update")
    }

    /// Pre-compile every ladder rung for a model (both variants + eval).
    /// Useful before timed benchmarking so compilation never lands inside
    /// a measured region.
    pub fn warmup(&self, model: &str, diversity: bool) -> Result<()> {
        let ladder = self.model(model)?.ladder.clone();
        for m in ladder {
            self.train_exec(model, diversity, m)?;
            self.eval_exec(model, m)?;
        }
        Ok(())
    }

    /// Total executions across all cached executables.
    pub fn total_executions(&self) -> u64 {
        self.cache
            .borrow()
            .values()
            .map(|e| e.executions.get())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    // Compilation/execution requires artifacts + a PJRT client; covered by
    // rust/tests/integration_runtime.rs (run via `make test-rust`, which
    // builds tiny artifacts first).
}
