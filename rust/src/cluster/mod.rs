//! Simulated data-parallel cluster timing model.
//!
//! The paper's Table 1 "time to ±1% final accuracy" was measured on
//! 4 x A100; this testbed is one CPU core (DESIGN.md §3), so alongside
//! real wall-clock we report *simulated cluster seconds* from a standard
//! synchronous data-parallel cost model:
//!
//! ```text
//! t_step(m)  = t_launch                              (kernel launch + sync)
//!            + ceil(m / workers) * t_sample          (compute, sharded)
//!            + t_allreduce(P)                        (ring allreduce)
//!            + [instrumented? ceil(m/workers) * t_sample * div_overhead]
//! t_allreduce(P) = t_comm_base + 2 * (workers-1)/workers * P * t_per_param
//! t_epoch(n, m)  = ceil(n/m) * t_step(m)
//! ```
//!
//! This reproduces exactly the mechanism behind the paper's speedups:
//! larger batches amortize the per-step fixed costs (launch + allreduce)
//! over more samples, so fewer, bigger steps make epochs cheaper — while
//! diversity instrumentation adds a per-sample surcharge (BackPACK's
//! overhead in the paper; the dense-trick/chunked-vmap overhead here).
//! Constants default to A100-class magnitudes and can be calibrated from
//! measured CPU per-sample costs via [`ClusterModel::calibrated`].
//!
//! **Failure regimes** (PR 9): [`ClusterSpec`] optionally describes an
//! *imperfect* cluster — per-worker speed heterogeneity, occasional
//! stragglers, and preemption/recompute events.  All regime draws are
//! pure hashes of `(fault_seed, step, worker)` via
//! [`splitmix64`](crate::util::rng::splitmix64), so simulated seconds
//! stay byte-deterministic for a given spec and contribute to run
//! fingerprints exactly when non-default.  With regimes inactive (the
//! default), [`ClusterModel::step_time_at`] is float-identical to
//! [`ClusterModel::step_time`].

use crate::util::rng::splitmix64;

/// Per-run overrides of the simulated cluster shape — the knobs a
/// scenario varies (worker count, instrumentation surcharge) without
/// retuning the A100-class hardware constants.  Carried by
/// `TrainConfig` and exposed on the `train`/`sweep` CLI as
/// `--sim-workers` / `--sim-div-overhead`; the default reproduces the
/// paper's 4 x A100 testbed exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterSpec {
    /// Number of data-parallel workers (paper: 4).
    pub workers: usize,
    /// Multiplicative per-sample surcharge of diversity-instrumented
    /// steps (paper's BackPACK regime: ~0.9, i.e. ~1.9x per sample).
    pub div_overhead: f64,
    /// Per-worker speed spread in `[0, 1)`: worker `w` of `W` runs at
    /// relative speed `1 - heterogeneity * (2w/(W-1) - 1)` (clamped to
    /// ≥ 0.05), so `0.2` means the slowest (last) worker is 20% slower
    /// than nominal.  `0.0` (default) disables heterogeneity.
    pub heterogeneity: f64,
    /// Compute-time multiplier applied to a worker's shard on a
    /// straggler step (paper-adjacent transient slowdowns; ≥ 1).
    pub straggler_factor: f64,
    /// Per-(step, worker) probability of a straggler event.  `0.0`
    /// (default) disables stragglers.
    pub straggler_prob: f64,
    /// Per-(step, worker) probability of a preemption: the worker loses
    /// its shard mid-step and recomputes it once.  `0.0` disables.
    pub preempt_prob: f64,
    /// Seed for the regime draws; part of the fingerprint, so two runs
    /// with different fault seeds never share cached results.
    pub fault_seed: u64,
}

impl Default for ClusterSpec {
    fn default() -> ClusterSpec {
        ClusterSpec {
            workers: 4,
            div_overhead: 0.9,
            heterogeneity: 0.0,
            straggler_factor: 1.0,
            straggler_prob: 0.0,
            preempt_prob: 0.0,
            fault_seed: 0,
        }
    }
}

impl ClusterSpec {
    /// True when this is the paper's a100x4 configuration (the default);
    /// non-default specs contribute to run fingerprints so cached results
    /// from different scenarios never collide.
    pub fn is_default(&self) -> bool {
        *self == ClusterSpec::default()
    }

    /// True when any failure regime is active (heterogeneity,
    /// stragglers, or preemptions).  The trainer switches from the
    /// closed-form epoch time to per-step accumulation exactly when
    /// this holds.
    pub fn has_regimes(&self) -> bool {
        self.heterogeneity != 0.0 || self.straggler_prob > 0.0 || self.preempt_prob > 0.0
    }

    /// The scenario matching THIS testbed's sharded step executor: a
    /// `--step-jobs N` run really is an N-worker synchronous
    /// data-parallel cluster (each lane computes a shard of the logical
    /// batch; the main thread plays the allreduce).  `perf_step` uses
    /// this to print the model's *predicted* step/epoch speedup next to
    /// the *measured* one — the paper's simulated columns and our
    /// wall-clock columns, side by side, from the same cost structure.
    pub fn local(step_jobs: usize) -> ClusterSpec {
        ClusterSpec {
            workers: step_jobs.max(1),
            ..ClusterSpec::default()
        }
    }

    /// Instantiate the timing model for a concrete workload.  A zero
    /// worker count is clamped to 1 (the CLI rejects it earlier).
    pub fn model(&self, param_count: usize, flops_per_sample: f64) -> ClusterModel {
        let mut m = ClusterModel::a100x4(param_count, flops_per_sample);
        m.workers = self.workers.max(1);
        m.div_overhead = self.div_overhead;
        m.heterogeneity = self.heterogeneity;
        m.straggler_factor = self.straggler_factor.max(1.0);
        m.straggler_prob = self.straggler_prob;
        m.preempt_prob = self.preempt_prob;
        m.fault_seed = self.fault_seed;
        m
    }
}

/// Synchronous data-parallel step-time model.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    /// Number of data-parallel workers (paper: 4).
    pub workers: usize,
    /// Fixed per-step launch/sync overhead (seconds).
    pub t_launch: f64,
    /// Per-sample fwd+bwd compute time on one worker (seconds).
    pub t_sample: f64,
    /// Fixed allreduce latency per step (seconds).
    pub t_comm_base: f64,
    /// Per-parameter allreduce transfer time (seconds / param).
    pub t_per_param: f64,
    /// Model parameter count (for the allreduce volume).
    pub param_count: usize,
    /// Multiplicative per-sample surcharge when the step is
    /// diversity-instrumented (paper: BackPACK roughly doubles cost).
    pub div_overhead: f64,
    /// Per-worker speed spread (see [`ClusterSpec::heterogeneity`]).
    pub heterogeneity: f64,
    /// Straggler compute multiplier (see [`ClusterSpec::straggler_factor`]).
    pub straggler_factor: f64,
    /// Per-(step, worker) straggler probability.
    pub straggler_prob: f64,
    /// Per-(step, worker) preemption/recompute probability.
    pub preempt_prob: f64,
    /// Seed for the deterministic regime draws.
    pub fault_seed: u64,
}

impl ClusterModel {
    /// A100x4-class constants for a model with `param_count` parameters
    /// and `flops_per_sample` fwd+bwd FLOPs.
    ///
    /// * 60 us launch+sync per step (CUDA graph-less PyTorch-like)
    /// * 120 TFLOP/s sustained per worker at large batch
    /// * 25 us allreduce latency + NVLink-class 150 GB/s effective ring
    ///   bandwidth on f32 gradients
    /// * instrumented steps cost ~1.9x per sample (Table 2's regime)
    pub fn a100x4(param_count: usize, flops_per_sample: f64) -> ClusterModel {
        ClusterModel {
            workers: 4,
            t_launch: 60e-6,
            t_sample: flops_per_sample / 120e12,
            t_comm_base: 25e-6,
            t_per_param: 4.0 / 150e9, // bytes / (bytes/sec)
            param_count,
            div_overhead: 0.9,
            heterogeneity: 0.0,
            straggler_factor: 1.0,
            straggler_prob: 0.0,
            preempt_prob: 0.0,
            fault_seed: 0,
        }
    }

    /// Calibrate from a measured per-sample cost on this testbed, keeping
    /// the fixed-cost structure (used when reporting "simulated seconds"
    /// consistently with local measurements).
    pub fn calibrated(
        workers: usize,
        measured_per_sample_s: f64,
        param_count: usize,
    ) -> ClusterModel {
        ClusterModel {
            workers,
            t_launch: 60e-6,
            t_sample: measured_per_sample_s,
            t_comm_base: 25e-6,
            t_per_param: 4.0 / 150e9,
            param_count,
            div_overhead: 0.9,
            heterogeneity: 0.0,
            straggler_factor: 1.0,
            straggler_prob: 0.0,
            preempt_prob: 0.0,
            fault_seed: 0,
        }
    }

    /// True when any failure regime is active on this model.
    pub fn has_regimes(&self) -> bool {
        self.heterogeneity != 0.0 || self.straggler_prob > 0.0 || self.preempt_prob > 0.0
    }

    /// Time of one optimizer step at logical batch `m`.
    pub fn step_time(&self, m: usize, instrumented: bool) -> f64 {
        assert!(m > 0);
        let shard = m.div_ceil(self.workers);
        let mut compute = shard as f64 * self.t_sample;
        if instrumented {
            compute *= 1.0 + self.div_overhead;
        }
        let allreduce = self.t_comm_base
            + 2.0 * (self.workers - 1) as f64 / self.workers as f64
                * self.param_count as f64
                * self.t_per_param;
        self.t_launch + compute + allreduce
    }

    /// Time of the optimizer step with global index `step_idx` at
    /// logical batch `m`, under the configured failure regimes.
    ///
    /// With no regime active this is *float-identical* to
    /// [`ClusterModel::step_time`] (the closed-form epoch totals keep
    /// matching the per-step sums bit for bit).  With regimes active,
    /// each worker computes its shard at its heterogeneous speed and
    /// may independently straggle (compute × `straggler_factor`) or be
    /// preempted (recompute the shard once); the synchronous step waits
    /// for the slowest worker.  All draws are pure hashes of
    /// `(fault_seed, step_idx, worker)` — no RNG state, so times are
    /// reproducible regardless of evaluation order.
    pub fn step_time_at(&self, step_idx: u64, m: usize, instrumented: bool) -> f64 {
        if !self.has_regimes() {
            return self.step_time(m, instrumented);
        }
        assert!(m > 0);
        let shard = m.div_ceil(self.workers);
        let mut per_sample = self.t_sample;
        if instrumented {
            per_sample *= 1.0 + self.div_overhead;
        }
        let w_count = self.workers;
        let mut slowest = 0.0f64;
        for w in 0..w_count {
            // Workers are spread evenly across [-1, +1] of the
            // heterogeneity band; the last worker is the slow end.
            let spread = if w_count > 1 {
                2.0 * w as f64 / (w_count - 1) as f64 - 1.0
            } else {
                0.0
            };
            let speed = (1.0 - self.heterogeneity * spread).max(0.05);
            let mut t = shard as f64 * per_sample / speed;
            if self.straggler_prob > 0.0
                && regime_draw(self.fault_seed, step_idx, w as u64, 1) < self.straggler_prob
            {
                t *= self.straggler_factor;
            }
            if self.preempt_prob > 0.0
                && regime_draw(self.fault_seed, step_idx, w as u64, 2) < self.preempt_prob
            {
                // The preempted worker loses its shard and recomputes
                // it once before the allreduce can start.
                t += shard as f64 * per_sample / speed;
            }
            slowest = slowest.max(t);
        }
        let allreduce = self.t_comm_base
            + 2.0 * (self.workers - 1) as f64 / self.workers as f64
                * self.param_count as f64
                * self.t_per_param;
        self.t_launch + slowest + allreduce
    }

    /// Time of one epoch (`ceil(n/m)` steps, last one partial).
    pub fn epoch_time(&self, n: usize, m: usize, instrumented: bool) -> f64 {
        assert!(n > 0 && m > 0);
        let full_steps = n / m;
        let tail = n % m;
        let mut t = full_steps as f64 * self.step_time(m, instrumented);
        if tail > 0 {
            t += self.step_time(tail, instrumented);
        }
        t
    }

    /// Throughput (samples/sec) at batch `m` — the parallel-efficiency
    /// curve the paper's section 2.1 describes.
    pub fn throughput(&self, m: usize, instrumented: bool) -> f64 {
        m as f64 / self.step_time(m, instrumented)
    }
}

/// Uniform draw in `[0, 1)` from a pure hash of
/// `(seed, step, worker, salt)` — stateless, so regime events are
/// deterministic for a given spec no matter the evaluation order.
fn regime_draw(seed: u64, step: u64, worker: u64, salt: u64) -> f64 {
    let mut s = seed
        ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ worker.wrapping_mul(0xD6E8_FEB8_6659_FD93)
        ^ salt.wrapping_mul(0xA24B_AED4_963E_E407);
    (splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ClusterModel {
        ClusterModel::a100x4(272_000, 250e6) // ResNet-20-ish
    }

    #[test]
    fn larger_batches_amortize_fixed_costs() {
        let m = model();
        // Epoch time strictly decreases from m=128 to m=2048 (same n).
        let t128 = m.epoch_time(50_000, 128, false);
        let t2048 = m.epoch_time(50_000, 2048, false);
        assert!(
            t2048 < t128,
            "large batch should be faster per epoch: {t2048} vs {t128}"
        );
        // And the ratio is meaningful (paper: SGD(2048) ~2x faster/epoch).
        assert!(t128 / t2048 > 1.5, "{}", t128 / t2048);
    }

    #[test]
    fn throughput_saturates() {
        let m = model();
        let t1 = m.throughput(64, false);
        let t2 = m.throughput(1024, false);
        let t3 = m.throughput(8192, false);
        assert!(t2 > t1);
        // Diminishing returns: relative gain 1024->8192 smaller than 64->1024.
        assert!((t3 / t2) < (t2 / t1));
    }

    #[test]
    fn instrumentation_costs_extra() {
        let m = model();
        let plain = m.step_time(256, false);
        let inst = m.step_time(256, true);
        assert!(inst > 1.5 * plain);
    }

    #[test]
    fn epoch_time_counts_partial_step() {
        let m = model();
        let exact = m.epoch_time(1024, 256, false);
        let with_tail = m.epoch_time(1025, 256, false);
        assert!(with_tail > exact);
        // Exactly one extra (1-sample) step.
        let delta = with_tail - exact;
        assert!((delta - m.step_time(1, false)).abs() < 1e-12);
    }

    #[test]
    fn worker_sharding_divides_compute() {
        let mut m = model();
        let t4 = m.step_time(1024, false);
        m.workers = 1;
        let t1 = m.step_time(1024, false);
        assert!(t1 > 3.0 * t4, "expected near-4x: {t1} vs {t4}");
    }

    #[test]
    fn cluster_spec_overrides_a100x4() {
        let spec = ClusterSpec::default();
        assert!(spec.is_default());
        let base = spec.model(272_000, 250e6);
        assert_eq!(base.workers, 4);
        assert!((base.div_overhead - 0.9).abs() < 1e-12);

        let wide = ClusterSpec {
            workers: 16,
            div_overhead: 0.2,
            ..ClusterSpec::default()
        };
        assert!(!wide.is_default());
        let m = wide.model(272_000, 250e6);
        assert_eq!(m.workers, 16);
        // More workers shard compute further.
        assert!(m.step_time(4096, false) < base.step_time(4096, false));
        // Cheaper instrumentation narrows the div surcharge.
        let cheap_ratio = m.step_time(4096, true) / m.step_time(4096, false);
        let base_ratio = base.step_time(4096, true) / base.step_time(4096, false);
        assert!(cheap_ratio < base_ratio);
        // Degenerate worker count clamps instead of dividing by zero.
        let z = ClusterSpec {
            workers: 0,
            ..ClusterSpec::default()
        };
        assert_eq!(z.model(10, 1.0).workers, 1);
    }

    #[test]
    fn local_spec_matches_step_lanes() {
        let s = ClusterSpec::local(4);
        assert_eq!(s.workers, 4);
        assert!(s.is_default()); // 4 lanes == the paper's 4 workers
        let wide = ClusterSpec::local(16);
        assert_eq!(wide.workers, 16);
        assert!(!wide.is_default());
        assert_eq!(ClusterSpec::local(0).workers, 1); // serial clamps
    }

    #[test]
    fn inactive_regimes_are_float_identical_to_step_time() {
        let m = model();
        assert!(!m.has_regimes());
        for (step, batch, inst) in [(0u64, 64usize, false), (7, 1024, true), (123, 1, false)] {
            let a = m.step_time_at(step, batch, inst);
            let b = m.step_time(batch, inst);
            assert_eq!(a.to_bits(), b.to_bits(), "step={step} m={batch}");
        }
    }

    #[test]
    fn regime_draws_are_seed_deterministic() {
        let spec = ClusterSpec {
            heterogeneity: 0.3,
            straggler_factor: 4.0,
            straggler_prob: 0.2,
            preempt_prob: 0.05,
            fault_seed: 42,
            ..ClusterSpec::default()
        };
        assert!(spec.has_regimes());
        assert!(!spec.is_default());
        let a = spec.model(272_000, 250e6);
        let b = spec.model(272_000, 250e6);
        // Same seed → identical per-step times; and at least one step in
        // a short horizon actually hits a straggler (prob 0.2 x 4 workers).
        let mut saw_slow = false;
        let baseline = ClusterSpec {
            straggler_prob: 0.0,
            preempt_prob: 0.0,
            ..spec
        }
        .model(272_000, 250e6);
        for step in 0..50u64 {
            let ta = a.step_time_at(step, 256, false);
            let tb = b.step_time_at(step, 256, false);
            assert_eq!(ta.to_bits(), tb.to_bits(), "step={step}");
            if ta > baseline.step_time_at(step, 256, false) * 1.5 {
                saw_slow = true;
            }
        }
        assert!(saw_slow, "expected at least one straggler in 50 steps");
        // A different fault seed changes the event schedule.
        let other = ClusterSpec {
            fault_seed: 43,
            ..spec
        }
        .model(272_000, 250e6);
        let differs = (0..50u64)
            .any(|s| other.step_time_at(s, 256, false) != a.step_time_at(s, 256, false));
        assert!(differs, "fault seed should reshuffle regime events");
    }

    #[test]
    fn heterogeneity_waits_for_the_slowest_worker() {
        let spec = ClusterSpec {
            heterogeneity: 0.4,
            ..ClusterSpec::default()
        };
        let m = spec.model(272_000, 250e6);
        let uniform = ClusterSpec::default().model(272_000, 250e6);
        // The sync step waits for the slow end of the band, so every
        // step is strictly slower than the uniform cluster.
        for step in 0..5u64 {
            assert!(m.step_time_at(step, 1024, false) > uniform.step_time(1024, false));
        }
    }

    #[test]
    fn calibrated_uses_measured_cost() {
        let m = ClusterModel::calibrated(4, 1e-3, 1000);
        // Dominated by compute: 256/4 * 1ms = 64 ms.
        let t = m.step_time(256, false);
        assert!((0.06..0.08).contains(&t), "{t}");
    }
}
