//! Retry policy + backoff clock for the resilient trial engine.
//!
//! [`RetryPolicy`] bounds attempts and spaces them with capped
//! exponential backoff.  Sleeps go through a [`Clock`] so tests can
//! substitute a [`SimClock`] that *records* requested sleeps instead of
//! performing them — chaos tests assert the exact backoff schedule
//! (`[50ms, 100ms]` for two retries at the defaults) without ever
//! sleeping.
//!
//! Classification lives with the engine (`TrialRunner`), not here: an
//! injected fault ([`super::is_injected`] or a [`super::PANIC_PREFIX`]
//! panic) is transient and retried up to `max_attempts`; a
//! non-injected panic is presumed deterministic and fails fast after
//! one retry; a plain error is not retried at all.

use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Bounded retry with capped exponential backoff.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts for transient failures (1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per retry after.
    pub base_backoff: Duration,
    /// Hard cap on any single backoff.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// No retries at all — the pre-fault-tolerance behaviour.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            ..RetryPolicy::default()
        }
    }

    /// Backoff to sleep after failed attempt number `attempt` (1-based):
    /// `base * 2^(attempt-1)`, capped at `max_backoff`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(32);
        let factor = 1u64 << shift;
        self.base_backoff
            .saturating_mul(factor.min(u32::MAX as u64) as u32)
            .min(self.max_backoff)
    }
}

/// Where backoff sleeps go: the real thread clock, or a recording sim
/// clock for tests.
#[derive(Debug, Clone)]
pub enum Clock {
    Real,
    Sim(Arc<SimClock>),
}

impl Clock {
    pub fn sleep(&self, d: Duration) {
        match self {
            Clock::Real => std::thread::sleep(d),
            Clock::Sim(c) => c.record(d),
        }
    }
}

/// Records every requested sleep instead of performing it.
#[derive(Debug, Default)]
pub struct SimClock {
    slept: Mutex<Vec<Duration>>,
}

impl SimClock {
    pub fn new() -> Arc<SimClock> {
        Arc::new(SimClock::default())
    }

    fn record(&self, d: Duration) {
        self.slept
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(d);
    }

    /// Every sleep requested so far, in order.
    pub fn slept(&self) -> Vec<Duration> {
        self.slept
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    /// Total simulated time slept.
    pub fn total(&self) -> Duration {
        self.slept().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy::default();
        assert_eq!(p.backoff(1), Duration::from_millis(50));
        assert_eq!(p.backoff(2), Duration::from_millis(100));
        assert_eq!(p.backoff(3), Duration::from_millis(200));
        // Far past the cap: 50ms * 2^20 >> 2s.
        assert_eq!(p.backoff(21), Duration::from_secs(2));
        // Degenerate attempt numbers never panic.
        assert_eq!(p.backoff(0), Duration::from_millis(50));
        assert_eq!(p.backoff(u32::MAX), Duration::from_secs(2));
    }

    #[test]
    fn sim_clock_records_without_sleeping() {
        let clock = SimClock::new();
        let c = Clock::Sim(clock.clone());
        let started = std::time::Instant::now();
        c.sleep(Duration::from_secs(3600));
        c.sleep(Duration::from_secs(1800));
        assert!(started.elapsed() < Duration::from_secs(5), "did not sleep");
        assert_eq!(
            clock.slept(),
            vec![Duration::from_secs(3600), Duration::from_secs(1800)]
        );
        assert_eq!(clock.total(), Duration::from_secs(5400));
    }

    #[test]
    fn none_policy_is_single_attempt() {
        assert_eq!(RetryPolicy::none().max_attempts, 1);
    }
}
