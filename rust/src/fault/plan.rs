//! The `FaultPlan` grammar: a comma-separated list of injection rules.
//!
//! ```text
//! plan     := rule ("," rule)*
//! rule     := kind "@" selector (":" extra)*
//! kind     := trial-panic | trial-error | step-panic | io-error
//!           | stall | lane-panic | conn-drop
//! selector := "t" N            trial N            (trial / step-block points)
//!           | "w" N            worker lane N      (lane points)
//!           | "c" N            connection N       (server connection points)
//!           | "store" | "load" cache I/O op       (io points)
//!           | "*"              every point the kind applies to
//! extra    := "b" N            only step block N  (step-panic)
//!           | N "ms" | N "s"   stall duration     (stall; default 10ms)
//!           | "p" FLOAT        fire with probability FLOAT, seeded draw
//!           | N                budget: fire at most N times total
//! ```
//!
//! Examples: `trial-panic@t3` (trial 3 always panics),
//! `step-panic@t5:b2` (trial 5's step block 2 panics),
//! `io-error@store:2` (the first two cache stores fail),
//! `stall@w1:50ms` (worker lane 1 stalls 50ms per claimed block),
//! `trial-panic@*:p0.5:3` (each trial panics with probability 0.5,
//! at most 3 times across the run).
//!
//! Probability draws are a pure hash of `(plan seed, rule index, point
//! identity)` — the same trial under the same seed always draws the
//! same verdict, no matter how many times it is retried or in what
//! order trials run.  I/O points have no stable natural identity, so a
//! probabilistic I/O rule draws from the rule's own atomic sequence
//! counter instead (deterministic for a fixed call sequence).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::rng::splitmix64;

use super::{FaultError, FaultPoint, IoOp, PANIC_PREFIX};

/// What a rule does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic at the trial boundary (caught per-trial by the pool).
    TrialPanic,
    /// Return a transient [`FaultError`] at the trial boundary.
    TrialError,
    /// Panic inside a step-block dispatch (caught per-block).
    StepPanic,
    /// Fail a results-cache I/O operation with a [`FaultError`].
    IoError,
    /// Sleep for the rule's duration, then let the point proceed.
    Stall,
    /// Panic inside a worker lane's claim loop (outside the per-item
    /// catch — exercises the pool's dead-lane recovery).
    LanePanic,
    /// Drop a server connection before it is served.
    ConnDrop,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            FaultKind::TrialPanic => "trial-panic",
            FaultKind::TrialError => "trial-error",
            FaultKind::StepPanic => "step-panic",
            FaultKind::IoError => "io-error",
            FaultKind::Stall => "stall",
            FaultKind::LanePanic => "lane-panic",
            FaultKind::ConnDrop => "conn-drop",
        }
    }

    fn parse(s: &str) -> Option<FaultKind> {
        Some(match s {
            "trial-panic" => FaultKind::TrialPanic,
            "trial-error" => FaultKind::TrialError,
            "step-panic" => FaultKind::StepPanic,
            "io-error" => FaultKind::IoError,
            "stall" => FaultKind::Stall,
            "lane-panic" => FaultKind::LanePanic,
            "conn-drop" => FaultKind::ConnDrop,
            _ => return None,
        })
    }
}

/// Where a rule applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selector {
    /// `tN` — a specific trial id.
    Trial(u64),
    /// `wN` — a specific worker lane.
    Lane(u64),
    /// `cN` — a specific server connection index.
    Conn(u64),
    /// `store` | `load` — a cache I/O operation.
    Io(IoOp),
    /// `*` — every point the kind applies to.
    Any,
}

impl fmt::Display for Selector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Selector::Trial(n) => write!(f, "t{n}"),
            Selector::Lane(n) => write!(f, "w{n}"),
            Selector::Conn(n) => write!(f, "c{n}"),
            Selector::Io(IoOp::Store) => write!(f, "store"),
            Selector::Io(IoOp::Load) => write!(f, "load"),
            Selector::Any => write!(f, "*"),
        }
    }
}

/// One parsed injection rule.  The atomics make a rule's budget and
/// I/O-draw sequence shared across every thread consulting the plan.
#[derive(Debug)]
pub struct FaultRule {
    pub kind: FaultKind,
    pub selector: Selector,
    /// `bN`: restrict a step-panic to one block index.
    pub block: Option<u64>,
    /// Stall duration (`50ms` / `1s`); only meaningful for `stall`.
    pub duration: Duration,
    /// `pF`: fire with seeded probability F instead of always.
    pub prob: Option<f64>,
    /// How many more times this rule may fire (`u64::MAX` = unlimited).
    remaining: AtomicU64,
    /// Draw sequence for points with no stable identity (I/O).
    draws: AtomicU64,
}

impl FaultRule {
    /// Does this rule's (kind, selector, block) cover `point`?
    fn covers(&self, point: FaultPoint) -> bool {
        let kind_ok = match (self.kind, point) {
            (FaultKind::TrialPanic | FaultKind::TrialError, FaultPoint::Trial { .. }) => true,
            (FaultKind::StepPanic, FaultPoint::StepBlock { .. }) => true,
            (FaultKind::IoError, FaultPoint::Io { .. }) => true,
            (FaultKind::LanePanic, FaultPoint::Lane { .. }) => true,
            (FaultKind::ConnDrop, FaultPoint::Conn { .. }) => true,
            (FaultKind::Stall, _) => true,
            _ => false,
        };
        if !kind_ok {
            return false;
        }
        let sel_ok = match (self.selector, point) {
            (Selector::Any, _) => true,
            (Selector::Trial(t), FaultPoint::Trial { trial }) => t == trial,
            (Selector::Trial(t), FaultPoint::StepBlock { trial, .. }) => t == trial,
            (Selector::Lane(w), FaultPoint::Lane { lane }) => w == lane,
            (Selector::Conn(c), FaultPoint::Conn { index }) => c == index,
            (Selector::Io(op), FaultPoint::Io { op: at }) => op == at,
            _ => false,
        };
        if !sel_ok {
            return false;
        }
        match (self.block, point) {
            (Some(b), FaultPoint::StepBlock { block, .. }) => b == block,
            (Some(_), _) => false,
            (None, _) => true,
        }
    }

    /// Claim one unit of budget; `false` when exhausted.
    fn take_budget(&self) -> bool {
        self.remaining
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |r| {
                if r == 0 {
                    None
                } else if r == u64::MAX {
                    Some(u64::MAX)
                } else {
                    Some(r - 1)
                }
            })
            .is_ok()
    }
}

/// A parsed, seeded injection schedule.  Consulted lock-free after
/// installation; every decision is a pure function of the seed and the
/// point identity (plus per-rule atomics for budgets and I/O draws).
#[derive(Debug)]
pub struct FaultPlan {
    pub seed: u64,
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse the grammar above; `seed` drives every probabilistic draw.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for raw in spec.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            rules.push(parse_rule(raw)?);
        }
        if rules.is_empty() {
            return Err("empty fault plan (expected kind@selector[:extra]*)".to_string());
        }
        Ok(FaultPlan { seed, rules })
    }

    /// Evaluate every rule against `point`, in rule order.  A firing
    /// panic rule panics with [`PANIC_PREFIX`]; an error rule returns a
    /// transient [`FaultError`]; a stall sleeps and keeps evaluating.
    pub fn check(&self, point: FaultPoint) -> Result<(), FaultError> {
        for (i, rule) in self.rules.iter().enumerate() {
            if !rule.covers(point) {
                continue;
            }
            if let Some(p) = rule.prob {
                let id = point_identity(point)
                    .unwrap_or_else(|| rule.draws.fetch_add(1, Ordering::SeqCst));
                if draw(self.seed, i as u64, id) >= p {
                    continue;
                }
            }
            if !rule.take_budget() {
                continue;
            }
            let desc = format!(
                "injected {} at {} (rule {} `{}@{}`)",
                rule.kind.name(),
                point,
                i,
                rule.kind.name(),
                rule.selector
            );
            match rule.kind {
                FaultKind::Stall => std::thread::sleep(rule.duration),
                FaultKind::TrialPanic | FaultKind::StepPanic | FaultKind::LanePanic => {
                    panic!("{PANIC_PREFIX}{desc}")
                }
                FaultKind::TrialError | FaultKind::IoError | FaultKind::ConnDrop => {
                    return Err(FaultError::new(desc))
                }
            }
        }
        Ok(())
    }
}

/// Natural identity of a point for probability draws, when it has one.
fn point_identity(point: FaultPoint) -> Option<u64> {
    match point {
        FaultPoint::Trial { trial } => Some(trial),
        FaultPoint::StepBlock { trial, block } => {
            Some(trial.wrapping_mul(0x9E3779B97F4A7C15) ^ block)
        }
        FaultPoint::Lane { lane } => Some(lane),
        FaultPoint::Conn { index } => Some(index),
        FaultPoint::Io { .. } => None,
    }
}

/// Uniform in `[0, 1)` from a pure hash of (seed, rule, identity).
fn draw(seed: u64, rule: u64, id: u64) -> f64 {
    let mut s = seed ^ rule.wrapping_mul(0xA24BAED4963EE407) ^ id.wrapping_mul(0xD6E8FEB86659FD93);
    let h = splitmix64(&mut s);
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

fn parse_rule(raw: &str) -> Result<FaultRule, String> {
    let (kind_s, rest) = raw
        .split_once('@')
        .ok_or_else(|| format!("rule {raw:?}: expected kind@selector"))?;
    let kind = FaultKind::parse(kind_s).ok_or_else(|| {
        format!(
            "rule {raw:?}: unknown kind {kind_s:?} (trial-panic | trial-error | \
             step-panic | io-error | stall | lane-panic | conn-drop)"
        )
    })?;
    let mut parts = rest.split(':');
    let sel_s = parts.next().unwrap_or("");
    let selector = parse_selector(sel_s)
        .ok_or_else(|| format!("rule {raw:?}: bad selector {sel_s:?} (tN | wN | cN | store | load | *)"))?;
    let mut rule = FaultRule {
        kind,
        selector,
        block: None,
        duration: Duration::from_millis(10),
        prob: None,
        remaining: AtomicU64::new(u64::MAX),
        draws: AtomicU64::new(0),
    };
    for extra in parts {
        parse_extra(&mut rule, extra).map_err(|e| format!("rule {raw:?}: {e}"))?;
    }
    if let Some(p) = rule.prob {
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("rule {raw:?}: probability {p} out of [0, 1]"));
        }
    }
    Ok(rule)
}

fn parse_selector(s: &str) -> Option<Selector> {
    match s {
        "*" => return Some(Selector::Any),
        "store" => return Some(Selector::Io(IoOp::Store)),
        "load" => return Some(Selector::Io(IoOp::Load)),
        _ => {}
    }
    let (head, num) = s.split_at(1.min(s.len()));
    let n: u64 = num.parse().ok()?;
    match head {
        "t" => Some(Selector::Trial(n)),
        "w" => Some(Selector::Lane(n)),
        "c" => Some(Selector::Conn(n)),
        _ => None,
    }
}

fn parse_extra(rule: &mut FaultRule, extra: &str) -> Result<(), String> {
    if extra.is_empty() {
        return Err("empty extra".to_string());
    }
    if let Some(num) = extra.strip_prefix('b') {
        if let Ok(b) = num.parse::<u64>() {
            rule.block = Some(b);
            return Ok(());
        }
    }
    if let Some(num) = extra.strip_prefix('p') {
        if let Ok(p) = num.parse::<f64>() {
            rule.prob = Some(p);
            return Ok(());
        }
    }
    if let Some(num) = extra.strip_suffix("ms") {
        if let Ok(ms) = num.parse::<u64>() {
            rule.duration = Duration::from_millis(ms);
            return Ok(());
        }
    }
    if let Some(num) = extra.strip_suffix('s') {
        if let Ok(secs) = num.parse::<u64>() {
            rule.duration = Duration::from_secs(secs);
            return Ok(());
        }
    }
    if let Ok(n) = extra.parse::<u64>() {
        rule.remaining = AtomicU64::new(n);
        return Ok(());
    }
    Err(format!(
        "bad extra {extra:?} (bN | Nms | Ns | pFLOAT | N)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips_the_issue_example() {
        let plan = FaultPlan::parse(
            "trial-panic@t3,step-panic@t5:b2,io-error@store:2,stall@w1:50ms",
            0,
        )
        .expect("issue example parses");
        assert_eq!(plan.rules.len(), 4);
        assert_eq!(plan.rules[0].kind, FaultKind::TrialPanic);
        assert_eq!(plan.rules[0].selector, Selector::Trial(3));
        assert_eq!(plan.rules[1].block, Some(2));
        assert_eq!(plan.rules[2].remaining.load(Ordering::SeqCst), 2);
        assert_eq!(plan.rules[3].duration, Duration::from_millis(50));
        assert_eq!(plan.rules[3].selector, Selector::Lane(1));
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for bad in [
            "",
            "trial-panic",
            "bogus@t1",
            "trial-panic@x9",
            "trial-panic@t1:zz",
            "trial-panic@t1:p1.5",
        ] {
            let err = FaultPlan::parse(bad, 0).unwrap_err();
            assert!(!err.is_empty(), "{bad:?} should fail");
        }
    }

    #[test]
    fn count_budget_is_shared_and_exhausts() {
        let plan = FaultPlan::parse("io-error@store:2", 0).unwrap();
        let p = FaultPoint::Io { op: IoOp::Store };
        assert!(plan.check(p).is_err());
        assert!(plan.check(p).is_err());
        assert!(plan.check(p).is_ok(), "budget of 2 is spent");
        // Loads were never covered.
        assert!(plan.check(FaultPoint::Io { op: IoOp::Load }).is_ok());
    }

    #[test]
    fn trial_selector_only_hits_its_trial() {
        let plan = FaultPlan::parse("trial-error@t3", 0).unwrap();
        assert!(plan.check(FaultPoint::Trial { trial: 2 }).is_ok());
        assert!(plan.check(FaultPoint::Trial { trial: 3 }).is_err());
        // And hits it every time (no budget).
        assert!(plan.check(FaultPoint::Trial { trial: 3 }).is_err());
    }

    #[test]
    fn step_block_filter_pins_one_block() {
        let plan = FaultPlan::parse("trial-error@t1:b2", 0);
        // trial-error does not cover step blocks; use a coverable shape.
        assert!(plan.is_ok());
        let plan = FaultPlan::parse("io-error@*:1", 0).unwrap();
        assert!(plan.check(FaultPoint::Io { op: IoOp::Load }).is_err());
        assert!(plan.check(FaultPoint::Io { op: IoOp::Store }).is_ok());
    }

    #[test]
    fn probability_draws_are_seed_deterministic() {
        let verdicts = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::parse("trial-error@*:p0.5", seed).unwrap();
            (0..64)
                .map(|t| plan.check(FaultPoint::Trial { trial: t }).is_err())
                .collect()
        };
        let a = verdicts(7);
        assert_eq!(a, verdicts(7), "same seed, same schedule");
        assert_ne!(a, verdicts(8), "different seed, different schedule");
        let fired = a.iter().filter(|&&b| b).count();
        assert!((10..=54).contains(&fired), "p=0.5 over 64 trials: {fired}");
        // Re-checking the same trial re-draws identically (retry safety).
        let plan = FaultPlan::parse("trial-error@*:p0.5", 7).unwrap();
        let first = plan.check(FaultPoint::Trial { trial: 5 }).is_err();
        for _ in 0..4 {
            assert_eq!(plan.check(FaultPoint::Trial { trial: 5 }).is_err(), first);
        }
    }

    #[test]
    #[should_panic(expected = "divebatch-fault:")]
    fn panic_kinds_carry_the_prefix() {
        let plan = FaultPlan::parse("trial-panic@t0", 0).unwrap();
        let _ = plan.check(FaultPoint::Trial { trial: 0 });
    }
}
