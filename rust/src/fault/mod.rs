//! Deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] is a seeded schedule of injected failures, parsed
//! from the CLI (`--inject`, `--inject-seed`) or the environment
//! (`DIVEBATCH_FAULTS`, `DIVEBATCH_FAULT_SEED`).  Production code calls
//! [`check`] at four injection scopes:
//!
//! | scope              | point                    | call site                    |
//! |--------------------|--------------------------|------------------------------|
//! | trial boundary     | [`FaultPoint::Trial`]    | `TrialSpec::execute_*`       |
//! | step-block dispatch| [`FaultPoint::StepBlock`]| `StepExecutor::run_blocks`   |
//! | worker lane claim  | [`FaultPoint::Lane`]     | `pool::worker_loop`          |
//! | cache I/O          | [`FaultPoint::Io`]       | `ResultsCache::{store,load}` |
//! | server connection  | [`FaultPoint::Conn`]     | `serve::handle_connection`   |
//!
//! With no plan installed, [`check`] is a single relaxed atomic load —
//! the hooks cost nothing in normal operation.  Panics raised by a plan
//! carry [`PANIC_PREFIX`] so the retry layer can classify them as
//! injected (transient) rather than deterministic compute failures;
//! error-kind faults return a typed [`FaultError`] that
//! [`is_injected`] recognizes through an `anyhow` chain.
//!
//! Determinism: every firing decision is a pure function of the plan
//! seed and the point identity (trial id, block index, lane, connection
//! index), plus per-rule atomic budgets.  The same plan + seed produces
//! the same failure schedule on every run — chaos tests assert exact
//! attempt counts, not "it failed somewhere".

pub mod plan;
pub mod retry;

pub use plan::{FaultKind, FaultPlan, FaultRule, Selector};
pub use retry::{Clock, RetryPolicy, SimClock};

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Marker prefix carried by every injected panic payload.  The retry
/// layer treats a panic whose message contains this prefix as
/// transient (retry up to the policy budget) rather than a
/// deterministic compute failure (fail fast after one retry).
pub const PANIC_PREFIX: &str = "divebatch-fault: ";

/// A results-cache I/O operation, as seen by the injection hooks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoOp {
    Store,
    Load,
}

impl fmt::Display for IoOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IoOp::Store => write!(f, "store"),
            IoOp::Load => write!(f, "load"),
        }
    }
}

/// One place the fault layer can inject a failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// About to execute trial `trial` (one attempt).
    Trial { trial: u64 },
    /// About to run step block `block` of trial `trial`.
    StepBlock { trial: u64, block: u64 },
    /// About to perform a results-cache I/O operation.
    Io { op: IoOp },
    /// A worker lane claimed an item from a scatter job.
    Lane { lane: u64 },
    /// The server accepted connection number `index`.
    Conn { index: u64 },
}

impl fmt::Display for FaultPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultPoint::Trial { trial } => write!(f, "trial {trial}"),
            FaultPoint::StepBlock { trial, block } => {
                write!(f, "trial {trial} step block {block}")
            }
            FaultPoint::Io { op } => write!(f, "cache {op}"),
            FaultPoint::Lane { lane } => write!(f, "worker lane {lane}"),
            FaultPoint::Conn { index } => write!(f, "connection {index}"),
        }
    }
}

/// A typed injected failure.  Always transient by definition: the
/// retry layer retries anything whose error chain contains one.
#[derive(Debug, Clone)]
pub struct FaultError {
    desc: String,
}

impl FaultError {
    pub fn new(desc: impl Into<String>) -> FaultError {
        FaultError { desc: desc.into() }
    }
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.desc)
    }
}

impl std::error::Error for FaultError {}

/// Does `err`'s chain contain an injected [`FaultError`]?
pub fn is_injected(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<FaultError>().is_some())
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// Consult the installed plan at an injection point.  With no plan
/// installed this is one relaxed load.  May panic (panic-kind rules) or
/// sleep (stall rules) by design.
pub fn check(point: FaultPoint) -> Result<(), FaultError> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(());
    }
    let plan = PLAN
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .clone();
    match plan {
        Some(p) => p.check(point),
        None => Ok(()),
    }
}

/// Install (or clear, with `None`) the process-wide plan.
pub fn install(plan: Option<Arc<FaultPlan>>) {
    let mut slot = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    ACTIVE.store(plan.is_some(), Ordering::SeqCst);
    *slot = plan;
}

/// Parse and install a plan from `DIVEBATCH_FAULTS` /
/// `DIVEBATCH_FAULT_SEED`, if set.  Called once from `main`.
pub fn init_from_env() -> Result<(), String> {
    let Ok(spec) = std::env::var("DIVEBATCH_FAULTS") else {
        return Ok(());
    };
    if spec.trim().is_empty() {
        return Ok(());
    }
    let seed = match std::env::var("DIVEBATCH_FAULT_SEED") {
        Ok(s) => s
            .trim()
            .parse::<u64>()
            .map_err(|_| format!("DIVEBATCH_FAULT_SEED {s:?} is not a u64"))?,
        Err(_) => 0,
    };
    let plan = FaultPlan::parse(&spec, seed).map_err(|e| format!("DIVEBATCH_FAULTS: {e}"))?;
    install(Some(Arc::new(plan)));
    Ok(())
}

static TEST_GATE: Mutex<()> = Mutex::new(());

/// RAII guard for tests: installs `plan`, serializes every guarded test
/// in the process (the plan is global state), and clears it on drop.
/// All in-process fault-injection tests must go through this.
pub struct FaultGuard {
    _gate: MutexGuard<'static, ()>,
}

impl FaultGuard {
    pub fn install(plan: FaultPlan) -> FaultGuard {
        let gate = TEST_GATE.lock().unwrap_or_else(|p| p.into_inner());
        install(Some(Arc::new(plan)));
        FaultGuard { _gate: gate }
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        install(None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_plan_means_no_faults() {
        // Other tests in this binary never install a global plan, so
        // the fast path must be clean here.
        assert!(check(FaultPoint::Trial { trial: 0 }).is_ok());
        assert!(check(FaultPoint::Io { op: IoOp::Store }).is_ok());
    }

    #[test]
    fn injected_errors_are_recognized_through_anyhow_chains() {
        let inner = FaultError::new("injected io-error at cache store");
        let wrapped = anyhow::Error::new(inner).context("storing trial 3");
        assert!(is_injected(&wrapped));
        assert!(!is_injected(&anyhow::anyhow!("ordinary failure")));
    }

    #[test]
    fn guard_installs_and_clears_the_global_plan() {
        {
            let _g = FaultGuard::install(FaultPlan::parse("trial-error@t9", 0).unwrap());
            assert!(check(FaultPoint::Trial { trial: 9 }).is_err());
            assert!(check(FaultPoint::Trial { trial: 1 }).is_ok());
        }
        assert!(check(FaultPoint::Trial { trial: 9 }).is_ok(), "cleared on drop");
    }
}
