//! The L3 coordinator — the paper's system contribution (DESIGN.md §4).
//!
//! * [`policy`]    — the open [`BatchPolicy`] controller API: built-in
//!   Fixed SGD / AdaBatch / DiveBatch (Algorithm 1) / Oracle policies,
//!   composable wrappers (Warmup, Clamp, EMA hysteresis, Chain), and the
//!   [`PolicyRegistry`] that owns CLI spec parsing
//! * [`plan`]      — accumulation planner over the compiled micro-batch
//!   ladder (static-shape PJRT executables <-> dynamic batch sizes)
//! * [`schedule`]  — LR step decay + Goyal linear batch rescaling
//! * [`optimizer`] — reference SGD(+momentum,+wd) on the flat params
//! * [`diversity`] — Definition-2 epoch accumulators (f64)
//! * [`step`]      — the sharded step executor: micro-batch blocks
//!   dispatched across a persistent worker pool with deterministic
//!   block-order reduction (`--step-jobs`)
//! * [`trainer`]   — the epoch event loop driving a boxed [`BatchPolicy`]
//!   through `on_epoch_start` / `on_step` / `on_epoch_end`

pub mod diversity;
pub mod optimizer;
pub mod plan;
pub mod policy;
pub mod schedule;
pub mod sgld;
pub mod step;
pub mod trainer;

pub use diversity::DiversityAccum;
pub use optimizer::{AdamOptimizer, Optim, SgdOptimizer};
pub use plan::{MicroBlock, MicroPlan};
pub use step::StepExecutor;
pub use policy::{
    AdaptContext, BatchPolicy, Decision, DiversityNeed, DiversityStats, HistoryPoint, Policy,
    PolicyEntry, PolicyError, PolicyHandle, PolicyRegistry,
};
pub use schedule::LrSchedule;
pub use sgld::SgldConfig;
pub use trainer::{TrainConfig, TrainOutcome, Trainer};
