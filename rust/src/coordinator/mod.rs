//! The L3 coordinator — the paper's system contribution (DESIGN.md §4).
//!
//! * [`policy`]    — batch-size policies: Fixed SGD, AdaBatch, DiveBatch
//!   (Algorithm 1), Oracle (exact-diversity ablation)
//! * [`plan`]      — accumulation planner over the compiled micro-batch
//!   ladder (static-shape PJRT executables <-> dynamic batch sizes)
//! * [`schedule`]  — LR step decay + Goyal linear batch rescaling
//! * [`optimizer`] — reference SGD(+momentum,+wd) on the flat params
//! * [`diversity`] — Definition-2 epoch accumulators (f64)
//! * [`trainer`]   — the epoch event loop tying it all together

pub mod diversity;
pub mod optimizer;
pub mod plan;
pub mod policy;
pub mod schedule;
pub mod sgld;
pub mod trainer;

pub use diversity::DiversityAccum;
pub use optimizer::{AdamOptimizer, Optim, SgdOptimizer};
pub use plan::{MicroBlock, MicroPlan};
pub use policy::{DiversityNeed, DiversityStats, Policy};
pub use schedule::LrSchedule;
pub use sgld::SgldConfig;
pub use trainer::{TrainConfig, TrainOutcome, Trainer};
