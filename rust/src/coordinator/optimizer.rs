//! Reference SGD optimizer over the flat parameter vector.
//!
//! The executables return SAMPLE-SUM gradients; the optimizer divides by
//! the logical batch size (Algorithm 1 line 8: `theta -= eta/m * sum_grad`)
//! and optionally applies momentum and decoupled-from-nothing classic L2
//! weight decay (the ResNet reference codebases' setting).
//!
//! The fused on-device `update` executable (L1 `sgd_fused` Pallas kernel)
//! implements the identical rule; `rust/tests/integration_runtime.rs`
//! asserts both paths agree bit-closely, and the P2 bench compares their
//! cost.

/// SGD with optional momentum, L2 weight decay and global-norm clipping.
#[derive(Clone, Debug)]
pub struct SgdOptimizer {
    pub momentum: f64,
    pub weight_decay: f64,
    /// Global-norm gradient clipping threshold (on the mean gradient).
    /// The paper's ResNet-20 runs rely on BatchNorm for stability; our
    /// BN-free substitute (DESIGN.md §3) uses clipping instead.  `None`
    /// disables (the synthetic experiments).
    pub clip_norm: Option<f64>,
    velocity: Vec<f32>,
    steps: u64,
}

impl SgdOptimizer {
    pub fn new(param_count: usize, momentum: f64, weight_decay: f64) -> SgdOptimizer {
        SgdOptimizer {
            momentum,
            weight_decay,
            clip_norm: None,
            velocity: vec![0.0; param_count],
            steps: 0,
        }
    }

    /// Plain SGD (no momentum / weight decay) — the synthetic experiments.
    pub fn plain(param_count: usize) -> SgdOptimizer {
        Self::new(param_count, 0.0, 0.0)
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Reset momentum state (e.g. between trials).
    pub fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
        self.steps = 0;
    }

    /// One update: `params -= lr * v'` with
    /// `g = grad_sum/m + wd * p` and `v' = mu * v + g`.
    ///
    /// Matches `sgd_fused` in python/compile/kernels/persample.py exactly
    /// (same operation order, f32 arithmetic).
    pub fn step(&mut self, params: &mut [f32], grad_sum: &[f32], lr: f64, batch_size: usize) {
        assert_eq!(params.len(), grad_sum.len(), "grad length mismatch");
        assert_eq!(params.len(), self.velocity.len(), "velocity length mismatch");
        assert!(batch_size > 0);
        let inv_m = self.effective_inv_m(grad_sum, batch_size);
        let lr = lr as f32;
        let mu = self.momentum as f32;
        let wd = self.weight_decay as f32;
        if mu == 0.0 && wd == 0.0 {
            // Hot path for the synthetic runs: theta -= lr/m * grad_sum.
            let scale = lr * inv_m;
            for (p, g) in params.iter_mut().zip(grad_sum) {
                *p -= scale * g;
            }
        } else {
            for ((p, v), g) in params.iter_mut().zip(self.velocity.iter_mut()).zip(grad_sum) {
                let eff = g * inv_m + wd * *p;
                *v = mu * *v + eff;
                *p -= lr * *v;
            }
        }
        self.steps += 1;
    }

    /// The scale applied to `grad_sum` before the update: `1/m`, shrunk
    /// further when global-norm clipping engages.  The fused on-device
    /// update executable takes this as its `inv_m` scalar input, so both
    /// update paths share identical clipping semantics.
    pub fn effective_inv_m(&self, grad_sum: &[f32], batch_size: usize) -> f32 {
        let inv_m = 1.0f32 / batch_size as f32;
        if let Some(clip) = self.clip_norm {
            let norm2: f64 = grad_sum
                .iter()
                .map(|&g| {
                    let v = g as f64 * inv_m as f64;
                    v * v
                })
                .sum();
            let norm = norm2.sqrt();
            if norm > clip {
                return inv_m * (clip / norm) as f32;
            }
        }
        inv_m
    }

    /// Adopt externally-computed state (from the on-device update path).
    pub fn set_velocity(&mut self, v: Vec<f32>) {
        assert_eq!(v.len(), self.velocity.len());
        self.velocity = v;
        self.steps += 1;
    }
}

/// Adam (Kingma & Ba) on the flat parameter vector — the paper's §6
/// "DiveBatch could complement these optimizers" direction.  Consumes the
/// same sample-sum gradients; weight decay is classic L2 (added to the
/// gradient before the moment updates), matching the SGD path's
/// convention rather than AdamW's decoupled form.
#[derive(Clone, Debug)]
pub struct AdamOptimizer {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Vec<f32>,
    v: Vec<f32>,
    steps: u64,
}

impl AdamOptimizer {
    pub fn new(param_count: usize, weight_decay: f64) -> AdamOptimizer {
        AdamOptimizer {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            m: vec![0.0; param_count],
            v: vec![0.0; param_count],
            steps: 0,
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// One bias-corrected Adam update from a SUM gradient.
    pub fn step(&mut self, params: &mut [f32], grad_sum: &[f32], lr: f64, batch_size: usize) {
        assert_eq!(params.len(), grad_sum.len());
        assert!(batch_size > 0);
        self.steps += 1;
        let inv_m = 1.0 / batch_size as f64;
        let (b1, b2) = (self.beta1, self.beta2);
        let bc1 = 1.0 - b1.powi(self.steps as i32);
        let bc2 = 1.0 - b2.powi(self.steps as i32);
        let wd = self.weight_decay;
        for i in 0..params.len() {
            let g = grad_sum[i] as f64 * inv_m + wd * params[i] as f64;
            let m = b1 * self.m[i] as f64 + (1.0 - b1) * g;
            let v = b2 * self.v[i] as f64 + (1.0 - b2) * g * g;
            self.m[i] = m as f32;
            self.v[i] = v as f32;
            let update = lr * (m / bc1) / ((v / bc2).sqrt() + self.eps);
            params[i] -= update as f32;
        }
    }
}

/// Unified optimizer the trainer drives (selected by `TrainConfig`).
#[derive(Clone, Debug)]
pub enum Optim {
    Sgd(SgdOptimizer),
    Adam(AdamOptimizer),
}

impl Optim {
    pub fn step(&mut self, params: &mut [f32], grad_sum: &[f32], lr: f64, batch_size: usize) {
        match self {
            Optim::Sgd(o) => o.step(params, grad_sum, lr, batch_size),
            Optim::Adam(o) => o.step(params, grad_sum, lr, batch_size),
        }
    }

    /// SGD-only state accessors (the fused device-update path).
    pub fn as_sgd_mut(&mut self) -> Option<&mut SgdOptimizer> {
        match self {
            Optim::Sgd(o) => Some(o),
            Optim::Adam(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_matches_algorithm1_line8() {
        let mut opt = SgdOptimizer::plain(3);
        let mut p = vec![1.0f32, 2.0, 3.0];
        let grad_sum = vec![10.0f32, -20.0, 0.0];
        opt.step(&mut p, &grad_sum, 0.5, 10);
        // p -= 0.5/10 * grad_sum
        assert_eq!(p, vec![0.5, 3.0, 3.0]);
        assert_eq!(opt.steps(), 1);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = SgdOptimizer::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        let g = vec![1.0f32];
        opt.step(&mut p, &g, 1.0, 1); // v=1, p=-1
        assert!((p[0] + 1.0).abs() < 1e-6);
        opt.step(&mut p, &g, 1.0, 1); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
        assert!((opt.velocity()[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_towards_zero() {
        let mut opt = SgdOptimizer::new(1, 0.0, 0.1);
        let mut p = vec![10.0f32];
        opt.step(&mut p, &[0.0], 1.0, 1);
        // g = 0 + 0.1*10 = 1; p = 10 - 1 = 9.
        assert!((p[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn batch_size_divides_gradient() {
        let mut a = SgdOptimizer::plain(1);
        let mut b = SgdOptimizer::plain(1);
        let mut pa = vec![0.0f32];
        let mut pb = vec![0.0f32];
        a.step(&mut pa, &[100.0], 1.0, 100);
        b.step(&mut pb, &[1.0], 1.0, 1);
        assert!((pa[0] - pb[0]).abs() < 1e-7);
    }

    #[test]
    fn reset_clears_state() {
        let mut opt = SgdOptimizer::new(2, 0.9, 0.0);
        let mut p = vec![0.0f32; 2];
        opt.step(&mut p, &[1.0, 1.0], 0.1, 1);
        opt.reset();
        assert_eq!(opt.velocity(), &[0.0, 0.0]);
        assert_eq!(opt.steps(), 0);
    }

    #[test]
    #[should_panic(expected = "grad length mismatch")]
    fn rejects_mismatched_grad() {
        let mut opt = SgdOptimizer::plain(2);
        let mut p = vec![0.0f32; 2];
        opt.step(&mut p, &[1.0], 0.1, 1);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // With zero moments, step 1 moves each param by ~lr*sign(g)
        // (bias correction makes m_hat = g, v_hat = g^2).
        let mut opt = AdamOptimizer::new(3, 0.0);
        let mut p = vec![0.0f32; 3];
        opt.step(&mut p, &[4.0, -2.0, 0.0], 0.01, 2);
        assert!((p[0] + 0.01).abs() < 1e-4, "{p:?}");
        assert!((p[1] - 0.01).abs() < 1e-4, "{p:?}");
        assert_eq!(p[2], 0.0);
        assert_eq!(opt.steps(), 1);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // Minimize ||p - t||^2 (grad = 2(p - t)); Adam should get close.
        let t = [3.0f32, -1.0, 0.5, 2.0];
        let mut opt = AdamOptimizer::new(4, 0.0);
        let mut p = vec![0.0f32; 4];
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&t).map(|(a, b)| 2.0 * (a - b)).collect();
            opt.step(&mut p, &g, 0.05, 1);
        }
        for (a, b) in p.iter().zip(&t) {
            assert!((a - b).abs() < 0.05, "{p:?}");
        }
    }

    #[test]
    fn adam_batch_size_divides_gradient() {
        let mut a = AdamOptimizer::new(1, 0.0);
        let mut b = AdamOptimizer::new(1, 0.0);
        let mut pa = vec![1.0f32];
        let mut pb = vec![1.0f32];
        a.step(&mut pa, &[64.0], 0.01, 64);
        b.step(&mut pb, &[1.0], 0.01, 1);
        assert!((pa[0] - pb[0]).abs() < 1e-6);
    }

    #[test]
    fn optim_dispatch() {
        let mut o = Optim::Sgd(SgdOptimizer::plain(2));
        assert!(o.as_sgd_mut().is_some());
        let mut p = vec![0.0f32; 2];
        o.step(&mut p, &[1.0, 1.0], 1.0, 1);
        assert!(p[0] < 0.0);
        let mut o = Optim::Adam(AdamOptimizer::new(2, 0.0));
        assert!(o.as_sgd_mut().is_none());
        o.step(&mut p, &[1.0, 1.0], 0.1, 1);
    }

    #[test]
    fn momentum_path_equals_plain_path_when_disabled() {
        // The mu==0,wd==0 fast path must match the general path.
        let mut fast = SgdOptimizer::plain(4);
        let mut slow = SgdOptimizer::new(4, 0.0, 1e-30); // forces general path
        let mut pf = vec![1.0f32, -2.0, 3.0, 0.5];
        let mut ps = pf.clone();
        let g = vec![0.3f32, 0.1, -0.7, 2.0];
        fast.step(&mut pf, &g, 0.05, 7);
        slow.step(&mut ps, &g, 0.05, 7);
        for (a, b) in pf.iter().zip(&ps) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
