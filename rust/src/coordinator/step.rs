//! The sharded step executor: dispatch the micro-batch blocks of one
//! logical batch (or one streaming pass) across a persistent
//! [`WorkerPool`](crate::pool::WorkerPool), with results returned in
//! **block order** for deterministic reduction.
//!
//! This is the step-level half of the crate's two-level parallelism
//! (trial-level lives in [`crate::engine`]; both sit on
//! [`crate::pool`]).  Contract:
//!
//! * **Determinism** — `run_blocks` returns one result per block, in
//!   block-index order, regardless of which lane finished first.  The
//!   trainer folds them sequentially (gradient accumulation, diversity
//!   pushes, loss sums), so run records are byte-identical between
//!   `--step-jobs 1` and `--step-jobs N`.
//! * **Per-lane scratch** — the closure receives `(lane, block_index)`
//!   with `lane < lanes()`, and a lane never runs two blocks
//!   concurrently, so callers keep one input buffer + executable-handle
//!   cache per lane (no sharing, no locking on the hot path).
//! * **Isolation** — a failing or panicking block aborts the *trial*
//!   with an error naming the block (`step block 3 of 8 ...`), never a
//!   hang and never a torn update: the parameter update only happens
//!   after every block of the batch has reduced cleanly.
//!
//! Single-lane executors run blocks inline on the caller thread — the
//! exact pre-refactor serial loop, with zero pool overhead — as do
//! single-block plans on any executor (nothing to parallelize).

use anyhow::{anyhow, Result};

use crate::fault::{self, FaultPoint};
use crate::pool::{JobError, WorkerPool};

/// Executes the blocks of micro-plans across a reusable worker pool.
pub struct StepExecutor {
    pool: Option<WorkerPool>,
    lanes: usize,
    /// Trial id for the step-block fault-injection scope; `None`
    /// disables the hook (directly-constructed executors in tests).
    trial: Option<u64>,
}

impl StepExecutor {
    /// `jobs` lanes total (the caller's thread included); `jobs <= 1`
    /// builds a serial executor with no pool at all.
    pub fn new(jobs: usize) -> StepExecutor {
        let lanes = jobs.max(1);
        StepExecutor {
            pool: if lanes > 1 {
                Some(WorkerPool::new(lanes))
            } else {
                None
            },
            lanes,
            trial: None,
        }
    }

    /// [`StepExecutor::new`], tagged with the owning trial so
    /// `step-panic@tN:bM` fault rules can target this executor's block
    /// dispatches.  The trainer uses this; the hook costs one relaxed
    /// atomic load per block when no plan is installed.
    pub fn for_trial(jobs: usize, trial: u64) -> StepExecutor {
        let mut ex = StepExecutor::new(jobs);
        ex.trial = Some(trial);
        ex
    }

    /// Total parallel lanes (1 = serial).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run `f(lane, block_index)` for every block `0..n`, returning the
    /// results in block order.  On failure, the error of the
    /// lowest-indexed failed block is returned (deterministic across
    /// lane counts), annotated with that block's index.
    pub fn run_blocks<R, F>(&self, n: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize, usize) -> Result<R> + Sync,
    {
        // Step-block injection scope: a `step-panic@tN:bM` rule panics
        // here, inside the per-item catch on the scatter path (the
        // block fails typed, the pool survives) or unwinding to the
        // trial-level catch on the serial path — never a hang.
        let trial = self.trial;
        let f = move |lane: usize, i: usize| -> Result<R> {
            if let Some(t) = trial {
                fault::check(FaultPoint::StepBlock {
                    trial: t,
                    block: i as u64,
                })
                .map_err(anyhow::Error::new)?;
            }
            f(lane, i)
        };
        match &self.pool {
            Some(pool) if n > 1 => {
                let results = pool.scatter(n, f);
                let mut out = Vec::with_capacity(n);
                for (i, r) in results.into_iter().enumerate() {
                    match r {
                        Ok(v) => out.push(v),
                        Err(e) => return Err(annotate_block(i, n, e)),
                    }
                }
                Ok(out)
            }
            _ => {
                let mut out = Vec::with_capacity(n);
                for i in 0..n {
                    out.push(f(0, i).map_err(|e| anyhow!("step block {i} of {n}: {e:#}"))?);
                }
                Ok(out)
            }
        }
    }
}

fn annotate_block(i: usize, n: usize, e: JobError) -> anyhow::Error {
    match e {
        JobError::Failed(m) => anyhow!("step block {i} of {n}: {m}"),
        JobError::Panicked(m) => anyhow!("step block {i} of {n} panicked in a worker: {m}"),
        // Blocks are never retried (retry lives at the trial level),
        // but the match stays exhaustive for the shared error type.
        e @ JobError::Exhausted(_) => anyhow!("step block {i} of {n}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_and_parallel_agree_block_for_block() {
        let serial = StepExecutor::new(1);
        let par = StepExecutor::new(4);
        assert_eq!(serial.lanes(), 1);
        assert_eq!(par.lanes(), 4);
        let f = |_: usize, i: usize| -> Result<u64> {
            let mut x = (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
            x ^= x >> 31;
            Ok(x)
        };
        for n in [1usize, 2, 5, 8, 33] {
            assert_eq!(
                serial.run_blocks(n, f).unwrap(),
                par.run_blocks(n, f).unwrap(),
                "n={n}"
            );
        }
    }

    #[test]
    fn failed_block_is_named_lowest_index_first() {
        // Blocks 2 AND 6 fail; the reported error must name block 2 at
        // every lane count (deterministic error selection).
        let f = |_: usize, i: usize| -> Result<usize> {
            if i == 2 || i == 6 {
                anyhow::bail!("synthetic failure in block {i}");
            }
            Ok(i)
        };
        for jobs in [1usize, 4] {
            let err = StepExecutor::new(jobs).run_blocks(8, f).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("step block 2 of 8"), "jobs={jobs}: {msg}");
        }
    }

    #[test]
    fn panicking_block_fails_with_name_instead_of_hanging() {
        let step = StepExecutor::new(4);
        let err = step
            .run_blocks(8, |_, i| -> Result<usize> {
                if i == 3 {
                    panic!("poisoned worker");
                }
                Ok(i)
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("step block 3 of 8") && msg.contains("panicked"),
            "{msg}"
        );
        // The executor (and its pool) remain usable afterwards.
        let ok = step.run_blocks(4, |_, i| Ok(i)).unwrap();
        assert_eq!(ok, vec![0, 1, 2, 3]);
    }

    #[test]
    fn single_block_runs_inline_on_lane_zero() {
        // No scatter for n <= 1: the closure must see lane 0 even on a
        // parallel executor (zero dispatch overhead for tiny plans).
        let step = StepExecutor::new(4);
        let lanes_seen = AtomicUsize::new(usize::MAX);
        let out = step
            .run_blocks(1, |lane, i| {
                lanes_seen.store(lane, Ordering::SeqCst);
                Ok(i)
            })
            .unwrap();
        assert_eq!(out, vec![0]);
        assert_eq!(lanes_seen.load(Ordering::SeqCst), 0);
    }
}
