//! Accumulation planner: decompose a logical batch into compiled
//! micro-batches.
//!
//! PJRT executables are static-shaped, so the AOT pipeline compiles each
//! model at a small ladder of micro-batch sizes (manifest `ladder`).  A
//! logical batch of size `m` (whatever the policy chose) is executed as a
//! sequence of micro-batch blocks whose sample-sum outputs are accumulated
//! — mathematically identical to one big batch (the executables return
//! sample sums; see python/tests/test_steps.py::test_sample_sum_additivity).
//!
//! The planner is greedy largest-rung-first, which minimizes the number of
//! dispatches (the dominant fixed cost — see the P2 ablation bench); the
//! tail that fits no full rung is padded up to the smallest viable rung
//! with `w = 0` rows.

/// One executable invocation: `take` real samples padded to `micro` rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MicroBlock {
    /// Compiled micro-batch size (a ladder rung).
    pub micro: usize,
    /// Real samples consumed from the batch (`0 < take <= micro`).
    pub take: usize,
}

/// A full decomposition of one logical batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MicroPlan {
    pub blocks: Vec<MicroBlock>,
}

impl MicroPlan {
    /// Build a plan for a logical batch of `m` samples over `ladder`
    /// (strictly ascending rung sizes).  `cap` optionally limits the
    /// largest rung used (e.g. to bound instrumented-step memory).
    pub fn build(m: usize, ladder: &[usize], cap: Option<usize>) -> MicroPlan {
        assert!(m > 0, "empty batch");
        assert!(!ladder.is_empty(), "empty ladder");
        let usable: Vec<usize> = ladder
            .iter()
            .copied()
            .filter(|&r| cap.map(|c| r <= c).unwrap_or(true))
            .collect();
        // If the cap excludes every rung, fall back to the smallest rung
        // (still correct, just more padding than the caller hoped).
        let usable = if usable.is_empty() {
            vec![ladder[0]]
        } else {
            usable
        };
        let mut blocks = Vec::new();
        let mut remaining = m;
        for &rung in usable.iter().rev() {
            while remaining >= rung {
                blocks.push(MicroBlock {
                    micro: rung,
                    take: rung,
                });
                remaining -= rung;
            }
        }
        if remaining > 0 {
            // Smallest rung that can hold the tail (the first, since
            // remaining < usable[0] would have been consumed otherwise —
            // but guard for safety when usable[0] > remaining is false).
            let rung = *usable
                .iter()
                .find(|&&r| r >= remaining)
                .unwrap_or(usable.last().unwrap());
            // A rung smaller than the tail can only happen if the cap
            // clipped the ladder below the tail; split greedily then.
            if rung >= remaining {
                blocks.push(MicroBlock {
                    micro: rung,
                    take: remaining,
                });
            } else {
                while remaining >= rung {
                    blocks.push(MicroBlock {
                        micro: rung,
                        take: rung,
                    });
                    remaining -= rung;
                }
                if remaining > 0 {
                    blocks.push(MicroBlock {
                        micro: rung,
                        take: remaining,
                    });
                }
            }
        }
        MicroPlan { blocks }
    }

    /// Real samples covered (must equal the logical batch size).
    pub fn covered(&self) -> usize {
        self.blocks.iter().map(|b| b.take).sum()
    }

    /// Total executed rows including padding.
    pub fn padded(&self) -> usize {
        self.blocks.iter().map(|b| b.micro).sum()
    }

    /// Number of executable dispatches.
    pub fn dispatches(&self) -> usize {
        self.blocks.len()
    }

    /// Fraction of executed rows that are padding (0 = perfect).
    pub fn waste(&self) -> f64 {
        let padded = self.padded();
        if padded == 0 {
            0.0
        } else {
            1.0 - self.covered() as f64 / padded as f64
        }
    }

    /// Naive single-rung alternative (all blocks at the smallest rung) —
    /// kept for the P2 ablation bench.
    pub fn build_smallest_only(m: usize, ladder: &[usize]) -> MicroPlan {
        Self::build(m, &ladder[..1], None)
    }

    // ------------------------------------- block -> worker scheduling

    /// Makespan (in padded rows — the cost proxy of one dispatch) of
    /// this plan's blocks spread over `workers` parallel lanes, using
    /// the deterministic longest-processing-time greedy: blocks are
    /// already ordered largest-first by `build`, and each is assigned
    /// to the least-loaded lane (lowest index on ties).
    pub fn makespan_rows(&self, workers: usize) -> usize {
        if self.blocks.is_empty() {
            return 0;
        }
        let lanes = workers.max(1).min(self.blocks.len());
        let mut load = vec![0usize; lanes];
        for b in &self.blocks {
            let lane = (0..lanes).min_by_key(|&i| (load[i], i)).unwrap();
            load[lane] += b.micro;
        }
        load.into_iter().max().unwrap()
    }

    /// Dispatch utilization of the plan over `workers` step-executor
    /// lanes: the fraction of configured lane capacity doing dispatch
    /// work, `padded / (workers * makespan)`.  1.0 for a serial
    /// executor or a perfectly balanced decomposition; below 1.0 when a
    /// straggler block — or too few blocks for the lane count — leaves
    /// lanes idle (a 2-block plan on 4 lanes reads 0.5, not 1.0: half
    /// the configured lanes do nothing).  Purely a function of plan
    /// shape — it does not depend on measured time, so it is
    /// deterministic and cheap enough to record per step.
    pub fn utilization(&self, workers: usize) -> f64 {
        let workers = workers.max(1);
        if workers <= 1 {
            return 1.0;
        }
        let makespan = self.makespan_rows(workers);
        if makespan == 0 {
            return 1.0;
        }
        self.padded() as f64 / (workers * makespan) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    const LADDER: &[usize] = &[64, 256, 1024];

    #[test]
    fn exact_fit_uses_one_block() {
        let p = MicroPlan::build(1024, LADDER, None);
        assert_eq!(
            p.blocks,
            vec![MicroBlock {
                micro: 1024,
                take: 1024
            }]
        );
        assert_eq!(p.waste(), 0.0);
    }

    #[test]
    fn paper_batch_5028_decomposes_greedily() {
        // DiveBatch's nonconvex average max batch from the paper.
        let p = MicroPlan::build(5028, &[128, 512, 2048, 8192], None);
        assert_eq!(p.covered(), 5028);
        // 2 x 2048 + 1 x 512 + 3 x 128 + tail 4 padded into 128.
        assert_eq!(p.blocks[0].micro, 2048);
        assert_eq!(p.blocks[1].micro, 2048);
        assert_eq!(p.blocks[2].micro, 512);
        // 5028 = 2*2048 + 512 + 3*128 + 36 -> tail block of 36 padded to 128.
        let tail = p.blocks.last().unwrap();
        assert_eq!(tail.micro, 128);
        assert_eq!(tail.take, 36);
        assert_eq!(p.dispatches(), 7);
    }

    #[test]
    fn tail_padding_is_minimal_rung() {
        let p = MicroPlan::build(70, LADDER, None);
        // 1 x 64 full + tail 6 in a padded 64 block.
        assert_eq!(p.blocks.len(), 2);
        assert_eq!(p.blocks[1], MicroBlock { micro: 64, take: 6 });
        assert!(p.waste() > 0.0);
    }

    #[test]
    fn batch_below_smallest_rung() {
        let p = MicroPlan::build(5, LADDER, None);
        assert_eq!(p.blocks, vec![MicroBlock { micro: 64, take: 5 }]);
    }

    #[test]
    fn cap_limits_rungs() {
        let p = MicroPlan::build(1024, LADDER, Some(256));
        assert!(p.blocks.iter().all(|b| b.micro <= 256));
        assert_eq!(p.covered(), 1024);
        assert_eq!(p.dispatches(), 4);
    }

    #[test]
    fn cap_below_all_rungs_falls_back_to_smallest() {
        let p = MicroPlan::build(100, LADDER, Some(8));
        assert!(p.blocks.iter().all(|b| b.micro == 64));
        assert_eq!(p.covered(), 100);
    }

    #[test]
    fn smallest_only_matches_dispatch_count() {
        let p = MicroPlan::build_smallest_only(300, LADDER);
        assert_eq!(p.dispatches(), 300usize.div_ceil(64));
        assert_eq!(p.covered(), 300);
    }

    #[test]
    fn property_covers_exactly_m() {
        forall(
            300,
            |r: &mut Rng| {
                let m = r.below(10_000) as usize + 1;
                // Random ascending ladder of 1-4 rungs from a pool.
                let pool = [4usize, 8, 16, 64, 128, 256, 1024, 2048];
                let k = r.below(4) as usize + 1;
                let mut ladder: Vec<usize> = (0..k)
                    .map(|_| pool[r.below(pool.len() as u64) as usize])
                    .collect();
                ladder.sort_unstable();
                ladder.dedup();
                (m, ladder)
            },
            |(m, ladder)| {
                let p = MicroPlan::build(*m, ladder, None);
                let covered_ok = p.covered() == *m;
                let block_ok = p
                    .blocks
                    .iter()
                    .all(|b| b.take > 0 && b.take <= b.micro && ladder.contains(&b.micro));
                // Padding never exceeds one smallest rung's worth.
                let waste_ok = p.padded() - p.covered() < ladder[0];
                covered_ok && block_ok && waste_ok
            },
        );
    }

    #[test]
    fn makespan_and_utilization_balanced_plan() {
        // 8 equal blocks of 64 over 4 lanes: 2 rounds, perfect balance.
        let p = MicroPlan::build(512, &[64], None);
        assert_eq!(p.dispatches(), 8);
        assert_eq!(p.makespan_rows(4), 128);
        assert_eq!(p.utilization(4), 1.0);
        // Serial lane count is always fully utilized by definition.
        assert_eq!(p.utilization(1), 1.0);
        assert_eq!(p.makespan_rows(1), 512);
    }

    #[test]
    fn utilization_sees_stragglers_and_sparse_plans() {
        // 3 blocks of 64 over 4 lanes: one configured lane idles -> 3/4.
        let p = MicroPlan::build(192, &[64], None);
        assert!((p.utilization(4) - 0.75).abs() < 1e-12);
        // 5 blocks over 4 lanes: makespan 2 rounds, 5/8 busy.
        let p = MicroPlan::build(320, &[64], None);
        assert!((p.utilization(4) - 5.0 / 8.0).abs() < 1e-12);
        // Mixed rungs: 1x1024 + 1x64-tail over 2 lanes — the big block
        // dominates the makespan.
        let p = MicroPlan::build(1040, LADDER, None);
        assert_eq!(p.makespan_rows(2), 1024);
        assert!((p.utilization(2) - (1024.0 + 64.0) / 2048.0).abs() < 1e-12);
        // A single block cannot parallelize at all: 7 of 8 lanes idle.
        let p = MicroPlan::build(64, &[64], None);
        assert!((p.utilization(8) - 1.0 / 8.0).abs() < 1e-12);
        assert_eq!(p.makespan_rows(8), 64);
    }

    #[test]
    fn property_utilization_bounds_and_determinism() {
        forall(
            200,
            |r: &mut Rng| {
                (
                    r.below(8192) as usize + 1,
                    r.below(7) as usize + 2, // 2..=8 lanes
                )
            },
            |&(m, lanes)| {
                let p = MicroPlan::build(m, LADDER, None);
                let u = p.utilization(lanes);
                let bounded = (0.0..=1.0).contains(&u) && u > 0.0;
                // Deterministic + consistent with the makespan identity
                // over the CONFIGURED lane count (idle lanes count).
                let again = p.utilization(lanes);
                let want = p.padded() as f64 / (lanes * p.makespan_rows(lanes)) as f64;
                let identity = (u - want).abs() < 1e-15;
                bounded && u == again && identity
            },
        );
    }

    #[test]
    fn property_greedy_no_worse_dispatches_than_smallest_only() {
        forall(
            200,
            |r: &mut Rng| r.below(8192) as usize + 1,
            |&m| {
                let greedy = MicroPlan::build(m, LADDER, None);
                let naive = MicroPlan::build_smallest_only(m, LADDER);
                greedy.dispatches() <= naive.dispatches()
            },
        );
    }
}
