//! Learning-rate schedules + the Goyal batch-size rescaling rule.
//!
//! The paper composes two multiplicative factors on top of the base lr:
//!
//! * **step decay**: x`decay` every `every` epochs (synthetic: 0.75/20,
//!   matching Devarakonda et al.'s schedule);
//! * **linear batch rescaling** (Goyal et al. 2017): when the batch grows
//!   from `m0` to `m_k`, scale lr by `m_k / m0` so the *effective* lr
//!   (eta/m) stays constant.  The paper runs each adaptive method with and
//!   without this rescaling (main text = without; appendix E = with).

/// Learning-rate schedule configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LrSchedule {
    /// Base learning rate (the small-batch-tuned eta^sgd).
    pub base: f64,
    /// Multiplicative step decay factor (1.0 disables).
    pub decay: f64,
    /// Epoch period of the step decay (0 disables).
    pub every: usize,
    /// Goyal linear rescaling with batch size on/off.
    pub rescale_with_batch: bool,
}

impl LrSchedule {
    /// Paper synthetic-experiment schedule: decay 0.75 every 20 epochs.
    pub fn step_075_20(base: f64, rescale: bool) -> LrSchedule {
        LrSchedule {
            base,
            decay: 0.75,
            every: 20,
            rescale_with_batch: rescale,
        }
    }

    /// Constant lr (optionally rescaled with batch).
    pub fn constant(base: f64, rescale: bool) -> LrSchedule {
        LrSchedule {
            base,
            decay: 1.0,
            every: 0,
            rescale_with_batch: rescale,
        }
    }

    /// Learning rate for `epoch` at batch size `m` (initial batch `m0`).
    pub fn lr(&self, epoch: usize, m: usize, m0: usize) -> f64 {
        let mut lr = self.base;
        if self.every > 0 && self.decay != 1.0 {
            lr *= self.decay.powi((epoch / self.every) as i32);
        }
        if self.rescale_with_batch {
            lr *= m as f64 / m0 as f64;
        }
        lr
    }

    /// The effective learning rate eta/m that Goyal scaling holds fixed.
    pub fn effective_lr(&self, epoch: usize, m: usize, m0: usize) -> f64 {
        self.lr(epoch, m, m0) / m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_applies_at_boundaries() {
        let s = LrSchedule::step_075_20(16.0, false);
        assert_eq!(s.lr(0, 128, 128), 16.0);
        assert_eq!(s.lr(19, 128, 128), 16.0);
        assert!((s.lr(20, 128, 128) - 12.0).abs() < 1e-12);
        assert!((s.lr(40, 128, 128) - 9.0).abs() < 1e-12);
        assert!((s.lr(60, 128, 128) - 6.75).abs() < 1e-12);
    }

    #[test]
    fn rescaling_preserves_effective_lr() {
        let s = LrSchedule::step_075_20(16.0, true);
        // Same epoch, batch grows 128 -> 4096: eta/m constant.
        let e0 = s.effective_lr(5, 128, 128);
        let e1 = s.effective_lr(5, 4096, 128);
        assert!((e0 - e1).abs() < 1e-15);
        // Paper appendix C convex: lr 16 at m 128 -> lr 512 at m 4096.
        assert!((s.lr(0, 4096, 128) - 512.0).abs() < 1e-9);
    }

    #[test]
    fn no_rescaling_keeps_lr_constant_in_m() {
        let s = LrSchedule::step_075_20(0.1, false);
        assert_eq!(s.lr(0, 128, 128), s.lr(0, 2048, 128));
        // Effective lr then shrinks as m grows (the main-text variant).
        assert!(s.effective_lr(0, 2048, 128) < s.effective_lr(0, 128, 128));
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(1.0, false);
        assert_eq!(s.lr(999, 64, 64), 1.0);
    }

    #[test]
    fn decay_disabled_when_every_zero() {
        let s = LrSchedule {
            base: 2.0,
            decay: 0.5,
            every: 0,
            rescale_with_batch: false,
        };
        assert_eq!(s.lr(100, 32, 32), 2.0);
    }
}
