//! Batch-size policies: Fixed, AdaBatch, DiveBatch (Algorithm 1), Oracle.
//!
//! The trainer calls [`Policy::next`] at every epoch boundary with the
//! diversity statistics observed during (DiveBatch) or recomputed after
//! (Oracle) the epoch; the policy returns the next epoch's logical batch
//! size.  Policies also declare which gradient-diversity instrumentation
//! they need so the trainer can pick the `train_div` vs `train_plain`
//! executable variant (the `plain` variant skips the per-sample pass
//! entirely — that is the paper's SGD/AdaBatch cost model).

use std::fmt;

/// Gradient-diversity statistics accumulated over an epoch
/// (Definition 2 numerator and denominator).
#[derive(Clone, Copy, Debug, Default)]
pub struct DiversityStats {
    /// `sum_i ||grad_i||^2` accumulated over every sample of the epoch.
    pub sqnorm_sum: f64,
    /// `|| sum_i grad_i ||^2` of the epoch-accumulated gradient vector.
    pub grad_norm2: f64,
}

impl DiversityStats {
    /// Estimated gradient diversity `Delta_hat` (Definition 2).
    pub fn delta_hat(&self) -> f64 {
        if self.grad_norm2 <= 0.0 {
            f64::INFINITY
        } else {
            self.sqnorm_sum / self.grad_norm2
        }
    }
}

/// Which diversity signal a policy consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiversityNeed {
    /// No instrumentation (`train_plain`).
    None,
    /// Accumulate Definition-2 stats during the epoch (`train_div`).
    Estimated,
    /// Recompute the exact diversity on the full dataset at epoch end
    /// (extra instrumented pass, no parameter updates).
    Exact,
}

/// A batch-size adaptation policy.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Fixed-batch mini-batch SGD (the paper's SGD baselines).
    Fixed { m: usize },
    /// AdaBatch (Devarakonda et al. 2018): multiply the batch size by
    /// `factor` every `every` epochs, capped at `m_max`.
    AdaBatch {
        m0: usize,
        factor: usize,
        every: usize,
        m_max: usize,
    },
    /// DiveBatch (Algorithm 1): `m_{k+1} = min(m_max, delta * n * Delta_hat)`.
    DiveBatch { m0: usize, delta: f64, m_max: usize },
    /// Oracle: DiveBatch's update rule driven by the *exact* gradient
    /// diversity of the full dataset (section 5.1 ablation).
    Oracle { m0: usize, delta: f64, m_max: usize },
}

impl Policy {
    /// Batch size for epoch 0.
    pub fn initial(&self) -> usize {
        match *self {
            Policy::Fixed { m } => m,
            Policy::AdaBatch { m0, .. } => m0,
            Policy::DiveBatch { m0, .. } => m0,
            Policy::Oracle { m0, .. } => m0,
        }
    }

    pub fn diversity_need(&self) -> DiversityNeed {
        match self {
            Policy::Fixed { .. } | Policy::AdaBatch { .. } => DiversityNeed::None,
            Policy::DiveBatch { .. } => DiversityNeed::Estimated,
            Policy::Oracle { .. } => DiversityNeed::Exact,
        }
    }

    /// Batch size for epoch `epoch + 1`, given the size used during
    /// `epoch`, the dataset size `n`, and (for diversity policies) the
    /// epoch's diversity statistics.
    ///
    /// For `DiveBatch`, `stats` must be the Definition-2 estimate
    /// accumulated over the epoch; for `Oracle`, the exact full-dataset
    /// diversity at the post-epoch parameters.
    pub fn next(
        &self,
        epoch: usize,
        current: usize,
        n: usize,
        stats: Option<DiversityStats>,
    ) -> usize {
        match *self {
            Policy::Fixed { m } => m,
            Policy::AdaBatch {
                factor,
                every,
                m_max,
                ..
            } => {
                if every > 0 && (epoch + 1) % every == 0 {
                    (current * factor.max(1)).min(m_max)
                } else {
                    current
                }
            }
            Policy::DiveBatch { m0, delta, m_max } | Policy::Oracle { m0, delta, m_max } => {
                let stats = stats.expect("diversity policy requires stats");
                let delta_hat = stats.delta_hat();
                if !delta_hat.is_finite() {
                    // Degenerate epoch (zero accumulated gradient):
                    // keep the current batch size rather than jumping.
                    return current.clamp(m0.min(m_max), m_max);
                }
                // Algorithm 1, line 11.
                let target = delta * n as f64 * delta_hat;
                let target = target.round().max(1.0) as usize;
                // Never shrink below the initial batch size (the paper
                // only ever grows the batch; m0 is the floor) and never
                // exceed n or m_max.
                target.clamp(m0, m_max.min(n.max(m0)))
            }
        }
    }

    /// Human-readable label matching the paper's table rows, e.g.
    /// `SGD (128)`, `AdaBatch (128 - 2048)`, `DiveBatch (128 - 2048)`.
    pub fn label(&self) -> String {
        match *self {
            Policy::Fixed { m } => format!("SGD ({m})"),
            Policy::AdaBatch { m0, m_max, .. } => format!("AdaBatch ({m0} - {m_max})"),
            Policy::DiveBatch { m0, m_max, .. } => format!("DiveBatch ({m0} - {m_max})"),
            Policy::Oracle { m0, m_max, .. } => format!("Oracle ({m0} - {m_max})"),
        }
    }

    /// Short machine name for file paths / CLI.
    pub fn kind(&self) -> &'static str {
        match self {
            Policy::Fixed { .. } => "sgd",
            Policy::AdaBatch { .. } => "adabatch",
            Policy::DiveBatch { .. } => "divebatch",
            Policy::Oracle { .. } => "oracle",
        }
    }

    /// Parse a CLI policy spec, e.g.:
    /// `sgd:m=128` | `adabatch:m0=128,factor=2,every=20,mmax=2048` |
    /// `divebatch:m0=128,delta=0.1,mmax=2048` | `oracle:m0=512,delta=0.1,mmax=8192`
    pub fn parse(spec: &str) -> Result<Policy, String> {
        let (kind, rest) = spec.split_once(':').unwrap_or((spec, ""));
        let mut kv = std::collections::BTreeMap::new();
        for pair in rest.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("bad policy param {pair:?}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get_usize = |k: &str, d: Option<usize>| -> Result<usize, String> {
            match kv.get(k) {
                Some(v) => v.parse().map_err(|_| format!("bad {k}={v}")),
                None => d.ok_or_else(|| format!("policy {kind} needs {k}=")),
            }
        };
        let get_f64 = |k: &str, d: Option<f64>| -> Result<f64, String> {
            match kv.get(k) {
                Some(v) => v.parse().map_err(|_| format!("bad {k}={v}")),
                None => d.ok_or_else(|| format!("policy {kind} needs {k}=")),
            }
        };
        match kind {
            "sgd" | "fixed" => Ok(Policy::Fixed {
                m: get_usize("m", None)?,
            }),
            "adabatch" => Ok(Policy::AdaBatch {
                m0: get_usize("m0", None)?,
                factor: get_usize("factor", Some(2))?,
                every: get_usize("every", Some(20))?,
                m_max: get_usize("mmax", None)?,
            }),
            "divebatch" => Ok(Policy::DiveBatch {
                m0: get_usize("m0", None)?,
                delta: get_f64("delta", Some(0.1))?,
                m_max: get_usize("mmax", None)?,
            }),
            "oracle" => Ok(Policy::Oracle {
                m0: get_usize("m0", None)?,
                delta: get_f64("delta", Some(0.1))?,
                m_max: get_usize("mmax", None)?,
            }),
            other => Err(format!(
                "unknown policy {other:?} (sgd|adabatch|divebatch|oracle)"
            )),
        }
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn stats(sq: f64, g2: f64) -> Option<DiversityStats> {
        Some(DiversityStats {
            sqnorm_sum: sq,
            grad_norm2: g2,
        })
    }

    #[test]
    fn fixed_never_changes() {
        let p = Policy::Fixed { m: 128 };
        for e in 0..100 {
            assert_eq!(p.next(e, 128, 20_000, None), 128);
        }
        assert_eq!(p.diversity_need(), DiversityNeed::None);
    }

    #[test]
    fn adabatch_doubles_every_20() {
        let p = Policy::AdaBatch {
            m0: 128,
            factor: 2,
            every: 20,
            m_max: 2048,
        };
        let mut m = p.initial();
        let mut sizes = vec![m];
        for e in 0..100 {
            m = p.next(e, m, 50_000, None);
            sizes.push(m);
        }
        // Doubles at epochs 19->20, 39->40, ... capped at 2048.
        assert_eq!(sizes[19], 128);
        assert_eq!(sizes[20], 256);
        assert_eq!(sizes[40], 512);
        assert_eq!(sizes[60], 1024);
        assert_eq!(sizes[80], 2048);
        assert_eq!(sizes[100], 2048); // capped
    }

    #[test]
    fn divebatch_follows_algorithm1_line11() {
        let p = Policy::DiveBatch {
            m0: 128,
            delta: 0.1,
            m_max: 2048,
        };
        // delta_hat = 50 / 25 = 2; target = 0.1 * 10_000 * 2 = 2000.
        assert_eq!(p.next(0, 128, 10_000, stats(50.0, 25.0)), 2000);
        // Cap at m_max.
        assert_eq!(p.next(0, 128, 10_000, stats(500.0, 25.0)), 2048);
        // Floor at m0.
        assert_eq!(p.next(0, 128, 10_000, stats(0.001, 25.0)), 128);
    }

    #[test]
    fn divebatch_degenerate_gradient_keeps_current() {
        let p = Policy::DiveBatch {
            m0: 128,
            delta: 0.1,
            m_max: 2048,
        };
        assert_eq!(p.next(3, 512, 10_000, stats(5.0, 0.0)), 512);
    }

    #[test]
    fn oracle_shares_update_rule() {
        let d = Policy::DiveBatch {
            m0: 128,
            delta: 0.5,
            m_max: 4096,
        };
        let o = Policy::Oracle {
            m0: 128,
            delta: 0.5,
            m_max: 4096,
        };
        let s = stats(30.0, 10.0);
        assert_eq!(d.next(1, 128, 8_000, s), o.next(1, 128, 8_000, s));
        assert_eq!(o.diversity_need(), DiversityNeed::Exact);
        assert_eq!(d.diversity_need(), DiversityNeed::Estimated);
    }

    #[test]
    fn delta_hat_definition() {
        let s = DiversityStats {
            sqnorm_sum: 12.0,
            grad_norm2: 3.0,
        };
        assert!((s.delta_hat() - 4.0).abs() < 1e-12);
        assert!(DiversityStats::default().delta_hat().is_infinite());
    }

    #[test]
    fn labels_match_paper_style() {
        assert_eq!(Policy::Fixed { m: 2048 }.label(), "SGD (2048)");
        assert_eq!(
            Policy::AdaBatch {
                m0: 128,
                factor: 2,
                every: 20,
                m_max: 2048
            }
            .label(),
            "AdaBatch (128 - 2048)"
        );
        assert_eq!(
            Policy::DiveBatch {
                m0: 256,
                delta: 0.01,
                m_max: 2048
            }
            .label(),
            "DiveBatch (256 - 2048)"
        );
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(Policy::parse("sgd:m=128").unwrap(), Policy::Fixed { m: 128 });
        assert_eq!(
            Policy::parse("adabatch:m0=128,mmax=2048").unwrap(),
            Policy::AdaBatch {
                m0: 128,
                factor: 2,
                every: 20,
                m_max: 2048
            }
        );
        assert_eq!(
            Policy::parse("divebatch:m0=256,delta=0.01,mmax=2048").unwrap(),
            Policy::DiveBatch {
                m0: 256,
                delta: 0.01,
                m_max: 2048
            }
        );
        assert!(Policy::parse("bogus").is_err());
        assert!(Policy::parse("sgd").is_err()); // missing m
        assert!(Policy::parse("sgd:m=abc").is_err());
    }

    #[test]
    fn property_divebatch_always_within_bounds() {
        let p = Policy::DiveBatch {
            m0: 64,
            delta: 0.1,
            m_max: 4096,
        };
        forall(
            300,
            |r: &mut Rng| {
                (
                    r.below(1_000_000) as usize + 1, // n... reused as sqnorm scale too
                    (r.next_f64() * 1e6, r.next_f64() * 1e6),
                )
            },
            |&(n, (sq, g2))| {
                let m = p.next(
                    0,
                    64,
                    n,
                    stats(sq, g2),
                );
                (64..=4096).contains(&m)
            },
        );
    }

    #[test]
    fn property_adabatch_monotone_nondecreasing() {
        let p = Policy::AdaBatch {
            m0: 32,
            factor: 2,
            every: 5,
            m_max: 1024,
        };
        let mut m = p.initial();
        for e in 0..200 {
            let next = p.next(e, m, 10_000, None);
            assert!(next >= m);
            m = next;
        }
        assert_eq!(m, 1024);
    }
}
