//! SGLD-style diversity boosting (paper §6 future direction).
//!
//! Yin et al. (2018) §5 show that adding isotropic Gaussian noise to
//! per-sample gradients (stochastic gradient Langevin dynamics) provably
//! *increases gradient diversity*, enabling larger batches.  The paper's
//! §6 proposes integrating this with DiveBatch.
//!
//! Implementation: for per-sample noise `eps_i ~ N(0, sigma^2 I_P)`,
//! the Definition-2 statistics of the noised gradients have closed-form
//! expectations in terms of the *noise-free* statistics the executables
//! already return:
//!
//! ```text
//! E[ sum_i ||g_i + eps_i||^2 ] = sum_i ||g_i||^2 + n * sigma^2 * P
//! E[ || sum_i (g_i + eps_i) ||^2 ] = || sum_i g_i ||^2 + n * sigma^2 * P
//! ```
//!
//! so the coordinator adjusts the accumulated stats analytically — no
//! per-sample noise materialization, no extra executable — and injects
//! the matching noise `N(0, n*sigma^2/m^2 I)` into each mean-gradient
//! update so the *optimization trajectory* is genuine SGLD, not just a
//! re-weighted batch schedule.

use super::policy::DiversityStats;
use crate::util::rng::Rng;

/// SGLD diversity-boost configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SgldConfig {
    /// Per-sample gradient noise std-dev (sigma).  0 disables.
    pub sigma: f64,
}

impl SgldConfig {
    pub fn disabled() -> SgldConfig {
        SgldConfig { sigma: 0.0 }
    }

    pub fn enabled(&self) -> bool {
        self.sigma > 0.0
    }

    /// Adjust epoch diversity statistics for the injected noise
    /// (closed-form expectations above).  `n` = samples accumulated,
    /// `p` = parameter count.
    pub fn adjust_stats(&self, stats: DiversityStats, n: usize, p: usize) -> DiversityStats {
        if !self.enabled() {
            return stats;
        }
        let boost = n as f64 * self.sigma * self.sigma * p as f64;
        DiversityStats {
            sqnorm_sum: stats.sqnorm_sum + boost,
            grad_norm2: stats.grad_norm2 + boost,
        }
    }

    /// Add the update-path noise to a SUM-gradient vector for a logical
    /// batch of `m` samples: `sum_i eps_i ~ N(0, m * sigma^2 I)`, i.e.
    /// std `sigma * sqrt(m)` per coordinate on the sum.
    pub fn perturb_grad_sum(&self, grad_sum: &mut [f32], m: usize, rng: &mut Rng) {
        if !self.enabled() {
            return;
        }
        let std = self.sigma * (m as f64).sqrt();
        for g in grad_sum.iter_mut() {
            *g += rng.normal_ms(0.0, std) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_identity() {
        let cfg = SgldConfig::disabled();
        let s = DiversityStats {
            sqnorm_sum: 10.0,
            grad_norm2: 5.0,
        };
        let out = cfg.adjust_stats(s, 100, 50);
        assert_eq!(out.sqnorm_sum, 10.0);
        assert_eq!(out.grad_norm2, 5.0);
        let mut g = vec![1.0f32; 8];
        cfg.perturb_grad_sum(&mut g, 4, &mut Rng::new(0));
        assert_eq!(g, vec![1.0; 8]);
    }

    #[test]
    fn noise_increases_diversity_toward_one() {
        // Low-diversity stats (identical grads): Delta = 1/n.  Adding
        // noise must push n*Delta upward (Yin et al.'s mechanism).
        let n = 100usize;
        let p = 1000usize;
        // n identical unit grads in 1 coord: sqnorm_sum = n, ||sum||^2 = n^2.
        let s = DiversityStats {
            sqnorm_sum: n as f64,
            grad_norm2: (n * n) as f64,
        };
        let base_ndelta = n as f64 * s.delta_hat();
        assert!((base_ndelta - 1.0).abs() < 1e-9);
        let cfg = SgldConfig { sigma: 0.1 };
        let boosted = cfg.adjust_stats(s, n, p);
        let boosted_ndelta = n as f64 * boosted.delta_hat();
        assert!(
            boosted_ndelta > 5.0,
            "expected a large diversity boost, got {boosted_ndelta}"
        );
        // And the boost saturates at n (perfectly diverse).
        assert!(boosted_ndelta <= n as f64 + 1e-6);
    }

    #[test]
    fn adjustment_matches_monte_carlo() {
        // Empirically verify the closed form: draw per-sample grads and
        // noise, compare measured stats to the analytic adjustment.
        let mut rng = Rng::new(42);
        let (n, p) = (200usize, 30usize);
        let sigma = 0.5;
        // Fixed per-sample grads: g_i = base + small per-sample wiggle.
        let grads: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..p).map(|j| 1.0 + 0.1 * rng.normal() + j as f64 * 0.0).collect())
            .collect();
        let clean_sq: f64 = grads.iter().map(|g| g.iter().map(|x| x * x).sum::<f64>()).sum();
        let mut clean_sum = vec![0.0f64; p];
        for g in &grads {
            for (a, b) in clean_sum.iter_mut().zip(g) {
                *a += b;
            }
        }
        let clean_norm2: f64 = clean_sum.iter().map(|x| x * x).sum();

        // Monte-Carlo noised stats (average over repeats).
        let reps = 60;
        let (mut mc_sq, mut mc_norm2) = (0.0, 0.0);
        for _ in 0..reps {
            let mut sum = vec![0.0f64; p];
            for g in &grads {
                for j in 0..p {
                    let v = g[j] + rng.normal_ms(0.0, sigma);
                    mc_sq += v * v;
                    sum[j] += v;
                }
            }
            mc_norm2 += sum.iter().map(|x| x * x).sum::<f64>();
        }
        mc_sq /= reps as f64;
        mc_norm2 /= reps as f64;

        let adj = SgldConfig { sigma }.adjust_stats(
            DiversityStats {
                sqnorm_sum: clean_sq,
                grad_norm2: clean_norm2,
            },
            n,
            p,
        );
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs();
        assert!(rel(adj.sqnorm_sum, mc_sq) < 0.05, "{} vs {mc_sq}", adj.sqnorm_sum);
        assert!(
            rel(adj.grad_norm2, mc_norm2) < 0.10,
            "{} vs {mc_norm2}",
            adj.grad_norm2
        );
    }

    #[test]
    fn perturbation_scales_with_batch() {
        let cfg = SgldConfig { sigma: 1.0 };
        let mut rng = Rng::new(7);
        let p = 4000;
        let mut g_small = vec![0.0f32; p];
        let mut g_big = vec![0.0f32; p];
        cfg.perturb_grad_sum(&mut g_small, 1, &mut rng);
        cfg.perturb_grad_sum(&mut g_big, 100, &mut rng);
        let var = |g: &[f32]| {
            g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / g.len() as f64
        };
        // Sum-noise variance scales with m: ratio ~ 100.
        let ratio = var(&g_big) / var(&g_small);
        assert!((50.0..200.0).contains(&ratio), "{ratio}");
    }
}
