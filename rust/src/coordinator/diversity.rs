//! Epoch-level gradient-diversity accumulation (Definition 2).
//!
//! During an instrumented epoch the trainer pushes every micro-batch's
//! `(grad_sum, sqnorm_sum)` here; at the epoch boundary `stats()` yields
//! the Definition-2 numerator (`sum_i ||g_i||^2`) and denominator
//! (`||sum_i g_i||^2`) from which the policy computes `Delta_hat`.
//! Gradient accumulation is carried in f64 — across an epoch of 20k
//! samples the f32 executables' sums would otherwise lose precision in
//! the denominator's cancellation-heavy norm.

use super::policy::DiversityStats;

/// Accumulator for one epoch's diversity statistics.
#[derive(Clone, Debug)]
pub struct DiversityAccum {
    grad_sum: Vec<f64>,
    sqnorm_sum: f64,
    samples: usize,
}

impl DiversityAccum {
    pub fn new(param_count: usize) -> DiversityAccum {
        DiversityAccum {
            grad_sum: vec![0.0; param_count],
            sqnorm_sum: 0.0,
            samples: 0,
        }
    }

    /// Add one micro-batch's outputs (sample-sum gradient + sq-norm sum).
    pub fn push(&mut self, grad_sum: &[f32], sqnorm_sum: f64, real_samples: usize) {
        assert_eq!(grad_sum.len(), self.grad_sum.len());
        for (acc, &g) in self.grad_sum.iter_mut().zip(grad_sum) {
            *acc += g as f64;
        }
        self.sqnorm_sum += sqnorm_sum;
        self.samples += real_samples;
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Definition-2 statistics for the epoch so far.
    pub fn stats(&self) -> DiversityStats {
        let grad_norm2: f64 = self.grad_sum.iter().map(|g| g * g).sum();
        DiversityStats {
            sqnorm_sum: self.sqnorm_sum,
            grad_norm2,
        }
    }

    /// `n * Delta_hat` — the quantity Algorithm 1 line 11 scales by delta.
    /// (Exposed for the Figure 2 diversity curves.)
    pub fn n_delta(&self) -> f64 {
        self.samples as f64 * self.stats().delta_hat()
    }

    pub fn reset(&mut self) {
        self.grad_sum.iter_mut().for_each(|g| *g = 0.0);
        self.sqnorm_sum = 0.0;
        self.samples = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_hand_computation() {
        // Three "samples" pushed as two micro-batches of per-sample grads:
        // g1=(1,0), g2=(0,1), g3=(1,1).
        // sum g = (2,2) -> ||.||^2 = 8; sum ||g_i||^2 = 1 + 1 + 2 = 4.
        // Delta = 4/8 = 0.5.
        let mut acc = DiversityAccum::new(2);
        acc.push(&[1.0, 1.0], 2.0, 2); // micro 1: g1+g2, ||g1||²+||g2||²
        acc.push(&[1.0, 1.0], 2.0, 1); // micro 2: g3
        let s = acc.stats();
        assert!((s.sqnorm_sum - 4.0).abs() < 1e-12);
        assert!((s.grad_norm2 - 8.0).abs() < 1e-12);
        assert!((s.delta_hat() - 0.5).abs() < 1e-12);
        assert_eq!(acc.samples(), 3);
        assert!((acc.n_delta() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_gradients_maximize_diversity() {
        // n orthonormal per-sample grads: Delta = n / n = 1... relative to
        // ||sum||^2 = n, sum ||g||^2 = n -> Delta = 1; n*Delta = n (the
        // "maximally diverse" regime where batch can scale to n).
        let n = 8;
        let mut acc = DiversityAccum::new(n);
        for i in 0..n {
            let mut g = vec![0.0f32; n];
            g[i] = 1.0;
            acc.push(&g, 1.0, 1);
        }
        assert!((acc.stats().delta_hat() - 1.0).abs() < 1e-12);
        assert!((acc.n_delta() - n as f64).abs() < 1e-9);
    }

    #[test]
    fn identical_gradients_minimize_diversity() {
        // n identical grads: sum ||g||^2 = n, ||sum||^2 = n^2 -> Delta=1/n.
        let n = 16;
        let mut acc = DiversityAccum::new(4);
        for _ in 0..n {
            acc.push(&[1.0, 2.0, 3.0, 4.0], 30.0, 1);
        }
        let d = acc.stats().delta_hat();
        assert!((d - 1.0 / n as f64).abs() < 1e-9, "{d}");
        // n * Delta = 1: gradient diversity says batch size 1 suffices.
        assert!((acc.n_delta() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut acc = DiversityAccum::new(2);
        acc.push(&[1.0, 1.0], 2.0, 1);
        acc.reset();
        assert_eq!(acc.samples(), 0);
        assert_eq!(acc.stats().sqnorm_sum, 0.0);
        assert!(acc.stats().delta_hat().is_infinite());
    }

    // ------------------------------------------------- property tests
    // Definition-2 invariants over randomized gradient sets (seeded
    // mini-prop framework: util::prop; failures shrink + report a seed).

    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn prop_identical_gradients_give_minimal_diversity() {
        // n copies of one gradient: Delta = 1/n, so n*Delta = 1 — the
        // metric's floor, "a batch of 1 already captures everything".
        forall(
            150,
            |r| {
                (
                    r.below(30) as usize + 2,
                    r.below(8) as usize + 1,
                    r.next_u64(),
                )
            },
            |&(n, d, seed)| {
                let mut r = Rng::new(seed);
                let g: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
                let sq: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
                if sq < 1e-6 {
                    return true; // degenerate near-zero draw
                }
                let mut acc = DiversityAccum::new(d);
                for _ in 0..n {
                    acc.push(&g, sq, 1);
                }
                (acc.stats().delta_hat() - 1.0 / n as f64).abs() < 1e-9
                    && (acc.n_delta() - 1.0).abs() < 1e-6
            },
        );
    }

    #[test]
    fn prop_orthogonal_gradients_give_diversity_n() {
        // n mutually orthogonal per-sample gradients (scaled axes of R^n):
        // ||sum g||^2 = sum ||g||^2, so Delta = 1 and n*Delta = n — full
        // batch-size headroom — for ANY per-axis scales.
        forall(
            150,
            |r| (r.below(16) as usize + 2, r.next_u64()),
            |&(n, seed)| {
                let mut r = Rng::new(seed);
                let mut acc = DiversityAccum::new(n);
                for i in 0..n {
                    let s = r.uniform(0.2, 3.0) as f32; // bounded away from 0
                    let mut g = vec![0.0f32; n];
                    g[i] = s;
                    acc.push(&g, (s as f64) * (s as f64), 1);
                }
                (acc.stats().delta_hat() - 1.0).abs() < 1e-9
                    && (acc.n_delta() - n as f64).abs() < 1e-6 * n as f64
            },
        );
    }

    #[test]
    fn prop_metric_invariant_under_gradient_permutation() {
        // Definition 2 is a sum over samples: the push order must not
        // change the statistics (up to f64 re-association noise).
        forall(
            150,
            |r| {
                (
                    r.below(10) as usize + 2,
                    r.below(6) as usize + 1,
                    r.next_u64(),
                )
            },
            |&(k, d, seed)| {
                let mut r = Rng::new(seed);
                let gs: Vec<Vec<f32>> = (0..k)
                    .map(|_| (0..d).map(|_| r.normal() as f32).collect())
                    .collect();
                let sq: Vec<f64> = gs
                    .iter()
                    .map(|g| g.iter().map(|&x| (x as f64) * (x as f64)).sum())
                    .collect();
                let mut fwd = DiversityAccum::new(d);
                for i in 0..k {
                    fwd.push(&gs[i], sq[i], 1);
                }
                let perm = r.permutation(k);
                let mut per = DiversityAccum::new(d);
                for &i in &perm {
                    per.push(&gs[i as usize], sq[i as usize], 1);
                }
                if fwd.samples() != per.samples() {
                    return false;
                }
                let (a, b) = (fwd.stats().delta_hat(), per.stats().delta_hat());
                if !a.is_finite() {
                    return !b.is_finite(); // all-zero-gradient draw
                }
                (a - b).abs() <= 1e-9 * a.abs().max(1.0)
            },
        );
    }

    #[test]
    fn f64_accumulation_avoids_f32_cancellation() {
        // Alternating large +/- f32 grads whose true sum is tiny: f32
        // accumulation would drift; f64 keeps the denominator meaningful.
        let mut acc = DiversityAccum::new(1);
        for i in 0..10_000 {
            let g = if i % 2 == 0 { 1e5f32 } else { -1e5f32 + 0.25 };
            acc.push(&[g], (g as f64) * (g as f64), 1);
        }
        // True sum = 5000 * 0.25 = 1250.
        let s = acc.stats();
        assert!((s.grad_norm2.sqrt() - 1250.0).abs() < 1.0, "{}", s.grad_norm2.sqrt());
    }
}
