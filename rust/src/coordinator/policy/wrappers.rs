//! Composable policy combinators: [`Warmup`], [`Clamp`], [`Ema`]
//! (EMA-smoothed hysteresis), and [`Chain`].
//!
//! Wrappers implement [`BatchPolicy`] over an inner boxed policy, so they
//! nest arbitrarily.  The first three are registry-parseable with the
//! `wrapper:.../base:...` spec grammar (leftmost segment = outermost
//! wrapper); [`Chain`] takes two child policies and is programmatic-only.

use super::api::{AdaptContext, BatchPolicy, Decision, PolicyError};
use super::registry::{Build, ParamMap, ParamSpec, PolicyEntry};
use super::DiversityNeed;

// ---------------------------------------------------------------- Warmup

/// Hold the batch size at `m` for the first `epochs` epochs, then hand
/// over to the inner policy (which starts from its own `initial()`).
/// Warmup epochs run uninstrumented (their stats would be discarded —
/// that is the point of warming up cheaply); the handover decision
/// switches instrumentation on so the inner policy's first real
/// decision has stats.  The inner policy does not observe warmup
/// epochs.
pub struct Warmup {
    pub epochs: usize,
    pub m: usize,
    pub inner: Box<dyn BatchPolicy>,
}

pub const WARMUP_PARAMS: &[ParamSpec] = &[
    ParamSpec {
        key: "epochs",
        default: None,
        help: "number of warmup epochs",
    },
    ParamSpec {
        key: "m",
        default: None,
        help: "batch size held during warmup",
    },
];

impl BatchPolicy for Warmup {
    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn label(&self) -> String {
        format!("Warmup({}x{}) {}", self.m, self.epochs, self.inner.label())
    }

    fn initial(&self) -> usize {
        if self.epochs > 0 {
            self.m
        } else {
            self.inner.initial()
        }
    }

    fn rescale_reference(&self) -> usize {
        // The inner policy's lr/batch pairing is what the schedule was
        // tuned for; the warmup batch must not skew Goyal rescaling.
        self.inner.rescale_reference()
    }

    fn diversity_need(&self) -> DiversityNeed {
        if self.epochs > 0 {
            DiversityNeed::None
        } else {
            self.inner.diversity_need()
        }
    }

    fn wants_step_decisions(&self) -> bool {
        self.inner.wants_step_decisions()
    }

    fn on_epoch_start(&mut self, ctx: &AdaptContext) {
        if ctx.epoch >= self.epochs {
            self.inner.on_epoch_start(ctx);
        }
    }

    fn on_step(&mut self, ctx: &AdaptContext) -> Option<Decision> {
        if ctx.epoch >= self.epochs {
            self.inner.on_step(ctx)
        } else {
            None
        }
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        if ctx.epoch + 1 < self.epochs {
            // Still warming up next epoch: no instrumentation yet.
            Ok(Decision::new(self.m, DiversityNeed::None))
        } else if ctx.epoch + 1 == self.epochs {
            // Warmup expires: the inner policy takes over from its own
            // initial batch size next epoch, with its instrumentation.
            Ok(Decision::new(
                self.inner.initial(),
                self.inner.diversity_need(),
            ))
        } else {
            self.inner.on_epoch_end(ctx)
        }
    }

    fn render_spec(&self) -> String {
        format!(
            "warmup:epochs={},m={}/{}",
            self.epochs,
            self.m,
            self.inner.render_spec()
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(Warmup {
            epochs: self.epochs,
            m: self.m,
            inner: self.inner.clone_box(),
        })
    }
}

// ----------------------------------------------------------------- Clamp

/// Clamp every decision of the inner policy into `[min, max]`.
pub struct Clamp {
    pub min: usize,
    pub max: usize,
    pub inner: Box<dyn BatchPolicy>,
}

pub const CLAMP_PARAMS: &[ParamSpec] = &[
    ParamSpec {
        key: "min",
        default: Some("1"),
        help: "lower batch-size bound",
    },
    ParamSpec {
        key: "max",
        default: None,
        help: "upper batch-size bound",
    },
];

impl Clamp {
    fn bound(&self, m: usize) -> usize {
        m.clamp(self.min, self.max)
    }
}

impl BatchPolicy for Clamp {
    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn label(&self) -> String {
        format!("Clamp({}-{}) {}", self.min, self.max, self.inner.label())
    }

    fn initial(&self) -> usize {
        self.bound(self.inner.initial())
    }

    fn rescale_reference(&self) -> usize {
        self.inner.rescale_reference()
    }

    fn diversity_need(&self) -> DiversityNeed {
        self.inner.diversity_need()
    }

    fn wants_step_decisions(&self) -> bool {
        self.inner.wants_step_decisions()
    }

    fn on_epoch_start(&mut self, ctx: &AdaptContext) {
        self.inner.on_epoch_start(ctx);
    }

    fn on_step(&mut self, ctx: &AdaptContext) -> Option<Decision> {
        self.inner.on_step(ctx).map(|mut d| {
            d.next_batch = self.bound(d.next_batch);
            d
        })
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let mut d = self.inner.on_epoch_end(ctx)?;
        d.next_batch = self.bound(d.next_batch);
        Ok(d)
    }

    fn render_spec(&self) -> String {
        format!(
            "clamp:min={},max={}/{}",
            self.min,
            self.max,
            self.inner.render_spec()
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(Clamp {
            min: self.min,
            max: self.max,
            inner: self.inner.clone_box(),
        })
    }
}

// ------------------------------------------------------------------- Ema

/// EMA-smoothed hysteresis over the inner policy's batch-size decisions:
/// targets are exponentially smoothed (`s <- beta*s + (1-beta)*target`)
/// and the actual batch only moves when the smoothed value deviates from
/// the current size by at least `band` (relative).  `band = 0` always
/// tracks the smoothed value; larger bands suppress oscillation (the
/// re-compilation / re-planning cost of a batch-size change is the whole
/// point of hysteresis).
pub struct Ema {
    pub beta: f64,
    pub band: f64,
    pub inner: Box<dyn BatchPolicy>,
    state: Option<f64>,
}

pub const EMA_PARAMS: &[ParamSpec] = &[
    ParamSpec {
        key: "beta",
        default: Some("0.5"),
        help: "EMA coefficient in [0, 1): weight on the previous value",
    },
    ParamSpec {
        key: "band",
        default: Some("0"),
        help: "relative dead-band; only move when |s - m| / m >= band",
    },
];

impl Ema {
    pub fn new(beta: f64, band: f64, inner: Box<dyn BatchPolicy>) -> Ema {
        Ema {
            beta,
            band,
            inner,
            state: None,
        }
    }
}

impl BatchPolicy for Ema {
    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn label(&self) -> String {
        format!("EMA({}) {}", self.beta, self.inner.label())
    }

    fn initial(&self) -> usize {
        self.inner.initial()
    }

    fn rescale_reference(&self) -> usize {
        self.inner.rescale_reference()
    }

    fn diversity_need(&self) -> DiversityNeed {
        self.inner.diversity_need()
    }

    fn wants_step_decisions(&self) -> bool {
        self.inner.wants_step_decisions()
    }

    fn on_epoch_start(&mut self, ctx: &AdaptContext) {
        self.inner.on_epoch_start(ctx);
    }

    fn on_step(&mut self, ctx: &AdaptContext) -> Option<Decision> {
        // Step decisions pass through unsmoothed: they are already rare
        // and policy-initiated; the EMA targets epoch boundaries.
        self.inner.on_step(ctx)
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let mut d = self.inner.on_epoch_end(ctx)?;
        let raw = d.next_batch as f64;
        let s = match self.state {
            Some(prev) => self.beta * prev + (1.0 - self.beta) * raw,
            None => raw,
        };
        self.state = Some(s);
        let cur = ctx.batch_size.max(1) as f64;
        if ((s - cur).abs() / cur) >= self.band {
            d.next_batch = s.round().max(1.0) as usize;
        } else {
            d.next_batch = ctx.batch_size;
        }
        Ok(d)
    }

    fn render_spec(&self) -> String {
        format!(
            "ema:beta={},band={}/{}",
            self.beta,
            self.band,
            self.inner.render_spec()
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(Ema {
            beta: self.beta,
            band: self.band,
            inner: self.inner.clone_box(),
            state: self.state,
        })
    }
}

// ----------------------------------------------------------------- Chain

/// Run `first` for epochs `[0, at)`, then `second` (from its own
/// `initial()`) for the rest of training.  Children see absolute epoch
/// numbers.  Programmatic-only: `render_spec` emits a descriptive,
/// non-parseable form.
pub struct Chain {
    pub at: usize,
    pub first: Box<dyn BatchPolicy>,
    pub second: Box<dyn BatchPolicy>,
}

impl Chain {
    fn active(&mut self, epoch: usize) -> &mut Box<dyn BatchPolicy> {
        if epoch < self.at {
            &mut self.first
        } else {
            &mut self.second
        }
    }
}

impl BatchPolicy for Chain {
    fn kind(&self) -> &'static str {
        self.second.kind()
    }

    fn label(&self) -> String {
        format!(
            "{} then {} (@{})",
            self.first.label(),
            self.second.label(),
            self.at
        )
    }

    fn initial(&self) -> usize {
        if self.at > 0 {
            self.first.initial()
        } else {
            self.second.initial()
        }
    }

    fn rescale_reference(&self) -> usize {
        // The schedule's base lr is tuned for the policy that starts
        // the run; the reference does not switch at the handover.
        if self.at > 0 {
            self.first.rescale_reference()
        } else {
            self.second.rescale_reference()
        }
    }

    fn diversity_need(&self) -> DiversityNeed {
        if self.at > 0 {
            self.first.diversity_need()
        } else {
            self.second.diversity_need()
        }
    }

    fn wants_step_decisions(&self) -> bool {
        self.first.wants_step_decisions() || self.second.wants_step_decisions()
    }

    fn on_epoch_start(&mut self, ctx: &AdaptContext) {
        self.active(ctx.epoch).on_epoch_start(ctx);
    }

    fn on_step(&mut self, ctx: &AdaptContext) -> Option<Decision> {
        self.active(ctx.epoch).on_step(ctx)
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        if ctx.epoch + 1 == self.at {
            // Handover boundary: the second policy starts fresh.
            Ok(Decision::new(
                self.second.initial(),
                self.second.diversity_need(),
            ))
        } else {
            self.active(ctx.epoch).on_epoch_end(ctx)
        }
    }

    fn render_spec(&self) -> String {
        format!(
            "chain(at={},{},{})",
            self.at,
            self.first.render_spec(),
            self.second.render_spec()
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(Chain {
            at: self.at,
            first: self.first.clone_box(),
            second: self.second.clone_box(),
        })
    }
}

// ----------------------------------------------------- registry entries

pub(crate) fn entries() -> Vec<PolicyEntry> {
    vec![
        PolicyEntry {
            name: "warmup",
            aliases: &[],
            summary: "hold a fixed batch for the first N epochs, then delegate",
            params: WARMUP_PARAMS,
            build: Build::Wrapper(|p: &ParamMap, inner| {
                let m = p.usize("m")?;
                if m == 0 {
                    return Err(PolicyError::BadValue {
                        policy: "warmup".into(),
                        key: "m".into(),
                        value: "0".into(),
                        reason: "batch size must be >= 1".into(),
                    });
                }
                Ok(Box::new(Warmup {
                    epochs: p.usize("epochs")?,
                    m,
                    inner,
                }))
            }),
        },
        PolicyEntry {
            name: "clamp",
            aliases: &[],
            summary: "clamp the inner policy's batch sizes into [min, max]",
            params: CLAMP_PARAMS,
            build: Build::Wrapper(|p: &ParamMap, inner| {
                let (min, max) = (p.usize("min")?, p.usize("max")?);
                if min == 0 || min > max {
                    return Err(PolicyError::BadValue {
                        policy: "clamp".into(),
                        key: "min".into(),
                        value: min.to_string(),
                        reason: format!("need 1 <= min <= max ({max})"),
                    });
                }
                Ok(Box::new(Clamp { min, max, inner }))
            }),
        },
        PolicyEntry {
            name: "ema",
            aliases: &["hysteresis"],
            summary: "EMA-smooth the inner decisions with a relative dead-band",
            params: EMA_PARAMS,
            build: Build::Wrapper(|p: &ParamMap, inner| {
                let (beta, band) = (p.f64("beta")?, p.f64("band")?);
                if !(0.0..1.0).contains(&beta) {
                    return Err(PolicyError::BadValue {
                        policy: "ema".into(),
                        key: "beta".into(),
                        value: beta.to_string(),
                        reason: "need 0 <= beta < 1".into(),
                    });
                }
                if band.is_nan() || band < 0.0 {
                    return Err(PolicyError::BadValue {
                        policy: "ema".into(),
                        key: "band".into(),
                        value: band.to_string(),
                        reason: "need band >= 0".into(),
                    });
                }
                Ok(Box::new(Ema::new(beta, band, inner)))
            }),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::super::baselines::{AdaBatch, DiveBatch, Fixed};
    use super::super::{DiversityNeed, DiversityStats};
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn ctx(
        epoch: usize,
        batch_size: usize,
        n: usize,
        stats: Option<DiversityStats>,
    ) -> AdaptContext<'static> {
        AdaptContext {
            epoch,
            step: 0,
            batch_size,
            n,
            m0: batch_size,
            stats,
            history: &[],
            sim_elapsed: 0.0,
            wall_elapsed: 0.0,
        }
    }

    fn stats(sq: f64, g2: f64) -> Option<DiversityStats> {
        Some(DiversityStats {
            sqnorm_sum: sq,
            grad_norm2: g2,
        })
    }

    /// Drive a policy through `stream` epoch boundaries the way the
    /// trainer does (instrumentation follows each decision's `need`),
    /// returning the batch-size trajectory including the initial size.
    fn trajectory(p: &mut dyn BatchPolicy, n: usize, stream: &[(f64, f64)]) -> Vec<usize> {
        let mut m = p.initial();
        let mut need = p.diversity_need();
        let mut out = vec![m];
        for (e, &(sq, g2)) in stream.iter().enumerate() {
            let s = match need {
                DiversityNeed::None => None,
                _ => stats(sq, g2),
            };
            let d = p.on_epoch_end(&ctx(e, m, n, s)).unwrap();
            m = d.next_batch;
            need = d.need;
            out.push(m);
        }
        out
    }

    #[test]
    fn warmup_holds_then_hands_over() {
        let mut p = Warmup {
            epochs: 3,
            m: 2,
            inner: Box::new(Fixed { m: 8 }),
        };
        assert_eq!(p.initial(), 2);
        assert_eq!(p.kind(), "sgd");
        let t = trajectory(&mut p, 100, &[(0.0, 0.0); 6]);
        assert_eq!(t, vec![2, 2, 2, 8, 8, 8, 8]);
    }

    #[test]
    fn warmup_zero_epochs_is_transparent() {
        let mut p = Warmup {
            epochs: 0,
            m: 2,
            inner: Box::new(Fixed { m: 8 }),
        };
        assert_eq!(p.initial(), 8);
        assert_eq!(trajectory(&mut p, 100, &[(0.0, 0.0); 3]), vec![8, 8, 8, 8]);
    }

    #[test]
    fn warmup_runs_uninstrumented_then_switches_need_on() {
        let mut p = Warmup {
            epochs: 2,
            m: 4,
            inner: Box::new(DiveBatch {
                m0: 8,
                delta: 0.1,
                m_max: 64,
            }),
        };
        // Warmup epochs pay no instrumentation...
        assert_eq!(p.diversity_need(), DiversityNeed::None);
        let d0 = p.on_epoch_end(&ctx(0, 4, 1000, None)).unwrap();
        assert_eq!((d0.next_batch, d0.need), (4, DiversityNeed::None));
        // ...the handover decision turns the inner policy's need on so
        // its first real decision (end of epoch 2) has stats.
        let d1 = p.on_epoch_end(&ctx(1, 4, 1000, None)).unwrap();
        assert_eq!((d1.next_batch, d1.need), (8, DiversityNeed::Estimated));
        let d2 = p.on_epoch_end(&ctx(2, 8, 1000, stats(50.0, 25.0))).unwrap();
        assert_eq!(d2.need, DiversityNeed::Estimated);
        assert!(d2.next_batch >= 8);
        // The Goyal reference is the inner policy's m0, not the warmup
        // batch — a warmup at m=4 must not inflate the rescaled lr.
        assert_eq!(p.rescale_reference(), 8);
    }

    #[test]
    fn clamp_bounds_inner_decisions() {
        let mut p = Clamp {
            min: 16,
            max: 64,
            inner: Box::new(DiveBatch {
                m0: 4,
                delta: 1.0,
                m_max: 4096,
            }),
        };
        assert_eq!(p.initial(), 16); // inner m0=4 pulled up
        // Huge diversity target -> capped at 64, not inner's 4096.
        let d = p.on_epoch_end(&ctx(0, 16, 10_000, stats(100.0, 1.0))).unwrap();
        assert_eq!(d.next_batch, 64);
        assert_eq!(d.need, DiversityNeed::Estimated);
    }

    #[test]
    fn ema_smooths_and_dead_bands() {
        // Inner jumps straight to 100; beta=0.5 smooths the first step to
        // 100 (no previous state), so use two different targets.
        let mut p = Ema::new(
            0.5,
            0.0,
            Box::new(DiveBatch {
                m0: 10,
                delta: 1.0,
                m_max: 1000,
            }),
        );
        // Epoch 0: raw target = 1 * 100 * (50/25=2) = 200 -> state = 200.
        let d0 = p.on_epoch_end(&ctx(0, 10, 100, stats(50.0, 25.0))).unwrap();
        assert_eq!(d0.next_batch, 100); // raw clamped to n by inner...

        // Re-run with explicit numbers: inner target at n=1000,
        // delta_hat=2 -> 1000*2 = 2000 -> capped at m_max=1000.
        let mut p = Ema::new(
            0.5,
            0.0,
            Box::new(DiveBatch {
                m0: 10,
                delta: 1.0,
                m_max: 1000,
            }),
        );
        let d0 = p.on_epoch_end(&ctx(0, 10, 1000, stats(50.0, 25.0))).unwrap();
        assert_eq!(d0.next_batch, 1000); // first observation seeds the EMA
        // Now inner says 10 (tiny diversity): smoothed = 0.5*1000 + 0.5*10 = 505.
        let d1 = p
            .on_epoch_end(&ctx(1, 1000, 1000, stats(0.001, 25.0)))
            .unwrap();
        assert_eq!(d1.next_batch, 505);
    }

    #[test]
    fn ema_dead_band_suppresses_small_moves() {
        let mut p = Ema::new(
            0.0, // no smoothing: track raw targets
            0.5, // but only move on >= 50% relative change
            Box::new(DiveBatch {
                m0: 10,
                delta: 1.0,
                m_max: 1000,
            }),
        );
        // Raw target 120 vs current 100: 20% < 50% -> stay at 100.
        // delta_hat = 0.12 at n=1000 gives target 120.
        let d = p
            .on_epoch_end(&ctx(0, 100, 1000, stats(0.12, 1.0)))
            .unwrap();
        assert_eq!(d.next_batch, 100);
        // Raw target 800 vs current 100: 700% -> move.
        let d = p.on_epoch_end(&ctx(1, 100, 1000, stats(0.8, 1.0))).unwrap();
        assert_eq!(d.next_batch, 800);
    }

    #[test]
    fn chain_switches_policies_at_epoch() {
        let mut p = Chain {
            at: 3,
            first: Box::new(Fixed { m: 4 }),
            second: Box::new(AdaBatch {
                m0: 16,
                factor: 2,
                every: 2,
                m_max: 64,
            }),
        };
        assert_eq!(p.initial(), 4);
        let t = trajectory(&mut p, 1000, &[(0.0, 0.0); 8]);
        // Epochs 0-2 fixed at 4; epoch 3 starts AdaBatch at 16; AdaBatch
        // grows when (epoch+1) % 2 == 0 (absolute epochs): e=3 -> 32,
        // e=5 -> 64 (cap), ...
        assert_eq!(t, vec![4, 4, 4, 16, 32, 32, 64, 64, 64]);
    }

    #[test]
    fn wrappers_compose() {
        // Clamp over Warmup over DiveBatch: warmup's forced size is also
        // clamped on epoch boundaries it emits.
        let mut p = Clamp {
            min: 8,
            max: 32,
            inner: Box::new(Warmup {
                epochs: 2,
                m: 2,
                inner: Box::new(DiveBatch {
                    m0: 4,
                    delta: 1.0,
                    m_max: 4096,
                }),
            }),
        };
        assert_eq!(p.initial(), 8); // warmup 2 pulled up by clamp
        let t = trajectory(&mut p, 10_000, &[(50.0, 25.0); 4]);
        assert!(t.iter().all(|&m| (8..=32).contains(&m)), "{t:?}");
        assert_eq!(
            p.render_spec(),
            "clamp:min=8,max=32/warmup:epochs=2,m=2/divebatch:m0=4,delta=1,mmax=4096"
        );
    }

    #[test]
    fn property_clamped_divebatch_stays_in_bounds_under_random_stats() {
        forall(
            200,
            |r: &mut Rng| {
                (0..12)
                    .map(|_| (r.next_f64() * 1e6, r.next_f64() * 1e6))
                    .collect::<Vec<(f64, f64)>>()
            },
            |stream| {
                let mut p = Clamp {
                    min: 16,
                    max: 256,
                    inner: Box::new(DiveBatch {
                        m0: 4,
                        delta: 0.1,
                        m_max: 4096,
                    }),
                };
                trajectory(&mut p, 10_000, stream)
                    .iter()
                    .all(|&m| (16..=256).contains(&m))
            },
        );
    }

    #[test]
    fn property_warmup_respects_m0_mmax_invariant_after_handover() {
        forall(
            200,
            |r: &mut Rng| {
                (0..10)
                    .map(|_| (r.next_f64() * 1e6, r.next_f64() * 1e6))
                    .collect::<Vec<(f64, f64)>>()
            },
            |stream| {
                let (m0, m_max, warm) = (32usize, 512usize, 3usize);
                let mut p = Warmup {
                    epochs: warm,
                    m: 8,
                    inner: Box::new(DiveBatch {
                        m0,
                        delta: 0.1,
                        m_max,
                    }),
                };
                let t = trajectory(&mut p, 100_000, stream);
                t.iter().enumerate().all(|(e, &m)| {
                    if e < warm {
                        m == 8 // forced warmup size
                    } else {
                        (m0..=m_max).contains(&m) // inner invariant
                    }
                })
            },
        );
    }
}
