//! The original closed `Policy` enum, kept as a thin configuration shim.
//!
//! Presets, examples, and tests construct policies as plain enum values;
//! `From<Policy> for PolicyHandle` maps each variant onto its
//! [`baselines`] trait implementation, so `TrainConfig::new(model,
//! Policy::DiveBatch { .. }, ...)` keeps compiling unchanged.  New code
//! (and anything reachable from the CLI) should go through
//! [`super::PolicyRegistry`] instead — the enum cannot represent
//! wrappers or out-of-tree policies.

use std::fmt;

use super::api::PolicyHandle;
use super::baselines::{self, ADABATCH_PARAMS, DIVEBATCH_PARAMS, SGD_PARAMS};
use super::registry::{suggest, ParamMap};
use super::{DiversityNeed, DiversityStats};

/// A batch-size adaptation policy (closed built-in set).
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Fixed-batch mini-batch SGD (the paper's SGD baselines).
    Fixed { m: usize },
    /// AdaBatch (Devarakonda et al. 2018): multiply the batch size by
    /// `factor` every `every` epochs, capped at `m_max`.
    AdaBatch {
        m0: usize,
        factor: usize,
        every: usize,
        m_max: usize,
    },
    /// DiveBatch (Algorithm 1): `m_{k+1} = min(m_max, delta * n * Delta_hat)`.
    DiveBatch { m0: usize, delta: f64, m_max: usize },
    /// Oracle: DiveBatch's update rule driven by the *exact* gradient
    /// diversity of the full dataset (section 5.1 ablation).
    Oracle { m0: usize, delta: f64, m_max: usize },
}

impl Policy {
    /// Batch size for epoch 0.
    pub fn initial(&self) -> usize {
        match *self {
            Policy::Fixed { m } => m,
            Policy::AdaBatch { m0, .. } => m0,
            Policy::DiveBatch { m0, .. } => m0,
            Policy::Oracle { m0, .. } => m0,
        }
    }

    pub fn diversity_need(&self) -> DiversityNeed {
        match self {
            Policy::Fixed { .. } | Policy::AdaBatch { .. } => DiversityNeed::None,
            Policy::DiveBatch { .. } => DiversityNeed::Estimated,
            Policy::Oracle { .. } => DiversityNeed::Exact,
        }
    }

    /// Batch size for epoch `epoch + 1`, given the size used during
    /// `epoch`, the dataset size `n`, and (for diversity policies) the
    /// epoch's diversity statistics.
    ///
    /// Kept for compatibility; the trainer now drives the equivalent
    /// [`super::BatchPolicy`] implementations.  Unlike the trait API this
    /// panics when a diversity policy is called without stats.
    pub fn next(
        &self,
        epoch: usize,
        current: usize,
        n: usize,
        stats: Option<DiversityStats>,
    ) -> usize {
        match *self {
            Policy::Fixed { m } => m,
            Policy::AdaBatch {
                factor,
                every,
                m_max,
                ..
            } => {
                if every > 0 && (epoch + 1) % every == 0 {
                    (current * factor.max(1)).min(m_max)
                } else {
                    current
                }
            }
            Policy::DiveBatch { m0, delta, m_max } | Policy::Oracle { m0, delta, m_max } => {
                let stats = stats.expect("diversity policy requires stats");
                baselines::divebatch_next(m0, delta, m_max, current, n, stats)
            }
        }
    }

    /// Human-readable label matching the paper's table rows, e.g.
    /// `SGD (128)`, `AdaBatch (128 - 2048)`, `DiveBatch (128 - 2048)`.
    pub fn label(&self) -> String {
        match *self {
            Policy::Fixed { m } => format!("SGD ({m})"),
            Policy::AdaBatch { m0, m_max, .. } => format!("AdaBatch ({m0} - {m_max})"),
            Policy::DiveBatch { m0, m_max, .. } => format!("DiveBatch ({m0} - {m_max})"),
            Policy::Oracle { m0, m_max, .. } => format!("Oracle ({m0} - {m_max})"),
        }
    }

    /// Short machine name for file paths / CLI.
    pub fn kind(&self) -> &'static str {
        match self {
            Policy::Fixed { .. } => "sgd",
            Policy::AdaBatch { .. } => "adabatch",
            Policy::DiveBatch { .. } => "divebatch",
            Policy::Oracle { .. } => "oracle",
        }
    }

    /// Parse a CLI policy spec into the enum, e.g.:
    /// `sgd:m=128` | `adabatch:m0=128,factor=2,every=20,mmax=2048` |
    /// `divebatch:m0=128,delta=0.1,mmax=2048` | `oracle:m0=512,delta=0.1,mmax=8192`
    ///
    /// Strict like the registry: unknown parameters are rejected with a
    /// "did you mean" suggestion, and values are validated the same way
    /// (`m >= 1`, `m0 <= mmax`) so the two parse surfaces agree instead
    /// of deferring failure to a trainer assert.  Wrapper specs
    /// (`warmup:.../...`) and out-of-tree policies are registry-only —
    /// use [`super::PolicyRegistry::parse`].
    pub fn parse(spec: &str) -> Result<Policy, String> {
        let (kind, rest) = spec.split_once(':').unwrap_or((spec, ""));
        let params = |allowed| ParamMap::from_spec(kind, rest, allowed).map_err(|e| e.to_string());
        let e = |err: super::PolicyError| err.to_string();
        match kind {
            "sgd" | "fixed" => {
                let m = params(SGD_PARAMS)?.usize("m").map_err(e)?;
                if m == 0 {
                    return Err(format!("bad m=0 for policy {kind}: batch size must be >= 1"));
                }
                Ok(Policy::Fixed { m })
            }
            "adabatch" => {
                let p = params(ADABATCH_PARAMS)?;
                let (m0, m_max) = (p.usize("m0").map_err(e)?, p.usize("mmax").map_err(e)?);
                baselines::check_bounds("adabatch", m0, m_max).map_err(e)?;
                Ok(Policy::AdaBatch {
                    m0,
                    factor: p.usize("factor").map_err(e)?,
                    every: p.usize("every").map_err(e)?,
                    m_max,
                })
            }
            "divebatch" | "oracle" => {
                let p = params(DIVEBATCH_PARAMS)?;
                let (m0, delta, m_max) = (
                    p.usize("m0").map_err(e)?,
                    p.f64("delta").map_err(e)?,
                    p.usize("mmax").map_err(e)?,
                );
                if kind == "divebatch" {
                    baselines::check_bounds("divebatch", m0, m_max).map_err(e)?;
                    Ok(Policy::DiveBatch { m0, delta, m_max })
                } else {
                    baselines::check_bounds("oracle", m0, m_max).map_err(e)?;
                    Ok(Policy::Oracle { m0, delta, m_max })
                }
            }
            other => Err(super::PolicyError::UnknownPolicy {
                name: other.to_string(),
                suggestion: suggest(
                    other,
                    ["sgd", "fixed", "adabatch", "divebatch", "oracle"].into_iter(),
                ),
            }
            .to_string()),
        }
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

impl From<Policy> for PolicyHandle {
    fn from(p: Policy) -> PolicyHandle {
        let boxed: Box<dyn super::BatchPolicy> = match p {
            Policy::Fixed { m } => Box::new(baselines::Fixed { m }),
            Policy::AdaBatch {
                m0,
                factor,
                every,
                m_max,
            } => Box::new(baselines::AdaBatch {
                m0,
                factor,
                every,
                m_max,
            }),
            Policy::DiveBatch { m0, delta, m_max } => {
                Box::new(baselines::DiveBatch { m0, delta, m_max })
            }
            Policy::Oracle { m0, delta, m_max } => Box::new(baselines::Oracle { m0, delta, m_max }),
        };
        PolicyHandle::new(boxed)
    }
}

/// Presets/tests compare a config's handle against enum literals.
impl PartialEq<Policy> for PolicyHandle {
    fn eq(&self, other: &Policy) -> bool {
        self.spec() == PolicyHandle::from(other.clone()).spec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(sq: f64, g2: f64) -> Option<DiversityStats> {
        Some(DiversityStats {
            sqnorm_sum: sq,
            grad_norm2: g2,
        })
    }

    #[test]
    fn fixed_never_changes() {
        let p = Policy::Fixed { m: 128 };
        for e in 0..100 {
            assert_eq!(p.next(e, 128, 20_000, None), 128);
        }
        assert_eq!(p.diversity_need(), DiversityNeed::None);
    }

    #[test]
    fn adabatch_doubles_every_20() {
        let p = Policy::AdaBatch {
            m0: 128,
            factor: 2,
            every: 20,
            m_max: 2048,
        };
        let mut m = p.initial();
        let mut sizes = vec![m];
        for e in 0..100 {
            m = p.next(e, m, 50_000, None);
            sizes.push(m);
        }
        // Doubles at epochs 19->20, 39->40, ... capped at 2048.
        assert_eq!(sizes[19], 128);
        assert_eq!(sizes[20], 256);
        assert_eq!(sizes[40], 512);
        assert_eq!(sizes[60], 1024);
        assert_eq!(sizes[80], 2048);
        assert_eq!(sizes[100], 2048); // capped
    }

    #[test]
    fn adabatch_edge_cases_pinned() {
        // every = 0: the growth schedule is disabled entirely.
        let p = Policy::AdaBatch {
            m0: 64,
            factor: 4,
            every: 0,
            m_max: 4096,
        };
        for e in 0..100 {
            assert_eq!(p.next(e, 64, 10_000, None), 64);
        }
        // factor = 0: clamped to 1 -> batch never changes, never zeroes.
        let p = Policy::AdaBatch {
            m0: 32,
            factor: 0,
            every: 5,
            m_max: 1024,
        };
        let mut m = p.initial();
        for e in 0..50 {
            m = p.next(e, m, 10_000, None);
            assert_eq!(m, 32);
        }
    }

    #[test]
    fn divebatch_follows_algorithm1_line11() {
        let p = Policy::DiveBatch {
            m0: 128,
            delta: 0.1,
            m_max: 2048,
        };
        // delta_hat = 50 / 25 = 2; target = 0.1 * 10_000 * 2 = 2000.
        assert_eq!(p.next(0, 128, 10_000, stats(50.0, 25.0)), 2000);
        // Cap at m_max.
        assert_eq!(p.next(0, 128, 10_000, stats(500.0, 25.0)), 2048);
        // Floor at m0.
        assert_eq!(p.next(0, 128, 10_000, stats(0.001, 25.0)), 128);
    }

    #[test]
    fn divebatch_degenerate_gradient_keeps_current() {
        let p = Policy::DiveBatch {
            m0: 128,
            delta: 0.1,
            m_max: 2048,
        };
        assert_eq!(p.next(3, 512, 10_000, stats(5.0, 0.0)), 512);
    }

    #[test]
    fn oracle_shares_update_rule() {
        let d = Policy::DiveBatch {
            m0: 128,
            delta: 0.5,
            m_max: 4096,
        };
        let o = Policy::Oracle {
            m0: 128,
            delta: 0.5,
            m_max: 4096,
        };
        let s = stats(30.0, 10.0);
        assert_eq!(d.next(1, 128, 8_000, s), o.next(1, 128, 8_000, s));
        assert_eq!(o.diversity_need(), DiversityNeed::Exact);
        assert_eq!(d.diversity_need(), DiversityNeed::Estimated);
    }

    #[test]
    fn labels_match_paper_style() {
        assert_eq!(Policy::Fixed { m: 2048 }.label(), "SGD (2048)");
        assert_eq!(
            Policy::AdaBatch {
                m0: 128,
                factor: 2,
                every: 20,
                m_max: 2048
            }
            .label(),
            "AdaBatch (128 - 2048)"
        );
        assert_eq!(
            Policy::DiveBatch {
                m0: 256,
                delta: 0.01,
                m_max: 2048
            }
            .label(),
            "DiveBatch (256 - 2048)"
        );
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(Policy::parse("sgd:m=128").unwrap(), Policy::Fixed { m: 128 });
        assert_eq!(
            Policy::parse("adabatch:m0=128,mmax=2048").unwrap(),
            Policy::AdaBatch {
                m0: 128,
                factor: 2,
                every: 20,
                m_max: 2048
            }
        );
        assert_eq!(
            Policy::parse("divebatch:m0=256,delta=0.01,mmax=2048").unwrap(),
            Policy::DiveBatch {
                m0: 256,
                delta: 0.01,
                m_max: 2048
            }
        );
        assert!(Policy::parse("bogus").is_err());
        assert!(Policy::parse("sgd").is_err()); // missing m
        assert!(Policy::parse("sgd:m=abc").is_err());
    }

    #[test]
    fn parse_validates_values_like_the_registry() {
        // Both parse surfaces must agree: these used to construct
        // policies that only failed later, inside the trainer.
        assert!(Policy::parse("sgd:m=0").is_err());
        assert!(Policy::parse("divebatch:m0=4096,mmax=128").is_err());
        assert!(Policy::parse("oracle:m0=0,mmax=128").is_err());
        assert!(Policy::parse("adabatch:m0=512,mmax=64").is_err());
    }

    #[test]
    fn parse_rejects_unknown_keys() {
        // Previously `divebatch:m0=128,tpyo=5,mmax=2048` parsed fine,
        // silently dropping the typo'd key.
        let e = Policy::parse("divebatch:m0=128,tpyo=5,mmax=2048").unwrap_err();
        assert!(e.contains("tpyo"), "{e}");
        // Near-miss keys get a suggestion.
        let e = Policy::parse("divebatch:m0=128,detla=0.5,mmax=2048").unwrap_err();
        assert!(e.contains("delta"), "{e}");
        // And near-miss policy names too.
        let e = Policy::parse("divebatchh:m0=128,mmax=2048").unwrap_err();
        assert!(e.contains("divebatch"), "{e}");
    }

    #[test]
    fn enum_and_handle_agree() {
        let p = Policy::DiveBatch {
            m0: 128,
            delta: 1.0,
            m_max: 4096,
        };
        let h = PolicyHandle::from(p.clone());
        assert_eq!(h.label(), p.label());
        assert_eq!(h.kind(), p.kind());
        assert_eq!(h.initial(), p.initial());
        assert_eq!(h.diversity_need(), p.diversity_need());
        assert_eq!(h, p); // PartialEq<Policy> for PolicyHandle
        assert_eq!(h.spec(), "divebatch:m0=128,delta=1,mmax=4096");
    }

    #[test]
    fn handle_decisions_match_enum_next() {
        // The trait port must be byte-identical to the enum rule.
        use super::super::api::AdaptContext;
        let p = Policy::DiveBatch {
            m0: 64,
            delta: 0.1,
            m_max: 4096,
        };
        let mut b = PolicyHandle::from(p.clone()).build();
        let mut m = p.initial();
        for e in 0..40 {
            let s = stats((e + 1) as f64 * 3.7, 2.5);
            let ctx = AdaptContext {
                epoch: e,
                step: 0,
                batch_size: m,
                n: 10_000,
                m0: 64,
                stats: s,
                history: &[],
                sim_elapsed: 0.0,
                wall_elapsed: 0.0,
            };
            let want = p.next(e, m, 10_000, s);
            let got = b.on_epoch_end(&ctx).unwrap().next_batch;
            assert_eq!(got, want, "epoch {e}");
            m = got;
        }
    }
}
