//! The policy registry: one table owning CLI spec parsing, labels, and
//! kinds for every selectable policy and wrapper.
//!
//! Spec grammar (`--policy` and presets):
//!
//! ```text
//! spec    := (wrapper "/")* base
//! wrapper := name [":" params]          e.g.  warmup:epochs=5,m=32
//! base    := name [":" params]          e.g.  divebatch:m0=128,mmax=4096
//! params  := key "=" value ("," key "=" value)*
//! ```
//!
//! The leftmost segment is the outermost wrapper:
//! `clamp:max=1024/warmup:epochs=5,m=32/divebatch:m0=128,mmax=4096`
//! clamps a warmed-up DiveBatch.  Parsing is strict: unknown policy
//! names and unknown parameters are rejected with a "did you mean"
//! suggestion; required parameters (no default) must be present.
//! `render_spec` of a parsed policy is canonical — parsing it again
//! reconstructs an equivalent policy (round-trip property-tested below).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::OnceLock;

use super::api::{BatchPolicy, PolicyError, PolicyHandle};
use super::{baselines, smoothed, wrappers};

/// One declared parameter of a policy spec.
#[derive(Clone, Copy, Debug)]
pub struct ParamSpec {
    pub key: &'static str,
    /// `None` = required.
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Validated `key=value` parameters for one spec segment, with defaults
/// materialized.  Construction rejects unknown keys (did-you-mean) and
/// missing required keys.
#[derive(Clone, Debug)]
pub struct ParamMap {
    policy: String,
    kv: BTreeMap<String, String>,
}

impl ParamMap {
    pub fn from_spec(policy: &str, rest: &str, allowed: &[ParamSpec]) -> Result<ParamMap, PolicyError> {
        let mut kv = BTreeMap::new();
        for pair in rest.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = pair.split_once('=').ok_or_else(|| PolicyError::BadSpec {
                spec: pair.to_string(),
                msg: "expected key=value".into(),
            })?;
            let k = k.trim();
            if !allowed.iter().any(|p| p.key == k) {
                return Err(PolicyError::UnknownParam {
                    policy: policy.to_string(),
                    key: k.to_string(),
                    suggestion: suggest(k, allowed.iter().map(|p| p.key)),
                });
            }
            if kv.insert(k.to_string(), v.trim().to_string()).is_some() {
                return Err(PolicyError::DuplicateParam {
                    policy: policy.to_string(),
                    key: k.to_string(),
                });
            }
        }
        for p in allowed {
            if !kv.contains_key(p.key) {
                match p.default {
                    Some(d) => {
                        kv.insert(p.key.to_string(), d.to_string());
                    }
                    None => {
                        return Err(PolicyError::MissingParam {
                            policy: policy.to_string(),
                            key: p.key.to_string(),
                        })
                    }
                }
            }
        }
        Ok(ParamMap {
            policy: policy.to_string(),
            kv,
        })
    }

    pub fn usize(&self, key: &str) -> Result<usize, PolicyError> {
        self.parse(key)
    }

    pub fn f64(&self, key: &str) -> Result<f64, PolicyError> {
        self.parse(key)
    }

    fn parse<T: std::str::FromStr>(&self, key: &str) -> Result<T, PolicyError> {
        let v = self.kv.get(key).ok_or_else(|| PolicyError::MissingParam {
            policy: self.policy.clone(),
            key: key.to_string(),
        })?;
        v.parse().map_err(|_| PolicyError::BadValue {
            policy: self.policy.clone(),
            key: key.to_string(),
            value: v.clone(),
            reason: "unparseable number".into(),
        })
    }
}

/// Levenshtein distance — inputs are short policy/param names.
fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate within edit distance 2, for "did you mean".
pub(crate) fn suggest<'a>(key: &str, candidates: impl Iterator<Item = &'a str>) -> Option<String> {
    candidates
        .map(|c| (levenshtein(key, c), c))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, c)| c.to_string())
}

/// How a registry entry constructs its policy.
#[derive(Clone, Copy)]
pub enum Build {
    /// Terminal policy: params -> policy.
    Base(fn(&ParamMap) -> Result<Box<dyn BatchPolicy>, PolicyError>),
    /// Combinator: params + inner policy -> wrapped policy.
    Wrapper(fn(&ParamMap, Box<dyn BatchPolicy>) -> Result<Box<dyn BatchPolicy>, PolicyError>),
}

/// One selectable policy or wrapper.
pub struct PolicyEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    pub params: &'static [ParamSpec],
    pub build: Build,
}

impl PolicyEntry {
    pub fn is_wrapper(&self) -> bool {
        matches!(self.build, Build::Wrapper(_))
    }
}

/// The registry.  [`PolicyRegistry::builtin`] is the process-wide table
/// behind the CLI; custom experiments can build their own with
/// [`PolicyRegistry::new`] + [`PolicyRegistry::register`].
#[derive(Default)]
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    pub fn new() -> PolicyRegistry {
        PolicyRegistry::default()
    }

    /// All built-in policies and wrappers.  Adding a policy to the CLI
    /// is one `register` line here plus the policy's own file.
    pub fn with_builtins() -> PolicyRegistry {
        let mut reg = PolicyRegistry::new();
        for e in baselines::entries() {
            reg.register(e);
        }
        reg.register(smoothed::entry());
        for e in wrappers::entries() {
            reg.register(e);
        }
        reg
    }

    /// The shared built-in registry (lazily initialized).
    pub fn builtin() -> &'static PolicyRegistry {
        static REG: OnceLock<PolicyRegistry> = OnceLock::new();
        REG.get_or_init(PolicyRegistry::with_builtins)
    }

    /// Register an entry, replacing any same-name entry.
    pub fn register(&mut self, entry: PolicyEntry) {
        self.entries.retain(|e| e.name != entry.name);
        self.entries.push(entry);
    }

    pub fn entries(&self) -> &[PolicyEntry] {
        &self.entries
    }

    fn lookup(&self, name: &str) -> Result<&PolicyEntry, PolicyError> {
        self.entries
            .iter()
            .find(|e| e.name == name || e.aliases.contains(&name))
            .ok_or_else(|| PolicyError::UnknownPolicy {
                name: name.to_string(),
                suggestion: suggest(
                    name,
                    self.entries
                        .iter()
                        .flat_map(|e| std::iter::once(e.name).chain(e.aliases.iter().copied())),
                ),
            })
    }

    /// Parse a full spec (`wrapper/.../base`) into a policy.
    pub fn parse_policy(&self, spec: &str) -> Result<Box<dyn BatchPolicy>, PolicyError> {
        let segs: Vec<&str> = spec.split('/').map(str::trim).collect();
        if segs.iter().any(|s| s.is_empty()) {
            return Err(PolicyError::BadSpec {
                spec: spec.to_string(),
                msg: "empty spec segment".into(),
            });
        }
        let (&base_seg, wrapper_segs) = segs.split_last().expect("split produced >= 1 segment");
        let (name, rest) = base_seg.split_once(':').unwrap_or((base_seg, ""));
        let entry = self.lookup(name.trim())?;
        let mut policy = match entry.build {
            Build::Base(build) => build(&ParamMap::from_spec(entry.name, rest, entry.params)?)?,
            Build::Wrapper(_) => {
                return Err(PolicyError::BadSpec {
                    spec: spec.to_string(),
                    msg: format!(
                        "{} is a wrapper; end the spec with a base policy, e.g. {}:.../divebatch:m0=128,mmax=4096",
                        entry.name, entry.name
                    ),
                })
            }
        };
        // Apply wrappers right-to-left so the leftmost is outermost.
        for seg in wrapper_segs.iter().rev() {
            let (name, rest) = seg.split_once(':').unwrap_or((*seg, ""));
            let entry = self.lookup(name.trim())?;
            policy = match entry.build {
                Build::Wrapper(build) => {
                    build(&ParamMap::from_spec(entry.name, rest, entry.params)?, policy)?
                }
                Build::Base(_) => {
                    return Err(PolicyError::BadSpec {
                        spec: spec.to_string(),
                        msg: format!("base policy {} cannot wrap another policy", entry.name),
                    })
                }
            };
        }
        Ok(policy)
    }

    /// Parse a spec into the [`PolicyHandle`] `TrainConfig` carries.
    pub fn parse(&self, spec: &str) -> Result<PolicyHandle, PolicyError> {
        self.parse_policy(spec).map(PolicyHandle::new)
    }

    /// Human-readable listing for `divebatch policies` / `--list-policies`.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "batch-size policies — spec grammar: [wrapper/...]base, params key=value,key=value"
        );
        for (wrapper_pass, header) in [(false, "base policies"), (true, "wrappers (compose left = outermost)")] {
            let _ = writeln!(s, "\n{header}:");
            for e in self.entries.iter().filter(|e| e.is_wrapper() == wrapper_pass) {
                let aliases = if e.aliases.is_empty() {
                    String::new()
                } else {
                    format!(" (alias: {})", e.aliases.join(", "))
                };
                let _ = writeln!(s, "  {:<14}{} — {}", e.name, aliases, e.summary);
                for p in e.params {
                    let left = match p.default {
                        Some(d) => format!("{}={d}", p.key),
                        None => format!("{} (required)", p.key),
                    };
                    let _ = writeln!(s, "      {left:<22} {}", p.help);
                }
            }
        }
        let _ = writeln!(
            s,
            "\nexamples:\n  --policy divebatch:m0=128,delta=1,mmax=4096\n  \
             --policy warmup:epochs=5,m=64/divebatch:m0=128,mmax=4096\n  \
             --policy clamp:min=64,max=1024/ema:beta=0.7/divebatch:m0=128,mmax=4096"
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::super::api::PolicyError;
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn reg() -> &'static PolicyRegistry {
        PolicyRegistry::builtin()
    }

    #[test]
    fn parses_all_builtin_base_policies() {
        for spec in [
            "sgd:m=128",
            "fixed:m=64", // alias
            "adabatch:m0=128,factor=2,every=20,mmax=2048",
            "adabatch:m0=128,mmax=2048", // defaults
            "divebatch:m0=256,delta=0.01,mmax=2048",
            "oracle:m0=512,delta=0.1,mmax=8192",
            "divebatch-ema:m0=128,mmax=4096,beta=0.75",
        ] {
            let p = reg().parse_policy(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(p.initial() > 0, "{spec}");
        }
    }

    #[test]
    fn unknown_policy_rejected_with_suggestion() {
        match reg().parse_policy("divebatchh:m0=128,mmax=2048") {
            Err(PolicyError::UnknownPolicy { name, suggestion }) => {
                assert_eq!(name, "divebatchh");
                assert_eq!(suggestion.as_deref(), Some("divebatch"));
            }
            other => panic!("{other:?}"),
        }
        assert!(reg().parse_policy("bogus").is_err());
    }

    #[test]
    fn unknown_param_rejected() {
        // The ISSUE's motivating bug: a typo'd key must not parse.
        match reg().parse_policy("divebatch:m0=128,tpyo=5,mmax=2048") {
            Err(PolicyError::UnknownParam { policy, key, .. }) => {
                assert_eq!(policy, "divebatch");
                assert_eq!(key, "tpyo");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_param_suggests_nearest_key() {
        match reg().parse_policy("divebatch:m0=128,detla=0.5,mmax=2048") {
            Err(PolicyError::UnknownParam { key, suggestion, .. }) => {
                assert_eq!(key, "detla");
                assert_eq!(suggestion.as_deref(), Some("delta"));
            }
            other => panic!("{other:?}"),
        }
        match reg().parse_policy("adabatch:m0=128,evry=10,mmax=2048") {
            Err(PolicyError::UnknownParam { suggestion, .. }) => {
                assert_eq!(suggestion.as_deref(), Some("every"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn duplicate_param_rejected() {
        // Last-one-wins would silently discard the user's first value —
        // the same silent-mistake class as unknown keys.
        match reg().parse_policy("divebatch:m0=128,mmax=2048,mmax=64") {
            Err(PolicyError::DuplicateParam { policy, key }) => {
                assert_eq!((policy.as_str(), key.as_str()), ("divebatch", "mmax"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn missing_required_param_rejected() {
        assert!(matches!(
            reg().parse_policy("sgd"),
            Err(PolicyError::MissingParam { .. })
        ));
        assert!(matches!(
            reg().parse_policy("divebatch:m0=128"), // missing mmax
            Err(PolicyError::MissingParam { .. })
        ));
    }

    #[test]
    fn bad_values_rejected() {
        assert!(matches!(
            reg().parse_policy("sgd:m=abc"),
            Err(PolicyError::BadValue { .. })
        ));
        assert!(matches!(
            reg().parse_policy("sgd:m=0"),
            Err(PolicyError::BadValue { .. })
        ));
        // Floor above cap cannot construct.
        assert!(matches!(
            reg().parse_policy("divebatch:m0=4096,mmax=128"),
            Err(PolicyError::BadValue { .. })
        ));
        assert!(matches!(
            reg().parse_policy("ema:beta=1.5/divebatch:m0=128,mmax=256"),
            Err(PolicyError::BadValue { .. })
        ));
    }

    #[test]
    fn malformed_specs_rejected() {
        for spec in [
            "",
            "sgd:m=128/",              // empty segment
            "/sgd:m=128",              // empty segment
            "sgd:m128",                // not key=value
            "warmup:epochs=3,m=8",     // wrapper with no base
            "sgd:m=8/divebatch:m0=4,mmax=8", // base in wrapper position
        ] {
            assert!(reg().parse_policy(spec).is_err(), "{spec:?} should fail");
        }
    }

    #[test]
    fn wrapper_grammar_leftmost_is_outermost() {
        let p = reg()
            .parse_policy("clamp:min=16,max=256/warmup:epochs=2,m=32/divebatch:m0=8,mmax=4096")
            .unwrap();
        // Outermost clamp pulls warmup's 32 into bounds (already in) and
        // the rendered spec preserves the wrapper order.
        assert_eq!(
            p.render_spec(),
            "clamp:min=16,max=256/warmup:epochs=2,m=32/divebatch:m0=8,delta=0.1,mmax=4096"
        );
        assert_eq!(p.initial(), 32);
        assert_eq!(p.kind(), "divebatch");
    }

    #[test]
    fn custom_registration_is_one_call() {
        // A fresh registry with only the exemplar policy registered.
        let mut custom = PolicyRegistry::new();
        custom.register(super::super::smoothed::entry());
        let p = custom.parse("divebatch-ema:m0=8,mmax=64").unwrap();
        assert_eq!(p.kind(), "divebatch-ema");
        // And the builtin names are absent.
        assert!(custom.parse("sgd:m=8").is_err());
    }

    #[test]
    fn help_lists_every_entry() {
        let h = reg().help();
        for e in reg().entries() {
            assert!(h.contains(e.name), "{} missing from help", e.name);
        }
        assert!(h.contains("required"));
        assert!(h.contains("examples"));
    }

    /// Deterministically derive a valid spec from fuzz dice (total on
    /// arbitrary dice, including shrunk short vectors).
    fn spec_from_dice(d: &[u64]) -> String {
        let g = |i: usize| d.get(i).copied().unwrap_or(0);
        let m0 = (g(0) % 512 + 1) as usize;
        let mmax = m0 + (g(1) % 4096) as usize;
        let base = match g(2) % 5 {
            0 => format!("sgd:m={m0}"),
            1 => format!(
                "adabatch:m0={m0},factor={},every={},mmax={mmax}",
                g(3) % 5,
                g(4) % 9
            ),
            2 => format!("divebatch:m0={m0},delta=0.25,mmax={mmax}"),
            3 => format!("oracle:m0={m0},delta=0.5,mmax={mmax}"),
            _ => format!("divebatch-ema:m0={m0},delta=0.5,mmax={mmax},beta=0.75"),
        };
        match g(5) % 4 {
            0 => base,
            1 => format!("warmup:epochs={},m={}/{base}", g(3) % 10, 1 + g(4) % 64),
            2 => format!("clamp:min={},max={}/{base}", 1 + g(4) % 8, 64 + g(4) % 64),
            _ => format!("ema:beta=0.25,band=0.5/{base}"),
        }
    }

    #[test]
    fn property_parseable_specs_round_trip() {
        forall(
            300,
            |r: &mut Rng| (0..6).map(|_| r.next_u64()).collect::<Vec<u64>>(),
            |dice| {
                let spec = spec_from_dice(dice);
                let p = match reg().parse_policy(&spec) {
                    Ok(p) => p,
                    Err(e) => panic!("dice-generated spec {spec:?} failed: {e}"),
                };
                let rendered = p.render_spec();
                let q = match reg().parse_policy(&rendered) {
                    Ok(q) => q,
                    Err(e) => panic!("rendered spec {rendered:?} failed: {e}"),
                };
                // Canonical form is a fixed point, and the reconstructed
                // policy is observationally identical.
                q.render_spec() == rendered
                    && q.label() == p.label()
                    && q.kind() == p.kind()
                    && q.initial() == p.initial()
                    && q.diversity_need() == p.diversity_need()
            },
        );
    }

    #[test]
    fn canonical_spec_materializes_defaults() {
        let p = reg().parse_policy("divebatch:m0=128,mmax=2048").unwrap();
        assert_eq!(p.render_spec(), "divebatch:m0=128,delta=0.1,mmax=2048");
        let q = reg().parse_policy(&p.render_spec()).unwrap();
        assert_eq!(q.render_spec(), p.render_spec());
    }
}
