//! EMA-smoothed DiveBatch — and the template for adding a policy.
//!
//! This file is the whole recipe: implement [`BatchPolicy`] (~30 lines),
//! export a [`PolicyEntry`], and add one `registry.register(...)` line in
//! [`super::registry::PolicyRegistry::with_builtins`].  Nothing in
//! `trainer.rs`, `args.rs`, or `main.rs` changes — the CLI picks the
//! policy up through the registry (`--policy divebatch-ema:m0=...`).

use super::api::{AdaptContext, BatchPolicy, Decision, PolicyError};
use super::baselines::divebatch_next;
use super::registry::{Build, ParamMap, ParamSpec, PolicyEntry};
use super::DiversityNeed;

/// DiveBatch whose Algorithm-1 targets are exponentially smoothed before
/// being applied: `s_{k+1} = beta * s_k + (1 - beta) * target_k`.  The
/// smoothing damps the batch-size oscillation DiveBatch exhibits when
/// `Delta_hat` is noisy (small datasets / early training), at the cost
/// of a few epochs of lag.
#[derive(Clone, Copy, Debug)]
pub struct SmoothedDiveBatch {
    pub m0: usize,
    pub delta: f64,
    pub m_max: usize,
    pub beta: f64,
    ema: Option<f64>,
}

impl SmoothedDiveBatch {
    pub fn new(m0: usize, delta: f64, m_max: usize, beta: f64) -> SmoothedDiveBatch {
        SmoothedDiveBatch {
            m0,
            delta,
            m_max,
            beta,
            ema: None,
        }
    }
}

impl BatchPolicy for SmoothedDiveBatch {
    fn kind(&self) -> &'static str {
        "divebatch-ema"
    }

    fn label(&self) -> String {
        format!("DiveBatch-EMA ({} - {})", self.m0, self.m_max)
    }

    fn initial(&self) -> usize {
        self.m0
    }

    fn diversity_need(&self) -> DiversityNeed {
        DiversityNeed::Estimated
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let stats = ctx.stats_or_err(self.kind())?;
        let raw =
            divebatch_next(self.m0, self.delta, self.m_max, ctx.batch_size, ctx.n, stats) as f64;
        let s = match self.ema {
            Some(prev) => self.beta * prev + (1.0 - self.beta) * raw,
            None => raw,
        };
        self.ema = Some(s);
        let next = (s.round() as usize).clamp(self.m0, self.m_max);
        Ok(Decision::new(next, DiversityNeed::Estimated))
    }

    fn render_spec(&self) -> String {
        format!(
            "divebatch-ema:m0={},delta={},mmax={},beta={}",
            self.m0, self.delta, self.m_max, self.beta
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

pub const DIVEBATCH_EMA_PARAMS: &[ParamSpec] = &[
    ParamSpec {
        key: "m0",
        default: None,
        help: "initial batch size",
    },
    ParamSpec {
        key: "delta",
        default: Some("0.1"),
        help: "diversity scale delta (Algorithm 1)",
    },
    ParamSpec {
        key: "mmax",
        default: None,
        help: "batch-size cap",
    },
    ParamSpec {
        key: "beta",
        default: Some("0.5"),
        help: "EMA coefficient in [0, 1)",
    },
];

pub(crate) fn entry() -> PolicyEntry {
    PolicyEntry {
        name: "divebatch-ema",
        aliases: &[],
        summary: "DiveBatch with EMA-smoothed batch-size targets",
        params: DIVEBATCH_EMA_PARAMS,
        build: Build::Base(|p: &ParamMap| {
            let (m0, m_max, beta) = (p.usize("m0")?, p.usize("mmax")?, p.f64("beta")?);
            if m0 == 0 || m0 > m_max {
                return Err(PolicyError::BadValue {
                    policy: "divebatch-ema".into(),
                    key: "mmax".into(),
                    value: m_max.to_string(),
                    reason: format!("need 1 <= m0 ({m0}) <= mmax"),
                });
            }
            if !(0.0..1.0).contains(&beta) {
                return Err(PolicyError::BadValue {
                    policy: "divebatch-ema".into(),
                    key: "beta".into(),
                    value: beta.to_string(),
                    reason: "need 0 <= beta < 1".into(),
                });
            }
            Ok(Box::new(SmoothedDiveBatch::new(
                m0,
                p.f64("delta")?,
                m_max,
                beta,
            )))
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::super::DiversityStats;
    use super::*;

    fn ctx(epoch: usize, m: usize, n: usize, sq: f64, g2: f64) -> AdaptContext<'static> {
        AdaptContext {
            epoch,
            step: 0,
            batch_size: m,
            n,
            m0: m,
            stats: Some(DiversityStats {
                sqnorm_sum: sq,
                grad_norm2: g2,
            }),
            history: &[],
            sim_elapsed: 0.0,
            wall_elapsed: 0.0,
        }
    }

    #[test]
    fn first_decision_seeds_the_ema() {
        let mut p = SmoothedDiveBatch::new(16, 1.0, 2048, 0.5);
        // target = 1 * 1000 * (50/25 = 2) = 2000 -> capped 2048? no:
        // clamp(16, min(2048, 1000)) = 1000.
        let d = p.on_epoch_end(&ctx(0, 16, 1000, 50.0, 25.0)).unwrap();
        assert_eq!(d.next_batch, 1000);
    }

    #[test]
    fn smoothing_damps_oscillating_targets() {
        let mut p = SmoothedDiveBatch::new(16, 1.0, 2048, 0.5);
        let hi = p.on_epoch_end(&ctx(0, 16, 1000, 50.0, 25.0)).unwrap();
        assert_eq!(hi.next_batch, 1000);
        // Diversity collapses: raw target would be 16, smoothed is
        // 0.5*1000 + 0.5*16 = 508.
        let lo = p.on_epoch_end(&ctx(1, 1000, 1000, 0.001, 25.0)).unwrap();
        assert_eq!(lo.next_batch, 508);
        // A plain DiveBatch would have jumped straight back to 16.
    }

    #[test]
    fn stays_within_m0_mmax() {
        let mut p = SmoothedDiveBatch::new(32, 1.0, 128, 0.9);
        let mut m = p.initial();
        for e in 0..50 {
            let (sq, g2) = if e % 2 == 0 { (1e6, 1.0) } else { (1e-9, 1.0) };
            m = p.on_epoch_end(&ctx(e, m, 100_000, sq, g2)).unwrap().next_batch;
            assert!((32..=128).contains(&m), "epoch {e}: {m}");
        }
    }

    #[test]
    fn missing_stats_is_typed() {
        let mut p = SmoothedDiveBatch::new(16, 1.0, 2048, 0.5);
        let c = AdaptContext {
            stats: None,
            ..ctx(0, 16, 1000, 0.0, 0.0)
        };
        assert!(matches!(
            p.on_epoch_end(&c),
            Err(PolicyError::MissingStats { .. })
        ));
    }
}
