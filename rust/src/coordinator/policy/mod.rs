//! Batch-size controllers: the open [`BatchPolicy`] trait API plus the
//! built-in policies from the paper.
//!
//! Layout:
//!
//! * [`api`]       — the [`BatchPolicy`] trait, [`AdaptContext`] /
//!   [`Decision`] step protocol, [`PolicyError`], and the [`PolicyHandle`]
//!   value type carried by `TrainConfig`
//! * [`baselines`] — Fixed SGD, AdaBatch, DiveBatch (Algorithm 1), Oracle
//! * [`wrappers`]  — composable combinators: [`Warmup`], [`Clamp`],
//!   [`Ema`] (hysteresis), [`Chain`]
//! * [`smoothed`]  — EMA-smoothed DiveBatch, the one-file "write your own
//!   policy" exemplar
//! * [`registry`]  — [`PolicyRegistry`]: CLI spec grammar
//!   (`wrapper:.../base:k=v,...`), strict param validation with
//!   did-you-mean suggestions, and `--list-policies` help
//! * [`legacy`]    — the closed [`Policy`] enum kept as a thin shim so
//!   presets and existing call sites keep compiling (`From<Policy> for
//!   PolicyHandle`)
//!
//! The trainer drives a policy through three hooks per epoch:
//! `on_epoch_start`, `on_step` (mid-epoch adaptation, opt-in via
//! `wants_step_decisions`), and `on_epoch_end`, which returns the next
//! epoch's [`Decision`] (batch size, diversity instrumentation, optional
//! lr rescale).  Adding a new policy is one file + one registration in
//! [`registry::PolicyRegistry::with_builtins`] — no trainer or CLI edits.

pub mod api;
pub mod baselines;
pub mod legacy;
pub mod registry;
pub mod smoothed;
pub mod wrappers;

pub use api::{AdaptContext, BatchPolicy, Decision, HistoryPoint, PolicyError, PolicyHandle};
pub use baselines::{AdaBatch, DiveBatch, Fixed, Oracle};
pub use legacy::Policy;
pub use registry::{Build, ParamMap, ParamSpec, PolicyEntry, PolicyRegistry};
pub use smoothed::SmoothedDiveBatch;
pub use wrappers::{Chain, Clamp, Ema, Warmup};

/// Gradient-diversity statistics accumulated over an epoch
/// (Definition 2 numerator and denominator).
#[derive(Clone, Copy, Debug, Default)]
pub struct DiversityStats {
    /// `sum_i ||grad_i||^2` accumulated over every sample of the epoch.
    pub sqnorm_sum: f64,
    /// `|| sum_i grad_i ||^2` of the epoch-accumulated gradient vector.
    pub grad_norm2: f64,
}

impl DiversityStats {
    /// Estimated gradient diversity `Delta_hat` (Definition 2).
    pub fn delta_hat(&self) -> f64 {
        if self.grad_norm2 <= 0.0 {
            f64::INFINITY
        } else {
            self.sqnorm_sum / self.grad_norm2
        }
    }
}

/// Which diversity signal a policy consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiversityNeed {
    /// No instrumentation (`train_plain`).
    None,
    /// Accumulate Definition-2 stats during the epoch (`train_div`).
    Estimated,
    /// Recompute the exact diversity on the full dataset at epoch end
    /// (extra instrumented pass, no parameter updates).
    Exact,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_hat_definition() {
        let s = DiversityStats {
            sqnorm_sum: 12.0,
            grad_norm2: 3.0,
        };
        assert!((s.delta_hat() - 4.0).abs() < 1e-12);
        assert!(DiversityStats::default().delta_hat().is_infinite());
    }
}
