//! The paper's four batch-size policies on the [`BatchPolicy`] trait:
//! Fixed SGD, AdaBatch, DiveBatch (Algorithm 1), Oracle.  Update rules
//! are byte-identical to the original closed `Policy` enum — the legacy
//! shim in `legacy.rs` maps onto these structs.

use super::api::{AdaptContext, BatchPolicy, Decision, PolicyError};
use super::registry::{Build, ParamMap, ParamSpec, PolicyEntry};
use super::{DiversityNeed, DiversityStats};

/// Algorithm 1 line 11: `m_{k+1} = min(m_max, delta * n * Delta_hat)`,
/// floored at `m0` (the paper only ever grows the batch) and capped at
/// the dataset size.  Degenerate epochs (zero accumulated gradient ->
/// infinite `Delta_hat`) keep the current batch size rather than jumping.
pub(crate) fn divebatch_next(
    m0: usize,
    delta: f64,
    m_max: usize,
    current: usize,
    n: usize,
    stats: DiversityStats,
) -> usize {
    let delta_hat = stats.delta_hat();
    if !delta_hat.is_finite() {
        return current.clamp(m0.min(m_max), m_max);
    }
    let target = delta * n as f64 * delta_hat;
    let target = target.round().max(1.0) as usize;
    target.clamp(m0, m_max.min(n.max(m0)))
}

// ---------------------------------------------------------------- Fixed

/// Fixed-batch mini-batch SGD (the paper's SGD baselines).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fixed {
    pub m: usize,
}

pub const SGD_PARAMS: &[ParamSpec] = &[ParamSpec {
    key: "m",
    default: None,
    help: "fixed batch size",
}];

impl BatchPolicy for Fixed {
    fn kind(&self) -> &'static str {
        "sgd"
    }

    fn label(&self) -> String {
        format!("SGD ({})", self.m)
    }

    fn initial(&self) -> usize {
        self.m
    }

    fn on_epoch_end(&mut self, _ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        Ok(Decision::new(self.m, DiversityNeed::None))
    }

    fn render_spec(&self) -> String {
        format!("sgd:m={}", self.m)
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

// -------------------------------------------------------------- AdaBatch

/// AdaBatch (Devarakonda et al. 2018): multiply the batch size by
/// `factor` every `every` epochs, capped at `m_max`.  `every = 0`
/// disables growth entirely; `factor = 0` is treated as `factor = 1`
/// (both pinned by unit tests below).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaBatch {
    pub m0: usize,
    pub factor: usize,
    pub every: usize,
    pub m_max: usize,
}

pub const ADABATCH_PARAMS: &[ParamSpec] = &[
    ParamSpec {
        key: "m0",
        default: None,
        help: "initial batch size",
    },
    ParamSpec {
        key: "factor",
        default: Some("2"),
        help: "growth factor (0 acts as 1)",
    },
    ParamSpec {
        key: "every",
        default: Some("20"),
        help: "grow every N epochs (0 = never)",
    },
    ParamSpec {
        key: "mmax",
        default: None,
        help: "batch-size cap",
    },
];

impl BatchPolicy for AdaBatch {
    fn kind(&self) -> &'static str {
        "adabatch"
    }

    fn label(&self) -> String {
        format!("AdaBatch ({} - {})", self.m0, self.m_max)
    }

    fn initial(&self) -> usize {
        self.m0
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let next = if self.every > 0 && (ctx.epoch + 1) % self.every == 0 {
            (ctx.batch_size * self.factor.max(1)).min(self.m_max)
        } else {
            ctx.batch_size
        };
        Ok(Decision::new(next, DiversityNeed::None))
    }

    fn render_spec(&self) -> String {
        format!(
            "adabatch:m0={},factor={},every={},mmax={}",
            self.m0, self.factor, self.every, self.m_max
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

// ------------------------------------------------------------- DiveBatch

/// DiveBatch (Algorithm 1): `m_{k+1} = min(m_max, delta * n * Delta_hat)`
/// from the Definition-2 estimate accumulated during the epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiveBatch {
    pub m0: usize,
    pub delta: f64,
    pub m_max: usize,
}

pub const DIVEBATCH_PARAMS: &[ParamSpec] = &[
    ParamSpec {
        key: "m0",
        default: None,
        help: "initial batch size",
    },
    ParamSpec {
        key: "delta",
        default: Some("0.1"),
        help: "diversity scale delta (Algorithm 1)",
    },
    ParamSpec {
        key: "mmax",
        default: None,
        help: "batch-size cap",
    },
];

impl BatchPolicy for DiveBatch {
    fn kind(&self) -> &'static str {
        "divebatch"
    }

    fn label(&self) -> String {
        format!("DiveBatch ({} - {})", self.m0, self.m_max)
    }

    fn initial(&self) -> usize {
        self.m0
    }

    fn diversity_need(&self) -> DiversityNeed {
        DiversityNeed::Estimated
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let stats = ctx.stats_or_err(self.kind())?;
        Ok(Decision::new(
            divebatch_next(self.m0, self.delta, self.m_max, ctx.batch_size, ctx.n, stats),
            DiversityNeed::Estimated,
        ))
    }

    fn render_spec(&self) -> String {
        format!(
            "divebatch:m0={},delta={},mmax={}",
            self.m0, self.delta, self.m_max
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

// ---------------------------------------------------------------- Oracle

/// Oracle: DiveBatch's update rule driven by the *exact* gradient
/// diversity of the full dataset (section 5.1 ablation).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Oracle {
    pub m0: usize,
    pub delta: f64,
    pub m_max: usize,
}

impl BatchPolicy for Oracle {
    fn kind(&self) -> &'static str {
        "oracle"
    }

    fn label(&self) -> String {
        format!("Oracle ({} - {})", self.m0, self.m_max)
    }

    fn initial(&self) -> usize {
        self.m0
    }

    fn diversity_need(&self) -> DiversityNeed {
        DiversityNeed::Exact
    }

    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let stats = ctx.stats_or_err(self.kind())?;
        Ok(Decision::new(
            divebatch_next(self.m0, self.delta, self.m_max, ctx.batch_size, ctx.n, stats),
            DiversityNeed::Exact,
        ))
    }

    fn render_spec(&self) -> String {
        format!(
            "oracle:m0={},delta={},mmax={}",
            self.m0, self.delta, self.m_max
        )
    }

    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

// ----------------------------------------------------- registry entries

/// Reject configurations where the floor exceeds the cap (the update
/// rule's clamp would panic at runtime otherwise).  Shared by the
/// registry builders and the legacy `Policy::parse` path so both parse
/// surfaces agree.
pub(crate) fn check_bounds(policy: &'static str, m0: usize, m_max: usize) -> Result<(), PolicyError> {
    if m0 == 0 {
        return Err(PolicyError::BadValue {
            policy: policy.into(),
            key: "m0".into(),
            value: "0".into(),
            reason: "batch size must be >= 1".into(),
        });
    }
    if m0 > m_max {
        return Err(PolicyError::BadValue {
            policy: policy.into(),
            key: "mmax".into(),
            value: m_max.to_string(),
            reason: format!("mmax must be >= m0 ({m0})"),
        });
    }
    Ok(())
}

pub(crate) fn entries() -> Vec<PolicyEntry> {
    vec![
        PolicyEntry {
            name: "sgd",
            aliases: &["fixed"],
            summary: "fixed-batch mini-batch SGD (paper baseline)",
            params: SGD_PARAMS,
            build: Build::Base(|p: &ParamMap| {
                let m = p.usize("m")?;
                if m == 0 {
                    return Err(PolicyError::BadValue {
                        policy: "sgd".into(),
                        key: "m".into(),
                        value: "0".into(),
                        reason: "batch size must be >= 1".into(),
                    });
                }
                Ok(Box::new(Fixed { m }))
            }),
        },
        PolicyEntry {
            name: "adabatch",
            aliases: &[],
            summary: "multiply batch by `factor` every `every` epochs (Devarakonda et al.)",
            params: ADABATCH_PARAMS,
            build: Build::Base(|p: &ParamMap| {
                let (m0, m_max) = (p.usize("m0")?, p.usize("mmax")?);
                check_bounds("adabatch", m0, m_max)?;
                Ok(Box::new(AdaBatch {
                    m0,
                    factor: p.usize("factor")?,
                    every: p.usize("every")?,
                    m_max,
                }))
            }),
        },
        PolicyEntry {
            name: "divebatch",
            aliases: &[],
            summary: "grow batch with estimated gradient diversity (Algorithm 1)",
            params: DIVEBATCH_PARAMS,
            build: Build::Base(|p: &ParamMap| {
                let (m0, m_max) = (p.usize("m0")?, p.usize("mmax")?);
                check_bounds("divebatch", m0, m_max)?;
                Ok(Box::new(DiveBatch {
                    m0,
                    delta: p.f64("delta")?,
                    m_max,
                }))
            }),
        },
        PolicyEntry {
            name: "oracle",
            aliases: &[],
            summary: "DiveBatch's rule on exact full-dataset diversity (ablation)",
            params: DIVEBATCH_PARAMS,
            build: Build::Base(|p: &ParamMap| {
                let (m0, m_max) = (p.usize("m0")?, p.usize("mmax")?);
                check_bounds("oracle", m0, m_max)?;
                Ok(Box::new(Oracle {
                    m0,
                    delta: p.f64("delta")?,
                    m_max,
                }))
            }),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::super::api::PolicyError;
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn ctx(
        epoch: usize,
        batch_size: usize,
        n: usize,
        stats: Option<DiversityStats>,
    ) -> AdaptContext<'static> {
        AdaptContext {
            epoch,
            step: 0,
            batch_size,
            n,
            m0: batch_size,
            stats,
            history: &[],
            sim_elapsed: 0.0,
            wall_elapsed: 0.0,
        }
    }

    fn stats(sq: f64, g2: f64) -> Option<DiversityStats> {
        Some(DiversityStats {
            sqnorm_sum: sq,
            grad_norm2: g2,
        })
    }

    fn next(p: &mut dyn BatchPolicy, c: &AdaptContext) -> usize {
        p.on_epoch_end(c).unwrap().next_batch
    }

    #[test]
    fn fixed_never_changes() {
        let mut p = Fixed { m: 128 };
        for e in 0..100 {
            assert_eq!(next(&mut p, &ctx(e, 128, 20_000, None)), 128);
        }
        assert_eq!(p.diversity_need(), DiversityNeed::None);
        assert!(!p.wants_step_decisions());
    }

    #[test]
    fn adabatch_doubles_every_20() {
        let mut p = AdaBatch {
            m0: 128,
            factor: 2,
            every: 20,
            m_max: 2048,
        };
        let mut m = p.initial();
        let mut sizes = vec![m];
        for e in 0..100 {
            m = next(&mut p, &ctx(e, m, 50_000, None));
            sizes.push(m);
        }
        assert_eq!(sizes[19], 128);
        assert_eq!(sizes[20], 256);
        assert_eq!(sizes[40], 512);
        assert_eq!(sizes[60], 1024);
        assert_eq!(sizes[80], 2048);
        assert_eq!(sizes[100], 2048); // capped
    }

    #[test]
    fn adabatch_every_zero_never_grows() {
        // Pinned edge case: `every = 0` disables the growth schedule
        // entirely — the policy degenerates to fixed-batch SGD at m0.
        let mut p = AdaBatch {
            m0: 64,
            factor: 4,
            every: 0,
            m_max: 4096,
        };
        let mut m = p.initial();
        for e in 0..200 {
            m = next(&mut p, &ctx(e, m, 10_000, None));
            assert_eq!(m, 64, "epoch {e}");
        }
    }

    #[test]
    fn adabatch_factor_zero_acts_as_factor_one() {
        // Pinned edge case: `factor = 0` is clamped to 1 at every growth
        // boundary, so the batch size never changes (and never collapses
        // to zero).
        let mut p = AdaBatch {
            m0: 32,
            factor: 0,
            every: 5,
            m_max: 1024,
        };
        let mut m = p.initial();
        for e in 0..50 {
            m = next(&mut p, &ctx(e, m, 10_000, None));
            assert_eq!(m, 32, "epoch {e}");
        }
    }

    #[test]
    fn divebatch_follows_algorithm1_line11() {
        let mut p = DiveBatch {
            m0: 128,
            delta: 0.1,
            m_max: 2048,
        };
        // delta_hat = 50 / 25 = 2; target = 0.1 * 10_000 * 2 = 2000.
        assert_eq!(next(&mut p, &ctx(0, 128, 10_000, stats(50.0, 25.0))), 2000);
        // Cap at m_max.
        assert_eq!(next(&mut p, &ctx(0, 128, 10_000, stats(500.0, 25.0))), 2048);
        // Floor at m0.
        assert_eq!(next(&mut p, &ctx(0, 128, 10_000, stats(0.001, 25.0))), 128);
    }

    #[test]
    fn divebatch_degenerate_gradient_keeps_current() {
        let mut p = DiveBatch {
            m0: 128,
            delta: 0.1,
            m_max: 2048,
        };
        assert_eq!(next(&mut p, &ctx(3, 512, 10_000, stats(5.0, 0.0))), 512);
    }

    #[test]
    fn diversity_policies_return_typed_error_without_stats() {
        let mut d = DiveBatch {
            m0: 4,
            delta: 0.1,
            m_max: 8,
        };
        match d.on_epoch_end(&ctx(0, 4, 100, None)) {
            Err(PolicyError::MissingStats { policy }) => assert_eq!(policy, "divebatch"),
            other => panic!("expected MissingStats, got {other:?}"),
        }
        let mut o = Oracle {
            m0: 4,
            delta: 0.1,
            m_max: 8,
        };
        assert!(matches!(
            o.on_epoch_end(&ctx(0, 4, 100, None)),
            Err(PolicyError::MissingStats { .. })
        ));
    }

    #[test]
    fn oracle_shares_update_rule() {
        let mut d = DiveBatch {
            m0: 128,
            delta: 0.5,
            m_max: 4096,
        };
        let mut o = Oracle {
            m0: 128,
            delta: 0.5,
            m_max: 4096,
        };
        let c = ctx(1, 128, 8_000, stats(30.0, 10.0));
        assert_eq!(next(&mut d, &c), next(&mut o, &c));
        assert_eq!(o.diversity_need(), DiversityNeed::Exact);
        assert_eq!(d.diversity_need(), DiversityNeed::Estimated);
    }

    #[test]
    fn labels_match_paper_style() {
        assert_eq!(Fixed { m: 2048 }.label(), "SGD (2048)");
        assert_eq!(
            AdaBatch {
                m0: 128,
                factor: 2,
                every: 20,
                m_max: 2048
            }
            .label(),
            "AdaBatch (128 - 2048)"
        );
        assert_eq!(
            DiveBatch {
                m0: 256,
                delta: 0.01,
                m_max: 2048
            }
            .label(),
            "DiveBatch (256 - 2048)"
        );
    }

    #[test]
    fn property_divebatch_always_within_bounds() {
        let mut p = DiveBatch {
            m0: 64,
            delta: 0.1,
            m_max: 4096,
        };
        forall(
            300,
            |r: &mut Rng| {
                (
                    r.below(1_000_000) as usize + 1,
                    (r.next_f64() * 1e6, r.next_f64() * 1e6),
                )
            },
            |&(n, (sq, g2))| {
                let m = next(&mut p, &ctx(0, 64, n, stats(sq, g2)));
                (64..=4096).contains(&m)
            },
        );
    }

    #[test]
    fn property_adabatch_monotone_nondecreasing() {
        let mut p = AdaBatch {
            m0: 32,
            factor: 2,
            every: 5,
            m_max: 1024,
        };
        let mut m = p.initial();
        for e in 0..200 {
            let n = next(&mut p, &ctx(e, m, 10_000, None));
            assert!(n >= m);
            m = n;
        }
        assert_eq!(m, 1024);
    }
}
