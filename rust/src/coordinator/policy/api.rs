//! The open controller API: [`BatchPolicy`], its step protocol
//! ([`AdaptContext`] in, [`Decision`] out), the typed [`PolicyError`],
//! and the [`PolicyHandle`] value type that `TrainConfig` carries.
//!
//! The trainer owns the event loop and calls the policy at three points:
//!
//! 1. `on_epoch_start(ctx)` — the epoch is about to run at
//!    `ctx.batch_size`;
//! 2. `on_step(ctx)` — after every optimizer step, *only* when
//!    `wants_step_decisions()` returns true.  Returning `Some(decision)`
//!    resizes the remaining logical batches of the epoch
//!    (`decision.next_batch`); `need` / `lr_rescale` are ignored here —
//!    instrumentation and lr changes are epoch-granular;
//! 3. `on_epoch_end(ctx)` — the boundary decision: next epoch's batch
//!    size, its diversity instrumentation, and an optional lr rescale
//!    factor.
//!
//! Policies are stateful (`&mut self`) and cheap to clone
//! ([`BatchPolicy::clone_box`]); the trainer clones a fresh instance from
//! the [`PolicyHandle`] prototype per run, so trials never leak state
//! into each other.

use std::fmt;

use super::{DiversityNeed, DiversityStats};

/// Summary of one completed epoch, exposed to policies as recent history
/// (oldest first).  Deliberately lightweight — policies that want the
/// full record can track their own state in the hooks.
#[derive(Clone, Copy, Debug)]
pub struct HistoryPoint {
    pub epoch: usize,
    /// Logical batch size the epoch ran at.
    pub batch_size: usize,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_acc: f64,
}

/// Everything a policy may consult when making a decision.
#[derive(Clone, Copy, Debug)]
pub struct AdaptContext<'a> {
    /// Current epoch index (0-based).
    pub epoch: usize,
    /// Optimizer steps completed so far this epoch (0 at epoch start).
    pub step: usize,
    /// Current logical batch size.
    pub batch_size: usize,
    /// Training-set size.
    pub n: usize,
    /// The run's Goyal-rescaling reference batch size (the base
    /// policy's `rescale_reference()`, usually its `m0`).
    pub m0: usize,
    /// Diversity statistics: the running epoch estimate on `on_step`,
    /// the epoch total (or exact full-dataset recomputation, per
    /// [`DiversityNeed`]) on `on_epoch_end`; `None` when the policy
    /// requested no instrumentation.
    pub stats: Option<DiversityStats>,
    /// Completed-epoch summaries, oldest first.
    pub history: &'a [HistoryPoint],
    /// Simulated cluster seconds elapsed so far (`ClusterModel` timing).
    pub sim_elapsed: f64,
    /// Real wall-clock seconds elapsed so far on this testbed.  NOTE:
    /// under the parallel trial engine this measures *contended* time,
    /// so a policy that keys decisions off it gives up the engine's
    /// records-identical-at-any-jobs-level guarantee for its runs —
    /// prefer `sim_elapsed` for time budgets.  No built-in policy reads
    /// this field.
    pub wall_elapsed: f64,
}

impl AdaptContext<'_> {
    /// The diversity stats, or a typed [`PolicyError::MissingStats`] —
    /// diversity-driven policies call this instead of panicking.
    pub fn stats_or_err(&self, policy: &str) -> Result<DiversityStats, PolicyError> {
        self.stats.ok_or_else(|| PolicyError::MissingStats {
            policy: policy.to_string(),
        })
    }
}

/// A policy's verdict for the next epoch (or, from `on_step`, for the
/// remainder of the current one).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// Logical batch size to use next.
    pub next_batch: usize,
    /// Diversity instrumentation required for the next epoch.
    pub need: DiversityNeed,
    /// Optional multiplicative lr factor applied on top of the
    /// `LrSchedule` from the next epoch on (`None` leaves the current
    /// factor in place).  Built-in policies never set this; it is the
    /// seam for policy-owned rescaling rules beyond Goyal's.
    pub lr_rescale: Option<f64>,
}

impl Decision {
    pub fn new(next_batch: usize, need: DiversityNeed) -> Decision {
        Decision {
            next_batch,
            need,
            lr_rescale: None,
        }
    }
}

/// Typed errors from policy construction, spec parsing, and decisions.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyError {
    /// A diversity-driven policy was asked to decide without stats.
    MissingStats { policy: String },
    /// Spec named a policy the registry does not know.
    UnknownPolicy {
        name: String,
        suggestion: Option<String>,
    },
    /// Spec passed a parameter the policy does not declare.
    UnknownParam {
        policy: String,
        key: String,
        suggestion: Option<String>,
    },
    /// A required parameter (no default) was not supplied.
    MissingParam { policy: String, key: String },
    /// The same parameter appeared twice in one spec segment.
    DuplicateParam { policy: String, key: String },
    /// A parameter value failed to parse or validate.
    BadValue {
        policy: String,
        key: String,
        value: String,
        reason: String,
    },
    /// The spec itself is malformed (empty segment, wrapper position...).
    BadSpec { spec: String, msg: String },
}

impl fmt::Display for PolicyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PolicyError::MissingStats { policy } => write!(
                f,
                "policy {policy:?} needs diversity stats but none were provided \
                 (its DiversityNeed and the trainer's instrumentation disagree)"
            ),
            PolicyError::UnknownPolicy { name, suggestion } => {
                write!(f, "unknown policy {name:?}")?;
                if let Some(s) = suggestion {
                    write!(f, " — did you mean {s:?}?")?;
                }
                write!(f, " (run `divebatch policies` for the list)")
            }
            PolicyError::UnknownParam {
                policy,
                key,
                suggestion,
            } => {
                write!(f, "unknown parameter {key:?} for policy {policy}")?;
                if let Some(s) = suggestion {
                    write!(f, " — did you mean {s:?}?")?;
                }
                Ok(())
            }
            PolicyError::MissingParam { policy, key } => {
                write!(f, "policy {policy} needs {key}=")
            }
            PolicyError::DuplicateParam { policy, key } => {
                write!(f, "parameter {key:?} given twice for policy {policy}")
            }
            PolicyError::BadValue {
                policy,
                key,
                value,
                reason,
            } => {
                write!(f, "bad {key}={value} for policy {policy}: {reason}")
            }
            PolicyError::BadSpec { spec, msg } => {
                write!(f, "bad policy spec {spec:?}: {msg}")
            }
        }
    }
}

impl std::error::Error for PolicyError {}

/// A batch-size adaptation policy.  See the module docs for the call
/// protocol; `smoothed.rs` is a complete ~30-line implementation.
///
/// `Send + Sync` is a supertrait: `TrainConfig` (which carries the
/// prototype via [`PolicyHandle`]) crosses thread boundaries in the
/// parallel trial engine ([`crate::engine`]).  Policies are plain data —
/// each trial builds and mutates its own instance — so this costs
/// implementors nothing.
pub trait BatchPolicy: Send + Sync {
    /// Short machine name for file paths / CLI (`"divebatch"`...).
    /// Wrappers forward their inner policy's kind.
    fn kind(&self) -> &'static str;

    /// Human-readable label matching the paper's table rows, e.g.
    /// `SGD (128)`, `DiveBatch (128 - 2048)`.
    fn label(&self) -> String;

    /// Batch size for epoch 0.
    fn initial(&self) -> usize;

    /// Reference batch size for Goyal lr rescaling (`LrSchedule`
    /// scales by `m / rescale_reference()`).  Defaults to `initial()`;
    /// wrappers forward the *inner* policy's reference so e.g. a small
    /// warmup batch does not inflate the post-handover lr.
    fn rescale_reference(&self) -> usize {
        self.initial()
    }

    /// Instrumentation required for epoch 0 (later epochs come from
    /// [`Decision::need`]).
    fn diversity_need(&self) -> DiversityNeed {
        DiversityNeed::None
    }

    /// Opt in to per-step `on_step` callbacks.  Off by default so
    /// epoch-granular policies pay zero overhead in the step hot loop.
    fn wants_step_decisions(&self) -> bool {
        false
    }

    /// The epoch is about to run at `ctx.batch_size`.
    fn on_epoch_start(&mut self, _ctx: &AdaptContext) {}

    /// Called after each optimizer step when `wants_step_decisions()`.
    /// `Some(d)` resizes the remaining logical batches to
    /// `d.next_batch`; `None` keeps the current size.
    fn on_step(&mut self, _ctx: &AdaptContext) -> Option<Decision> {
        None
    }

    /// The epoch-boundary decision (Algorithm 1 line 11 for DiveBatch).
    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError>;

    /// Canonical spec string: `PolicyRegistry::parse(render_spec())`
    /// must reconstruct an equivalent policy for registry-parseable
    /// policies (programmatic-only combinators like `Chain` render a
    /// descriptive, non-parseable form).
    fn render_spec(&self) -> String;

    /// Clone into a fresh boxed instance (state included).
    fn clone_box(&self) -> Box<dyn BatchPolicy>;
}

impl Clone for Box<dyn BatchPolicy> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// The policy value carried by `TrainConfig`: a cloneable prototype plus
/// value semantics (`Clone` / `Debug` / `PartialEq` via the canonical
/// spec) so run configs stay comparable and fingerprintable.  The
/// trainer calls [`PolicyHandle::build`] to get a fresh stateful
/// instance per run.
pub struct PolicyHandle {
    proto: Box<dyn BatchPolicy>,
}

impl PolicyHandle {
    pub fn new(proto: Box<dyn BatchPolicy>) -> PolicyHandle {
        PolicyHandle { proto }
    }

    /// Fresh policy instance for one run (prototype state cloned).
    pub fn build(&self) -> Box<dyn BatchPolicy> {
        self.proto.clone_box()
    }

    /// Canonical spec string (the `Debug`/`PartialEq` identity).
    pub fn spec(&self) -> String {
        self.proto.render_spec()
    }

    pub fn label(&self) -> String {
        self.proto.label()
    }

    pub fn kind(&self) -> &'static str {
        self.proto.kind()
    }

    pub fn initial(&self) -> usize {
        self.proto.initial()
    }

    pub fn rescale_reference(&self) -> usize {
        self.proto.rescale_reference()
    }

    pub fn diversity_need(&self) -> DiversityNeed {
        self.proto.diversity_need()
    }
}

impl Clone for PolicyHandle {
    fn clone(&self) -> Self {
        PolicyHandle {
            proto: self.proto.clone_box(),
        }
    }
}

impl fmt::Debug for PolicyHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The canonical spec — this feeds RunSpec::fingerprint.
        write!(f, "{}", self.spec())
    }
}

impl fmt::Display for PolicyHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

impl PartialEq for PolicyHandle {
    fn eq(&self, other: &PolicyHandle) -> bool {
        self.spec() == other.spec()
    }
}

impl From<Box<dyn BatchPolicy>> for PolicyHandle {
    fn from(proto: Box<dyn BatchPolicy>) -> PolicyHandle {
        PolicyHandle::new(proto)
    }
}

#[cfg(test)]
mod tests {
    use super::super::baselines::{DiveBatch, Fixed};
    use super::*;

    fn ctx(stats: Option<DiversityStats>) -> AdaptContext<'static> {
        AdaptContext {
            epoch: 0,
            step: 0,
            batch_size: 32,
            n: 1000,
            m0: 32,
            stats,
            history: &[],
            sim_elapsed: 0.0,
            wall_elapsed: 0.0,
        }
    }

    #[test]
    fn handle_identity_is_the_canonical_spec() {
        let a = PolicyHandle::new(Box::new(Fixed { m: 128 }));
        let b = PolicyHandle::new(Box::new(Fixed { m: 128 }));
        let c = PolicyHandle::new(Box::new(Fixed { m: 256 }));
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(format!("{a:?}"), "sgd:m=128");
        assert_eq!(format!("{a}"), "SGD (128)");
        assert_eq!(a.clone(), a);
        assert_eq!(a.kind(), "sgd");
        assert_eq!(a.initial(), 128);
    }

    #[test]
    fn handle_builds_independent_instances() {
        let h = PolicyHandle::new(Box::new(DiveBatch {
            m0: 8,
            delta: 0.5,
            m_max: 64,
        }));
        let mut p = h.build();
        let d = p
            .on_epoch_end(&ctx(Some(DiversityStats {
                sqnorm_sum: 50.0,
                grad_norm2: 25.0,
            })))
            .unwrap();
        assert_eq!(d.next_batch, 64); // 0.5 * 1000 * 2 = 1000, capped
        // The prototype is untouched; a second build starts fresh.
        assert_eq!(h.initial(), 8);
    }

    #[test]
    fn missing_stats_is_a_typed_error() {
        let e = ctx(None).stats_or_err("divebatch").unwrap_err();
        assert_eq!(
            e,
            PolicyError::MissingStats {
                policy: "divebatch".into()
            }
        );
        assert!(e.to_string().contains("divebatch"));
    }

    #[test]
    fn error_display_mentions_suggestions() {
        let e = PolicyError::UnknownParam {
            policy: "divebatch".into(),
            key: "detla".into(),
            suggestion: Some("delta".into()),
        };
        let msg = e.to_string();
        assert!(msg.contains("detla") && msg.contains("did you mean") && msg.contains("delta"));
    }
}
