//! The training coordinator: DiveBatch's Algorithm 1 as a Rust event loop
//! over AOT-compiled PJRT executables.
//!
//! Per epoch `k` (batch size `m_k` from the policy):
//!
//! 1. shuffle the training set; iterate `ceil(n/m_k)` logical batches;
//! 2. decompose each logical batch into compiled micro-batches
//!    ([`MicroPlan`]) and dispatch the blocks across the sharded step
//!    executor ([`StepExecutor`], `--step-jobs N` lanes; each lane owns
//!    its input buffer and executable handles); fold the per-block
//!    sample-sum outputs **in block order** — whatever lane finished
//!    first — so the reduction is byte-identical to the serial loop;
//! 3. apply one optimizer update per logical batch
//!    (`theta -= eta_k/m_k * sum_grad`, + momentum/wd for image runs);
//! 4. push `(grad_sum, sqnorm_sum)` into the epoch's [`DiversityAccum`];
//!    step-level policies (`wants_step_decisions`) may resize the
//!    remaining logical batches mid-epoch via `on_step`;
//! 5. at the epoch boundary: evaluate on the validation set (streamed
//!    through the same executor), optionally recompute the exact
//!    diversity (Oracle), hand the policy an [`AdaptContext`] and apply
//!    its [`Decision`] (next batch size, next epoch's instrumentation,
//!    optional lr rescale), then the LR schedule (incl. Goyal rescaling).
//!
//! Step-level parallelism is what finally makes batch-size adaptation
//! move *measured* wall-clock, not just the simulated cluster columns: a
//! logical batch grown 8x decomposes into 8x the blocks, which now
//! execute concurrently.  Parameter updates stay strictly sequential
//! across logical batches (SGD's data dependence); the speedup comes
//! from inside each batch — exactly the data-parallel mechanism the
//! paper's section 2.1 argues for.
//!
//! The trainer is generic over any boxed [`BatchPolicy`]: it builds a
//! fresh stateful instance from the config's [`PolicyHandle`] per run,
//! so trials never share controller state.  Python never runs here:
//! every numeric kernel is a compiled artifact.

use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Result};

use super::diversity::DiversityAccum;
use super::optimizer::{AdamOptimizer, Optim, SgdOptimizer};
use super::plan::{MicroBlock, MicroPlan};
use super::policy::{AdaptContext, DiversityNeed, DiversityStats, HistoryPoint, PolicyHandle};
use super::schedule::LrSchedule;
use super::sgld::SgldConfig;
use super::step::StepExecutor;
use crate::cluster::ClusterModel;
use crate::data::{Batch, Dataset, EpochBatches};
use crate::metrics::{EpochRecord, MemMode, MemoryModel, RunRecord};
use crate::runtime::{ExecCache, Runtime};
use crate::util::rng::Rng;
use crate::util::timer::{Profiler, Timer};

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Manifest model name (e.g. "logreg512").
    pub model: String,
    /// Batch-size controller (any [`super::BatchPolicy`], via handle).
    pub policy: PolicyHandle,
    pub schedule: LrSchedule,
    pub epochs: usize,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Global-norm gradient clipping (image runs; see optimizer.rs).
    pub clip_norm: Option<f64>,
    /// Trial seed: selects init params file and the shuffling stream.
    pub seed: u64,
    /// Cap on instrumented micro-batch size (None = whole ladder).
    pub max_micro: Option<usize>,
    /// Use the fused on-device `update` executable instead of the Rust
    /// optimizer (P2 ablation; semantics are identical).  SGD only.
    pub device_update: bool,
    /// Use Adam instead of SGD (paper §6: "DiveBatch could complement
    /// these optimizers").  lr/schedule semantics unchanged.
    pub use_adam: bool,
    /// SGLD-style diversity boosting (paper §6 + Yin et al. §5): inject
    /// per-sample gradient noise of std sigma into the updates and apply
    /// the closed-form diversity adjustment (see coordinator/sgld.rs).
    pub sgld: SgldConfig,
    /// Simulated-cluster shape for this run's `sim_s` timing columns
    /// (worker count, instrumentation surcharge).  Default: the paper's
    /// a100x4 constants; the `train`/`sweep` CLI exposes it as
    /// `--sim-workers` / `--sim-div-overhead`.
    pub cluster: crate::cluster::ClusterSpec,
    /// Step-executor lanes for sharding each logical batch's
    /// micro-blocks (`--step-jobs`).  `0` = auto: `DIVEBATCH_STEP_JOBS`
    /// if set, else this trial's share of the engine's jobs budget
    /// (serial for a directly-constructed [`Trainer`]).  Records are
    /// byte-identical at every level; only real wall-clock moves.
    pub step_jobs: usize,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    /// `policy` accepts the legacy `Policy` enum, a `PolicyHandle` from
    /// [`super::PolicyRegistry::parse`], or any `Box<dyn BatchPolicy>`.
    pub fn new(
        model: &str,
        policy: impl Into<PolicyHandle>,
        schedule: LrSchedule,
        epochs: usize,
    ) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            policy: policy.into(),
            schedule,
            epochs,
            momentum: 0.0,
            weight_decay: 0.0,
            clip_norm: None,
            seed: 0,
            max_micro: None,
            device_update: false,
            use_adam: false,
            sgld: SgldConfig::disabled(),
            cluster: crate::cluster::ClusterSpec::default(),
            step_jobs: 0,
            verbose: false,
        }
    }
}

/// Outcome of a run: the record plus profiling counters.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub profile: Profiler,
    /// Final parameters (for checkpoint-style chaining).
    pub params: Vec<f32>,
}

/// Per-lane scratch of the sharded step executor: one gathered input
/// buffer and one executable-handle cache per lane, plus timing totals
/// merged into the run profile at the end.  A lane never runs two
/// blocks concurrently (the [`StepExecutor`] contract), so the mutex
/// that wraps this is uncontended — it exists to move mutable state
/// across the dispatch closure, not for real sharing.
struct LaneScratch {
    buf: Batch,
    execs: ExecCache,
    gather_s: f64,
    gather_n: u64,
    exec_s: f64,
    exec_n: u64,
    /// First-touch JIT compiles resolved through this lane's handle
    /// cache (serial runs compile lazily here; parallel runs warm up
    /// front so these stay 0).
    compile_s: f64,
    compile_n: u64,
}

impl LaneScratch {
    fn new() -> LaneScratch {
        LaneScratch {
            buf: Batch::empty(),
            execs: ExecCache::new(),
            gather_s: 0.0,
            gather_n: 0,
            exec_s: 0.0,
            exec_n: 0,
            compile_s: 0.0,
            compile_n: 0,
        }
    }

    fn lock(slot: &Mutex<LaneScratch>) -> MutexGuard<'_, LaneScratch> {
        slot.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Resolve a train executable through the lane cache, attributing a
    /// handle-cache miss (= first-touch fetch, possibly a JIT compile)
    /// to the "compile" profile section.
    fn train_exec(
        &mut self,
        rt: &Runtime,
        model: &str,
        instrumented: bool,
        micro: usize,
    ) -> Result<std::sync::Arc<crate::runtime::Executable>> {
        let before = self.execs.len();
        let t = Timer::start();
        let exec = self.execs.train(rt, model, instrumented, micro)?;
        if self.execs.len() > before {
            self.compile_s += t.seconds();
            self.compile_n += 1;
        }
        Ok(exec)
    }
}

/// Decompose a sequential streaming pass over `n` rows (validation /
/// Oracle full-dataset scans) into one flat index vector (`0..n` — a
/// sequential pass visits rows in order) plus zero-copy
/// `(offset, block)` spans into it, for dispatch through the step
/// executor with block-order folding.
fn stream_blocks(
    n: usize,
    info: &crate::runtime::ModelInfo,
    cap: Option<usize>,
) -> (Vec<u32>, Vec<(usize, MicroBlock)>) {
    let indices: Vec<u32> = (0..n as u32).collect();
    let mut spans = Vec::new();
    let mut base = 0usize;
    for chunk in EpochBatches::sequential(n, info.max_micro()) {
        let plan = MicroPlan::build(chunk.len(), &info.ladder, cap);
        let mut offset = 0usize;
        for block in &plan.blocks {
            spans.push((base + offset, *block));
            offset += block.take;
        }
        base += chunk.len();
    }
    debug_assert_eq!(base, n);
    (indices, spans)
}

/// Orchestrates one training run over a [`Runtime`].
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    cfg: TrainConfig,
    cluster: ClusterModel,
    train: Dataset,
    val: Dataset,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: TrainConfig,
        train: Dataset,
        val: Dataset,
        cluster: ClusterModel,
    ) -> Result<Trainer<'rt>> {
        let info = rt.model(&cfg.model)?;
        if train.feat_len() != info.feat_len() {
            bail!(
                "dataset feature length {} != model {} ({})",
                train.feat_len(),
                cfg.model,
                info.feat_len()
            );
        }
        if train.y.dtype() != if info.label_dtype == crate::runtime::Dtype::S32 { "s32" } else { "f32" } {
            bail!(
                "dataset label dtype {} incompatible with model {}",
                train.y.dtype(),
                cfg.model
            );
        }
        Ok(Trainer {
            rt,
            cfg,
            cluster,
            train,
            val,
        })
    }

    /// Execute the run.
    pub fn run(&self) -> Result<TrainOutcome> {
        let cfg = &self.cfg;
        let info = self.rt.model(&cfg.model)?.clone();
        let n = self.train.n();
        // Fresh stateful policy instance for this run.
        let mut policy = cfg.policy.build();
        // Instrumentation for epoch 0; later epochs come from decisions.
        // Only estimating policies instrument their actual training
        // steps; Oracle trains plain and pays a separate exact pass at
        // the boundary.
        let mut need = policy.diversity_need();
        let step_decisions = policy.wants_step_decisions();

        if cfg.device_update && cfg.use_adam {
            bail!("device_update supports the SGD path only");
        }
        let mut params = self.rt.manifest.load_init_params(&cfg.model, cfg.seed as usize)?;
        let mut opt = if cfg.use_adam {
            Optim::Adam(AdamOptimizer::new(info.param_count, cfg.weight_decay))
        } else {
            let mut sgd = SgdOptimizer::new(info.param_count, cfg.momentum, cfg.weight_decay);
            sgd.clip_norm = cfg.clip_norm;
            Optim::Sgd(sgd)
        };
        let mut shuffle_rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xD117E);
        let mut sgld_rng = shuffle_rng.fork(0x56_1D);

        let mem_model = MemoryModel::for_model(
            info.param_count,
            info.feat_len(),
            info.input_shape.len(),
            info.chunk,
        );

        let mut record = RunRecord::new(
            &cfg.policy.label(),
            &cfg.model,
            cfg.policy.kind(),
            &self.train.name,
            cfg.seed,
        );
        let mut profile = Profiler::new();

        // The sharded step executor: `--step-jobs` lanes (0 = auto; see
        // TrainConfig::step_jobs).  Block results are always folded in
        // block order below, so every lane count yields byte-identical
        // records — only measured wall-clock changes.
        let step = StepExecutor::for_trial(crate::pool::resolve_step_jobs(cfg.step_jobs, 1), cfg.seed);
        if step.lanes() > 1 {
            // Parallel lanes racing a cold entry would serialize on the
            // per-key first-compile guard at step one; precompile the
            // whole train/eval surface instead (see Runtime::warmup).
            self.rt.warmup(&cfg.model)?;
        }
        let scratch: Vec<Mutex<LaneScratch>> = (0..step.lanes())
            .map(|_| Mutex::new(LaneScratch::new()))
            .collect();

        // Reusable per-batch buffers.  The remaining per-block
        // allocations inside the epoch loop are the executables' owned
        // outputs (run_train returns its grad_sum vector — true before
        // this refactor too) and, in parallel mode, the scatter's result
        // slots — amortized over a whole logical batch of blocks.
        let mut grad_accum = vec![0.0f32; info.param_count];
        let mut spans: Vec<(usize, MicroBlock)> = Vec::new();

        let m0 = policy.initial();
        // Goyal rescaling reference: the base policy's m0 even under
        // wrappers (a warmup batch must not inflate the rescaled lr).
        let lr_ref = policy.rescale_reference();
        let mut m_k = m0;
        // Policy-owned lr factor on top of the schedule (Decision::lr_rescale).
        let mut lr_scale = 1.0f64;
        let mut cum_wall = 0.0;
        let mut cum_sim = 0.0;
        // Global optimizer-step index across epochs — the key for the
        // cluster model's deterministic failure-regime draws.
        let mut global_step: u64 = 0;
        let mut history: Vec<HistoryPoint> = Vec::new();

        for epoch in 0..cfg.epochs {
            let epoch_timer = Timer::start();
            let instrumented = need == DiversityNeed::Estimated;
            let mem_mode = if instrumented {
                MemMode::DivChunked
            } else {
                MemMode::Plain
            };
            let lr = cfg.schedule.lr(epoch, m_k, lr_ref) * lr_scale;
            let mut diversity = DiversityAccum::new(info.param_count);
            let mut train_loss_sum = 0.0;
            let mut train_correct = 0.0;
            let mut steps = 0usize;
            // Dispatch accounting for the epoch record: executable
            // dispatches, padding waste, and the plan-shape utilization
            // of the step-executor lanes (1.0 when serial).
            let mut dispatches = 0usize;
            let mut padded_rows = 0usize;
            let mut covered_rows = 0usize;
            let mut util_sum = 0.0f64;

            policy.on_epoch_start(&AdaptContext {
                epoch,
                step: 0,
                batch_size: m_k,
                n,
                m0: lr_ref,
                stats: None,
                history: &history,
                sim_elapsed: cum_sim,
                wall_elapsed: cum_wall,
            });

            // Current logical batch size; step-level policies may resize
            // the remaining batches of the epoch.
            let mut m_cur = m_k;
            let mut m_peak = m_k;
            let sim_before_steps = cum_sim;
            let mut batches = EpochBatches::new(n, m_cur, &mut shuffle_rng);
            while let Some(indices) = batches.next() {
                let logical = indices.len();
                let plan = MicroPlan::build(logical, &info.ladder, cfg.max_micro);
                // Block spans: (offset into `indices`, block).
                spans.clear();
                let mut offset = 0usize;
                for block in &plan.blocks {
                    spans.push((offset, *block));
                    offset += block.take;
                }
                debug_assert_eq!(offset, logical);
                dispatches += plan.dispatches();
                padded_rows += plan.padded();
                covered_rows += plan.covered();
                util_sum += plan.utilization(step.lanes());

                // Execute every block of this logical batch — across
                // the worker lanes when step-parallel, inline when
                // serial.  Each lane gathers into its own buffer and
                // resolves executables from its own handle cache.
                let outs = step.run_blocks(spans.len(), |lane, bi| {
                    let (off, block) = spans[bi];
                    let mut s = LaneScratch::lock(&scratch[lane]);
                    let t = Timer::start();
                    self.train
                        .gather_into(&indices[off..off + block.take], block.micro, &mut s.buf);
                    s.gather_s += t.seconds();
                    s.gather_n += 1;
                    let exec = s.train_exec(self.rt, &cfg.model, instrumented, block.micro)?;
                    let t = Timer::start();
                    let out = exec.run_train(&params, &s.buf)?;
                    s.exec_s += t.seconds();
                    s.exec_n += 1;
                    Ok(out)
                })?;

                // Deterministic reduction: fold the block outputs in
                // block-index order regardless of which lane finished
                // first — bit-identical to the serial loop's
                // interleaved accumulation.
                grad_accum.iter_mut().for_each(|g| *g = 0.0);
                {
                    let _g = profile.section("accumulate");
                    for (out, (_, block)) in outs.iter().zip(&spans) {
                        for (a, g) in grad_accum.iter_mut().zip(&out.grad_sum) {
                            *a += g;
                        }
                        train_loss_sum += out.loss_sum;
                        train_correct += out.correct;
                        if instrumented {
                            diversity.push(&out.grad_sum, out.sqnorm_sum, block.take);
                        }
                    }
                }
                // SGLD: inject per-sample-equivalent noise into the sum
                // gradient (diversity stats are adjusted analytically at
                // the epoch boundary; see coordinator/sgld.rs).
                if cfg.sgld.enabled() {
                    cfg.sgld.perturb_grad_sum(&mut grad_accum, logical, &mut sgld_rng);
                }
                // Optimizer update: theta <- theta - lr/m * sum_grad (+mu/wd).
                {
                    let _g = profile.section("update");
                    if cfg.device_update {
                        let sgd = opt.as_sgd_mut().expect("checked above");
                        let upd = self.rt.update_exec(&cfg.model)?;
                        // Clipping folds into the inv_m scalar, so the
                        // device path shares exact semantics with step().
                        let inv_m = sgd.effective_inv_m(&grad_accum, logical);
                        let (new_p, new_v) = upd.run_update(
                            &params,
                            sgd.velocity(),
                            &grad_accum,
                            lr as f32,
                            cfg.momentum as f32,
                            cfg.weight_decay as f32,
                            inv_m,
                        )?;
                        params = new_p;
                        sgd.set_velocity(new_v);
                    } else {
                        opt.step(&mut params, &grad_accum, lr, logical);
                    }
                }
                steps += 1;
                cum_sim += self.cluster.step_time_at(global_step, logical, instrumented);
                global_step += 1;

                // Step-level adaptation (opt-in): the policy may resize
                // the remaining logical batches of this epoch.  Only
                // `next_batch` is applied here; instrumentation and lr
                // changes are epoch-granular.
                if step_decisions {
                    let step_stats = if instrumented && diversity.samples() > 0 {
                        Some(cfg.sgld.adjust_stats(
                            diversity.stats(),
                            diversity.samples(),
                            info.param_count,
                        ))
                    } else {
                        None
                    };
                    let ctx = AdaptContext {
                        epoch,
                        step: steps,
                        batch_size: m_cur,
                        n,
                        m0: lr_ref,
                        stats: step_stats,
                        history: &history,
                        sim_elapsed: cum_sim,
                        wall_elapsed: cum_wall + epoch_timer.seconds(),
                    };
                    if let Some(d) = policy.on_step(&ctx) {
                        let next = d.next_batch.max(1);
                        if next != m_cur {
                            m_cur = next;
                            m_peak = m_peak.max(m_cur);
                            batches.set_batch_size(m_cur);
                        }
                    }
                }
            }

            // Actual simulated time spent in this epoch's steps (exact
            // under mid-epoch resizes; equals the closed-form epoch
            // estimate only when the batch size was constant).
            let sim_steps = cum_sim - sim_before_steps;

            // Epoch boundary: diversity statistics for the policy.
            let (stats, delta_hat, n_delta, exact_delta) = match need {
                DiversityNeed::None => (None, None, None, None),
                DiversityNeed::Estimated => {
                    let s = cfg
                        .sgld
                        .adjust_stats(diversity.stats(), diversity.samples(), info.param_count);
                    (
                        Some(s),
                        Some(s.delta_hat()),
                        Some(diversity.samples() as f64 * s.delta_hat()),
                        None,
                    )
                }
                DiversityNeed::Exact => {
                    let _g = profile.section("oracle");
                    let s = self.exact_diversity(&params, &info, &step, &scratch)?;
                    // Oracle pays a full instrumented pass over the data.
                    // Stays closed-form even under failure regimes: the
                    // oracle pass is a diagnostic sweep, not optimizer
                    // steps, so it has no global step indices to draw on.
                    cum_sim += self.cluster.epoch_time(n, info.max_micro(), true);
                    (
                        Some(s),
                        None,
                        None,
                        Some(s.delta_hat()),
                    )
                }
            };

            // Validation.
            let (val_loss, val_acc) = {
                let _g = profile.section("eval");
                self.evaluate(&params, &info, &step, &scratch)?
            };

            let wall = epoch_timer.seconds();
            cum_wall += wall;
            // Epoch-granular policies keep the paper's closed-form epoch
            // estimate (byte-identical records); step-level policies —
            // and any active failure regime, whose per-step event draws
            // the closed form cannot see — get the per-step
            // accumulation, which reflects mid-epoch sizes and
            // straggler/preemption events.
            let sim_epoch = if step_decisions || self.cluster.has_regimes() {
                sim_steps
            } else {
                self.cluster.epoch_time(n, m_k, instrumented)
            };
            let train_loss = train_loss_sum / n as f64;
            record.epochs.push(EpochRecord {
                epoch,
                // The size the epoch *started* at; step-level policies
                // may have resized mid-epoch (see `steps` and `mem_mb`).
                batch_size: m_k,
                lr,
                steps,
                train_loss,
                train_acc: 100.0 * train_correct / n as f64,
                val_loss,
                val_acc,
                delta_hat,
                n_delta,
                exact_delta,
                wall_s: wall,
                sim_s: sim_epoch,
                cum_wall_s: cum_wall,
                cum_sim_s: cum_sim,
                // Peak batch size of the epoch (== m_k unless a
                // step-level policy grew it mid-epoch).
                mem_mb: mem_model.step_mb(m_peak, mem_mode),
                dispatches,
                pad_waste: if padded_rows == 0 {
                    0.0
                } else {
                    1.0 - covered_rows as f64 / padded_rows as f64
                },
                par_util: if steps == 0 {
                    1.0
                } else {
                    util_sum / steps as f64
                },
            });
            history.push(HistoryPoint {
                epoch,
                batch_size: m_k,
                train_loss,
                val_loss,
                val_acc,
            });
            if cfg.verbose {
                eprintln!(
                    "[{}] epoch {epoch:>3}  m={m_k:<5} lr={lr:<8.4} train_loss={:.4} val_acc={val_acc:.2}%{} wall={wall:.3}s sim={sim_epoch:.3}s",
                    cfg.policy.kind(),
                    train_loss,
                    delta_hat
                        .or(exact_delta)
                        .map(|d| format!(" delta={d:.3e}"))
                        .unwrap_or_default(),
                );
            }

            // Next epoch's decision (Algorithm 1 line 11 for DiveBatch).
            let decision = policy.on_epoch_end(&AdaptContext {
                epoch,
                step: steps,
                batch_size: m_cur,
                n,
                m0: lr_ref,
                stats,
                history: &history,
                sim_elapsed: cum_sim,
                wall_elapsed: cum_wall,
            })?;
            m_k = decision.next_batch.max(1);
            need = decision.need;
            if let Some(f) = decision.lr_rescale {
                lr_scale = f;
            }
        }

        // Fold the lane-local timings into the run profile (gather /
        // execute attribution survives the move into worker closures).
        for slot in scratch {
            let s = slot.into_inner().unwrap_or_else(|e| e.into_inner());
            if s.gather_n > 0 {
                profile.add_n("gather", s.gather_s, s.gather_n);
            }
            if s.exec_n > 0 {
                profile.add_n("execute", s.exec_s, s.exec_n);
            }
            if s.compile_n > 0 {
                profile.add_n("compile", s.compile_s, s.compile_n);
            }
        }

        Ok(TrainOutcome {
            record,
            profile,
            params,
        })
    }

    /// Mean val loss + accuracy % over the validation set, streamed
    /// through the step executor as one block dispatch and folded in
    /// block order (byte-identical at every lane count).
    fn evaluate(
        &self,
        params: &[f32],
        info: &crate::runtime::ModelInfo,
        step: &StepExecutor,
        scratch: &[Mutex<LaneScratch>],
    ) -> Result<(f64, f64)> {
        let n = self.val.n();
        let (indices, spans) = stream_blocks(n, info, None);
        let outs = step.run_blocks(spans.len(), |lane, bi| {
            let (off, block) = spans[bi];
            let mut s = LaneScratch::lock(&scratch[lane]);
            self.val.gather_into(&indices[off..off + block.take], block.micro, &mut s.buf);
            let exec = s.execs.eval(self.rt, &self.cfg.model, block.micro)?;
            exec.run_eval(params, &s.buf)
        })?;
        let mut loss = 0.0;
        let mut correct = 0.0;
        for out in &outs {
            loss += out.loss_sum;
            correct += out.correct;
        }
        Ok((loss / n as f64, 100.0 * correct / n as f64))
    }

    /// Exact Definition-1 gradient diversity over the FULL training set at
    /// fixed `params` (Oracle policy) — streams instrumented micro-batches
    /// through the step executor without applying updates, pushing the
    /// block outputs into the accumulator in block order.  The stream is
    /// dispatched in bounded chunks so peak memory stays at
    /// O(chunk x param_count) — a full-dataset scan must not hold every
    /// block's gradient vector alive at once.
    fn exact_diversity(
        &self,
        params: &[f32],
        info: &crate::runtime::ModelInfo,
        step: &StepExecutor,
        scratch: &[Mutex<LaneScratch>],
    ) -> Result<DiversityStats> {
        // Blocks in flight per dispatch: enough to keep every lane busy
        // across several rounds, small enough to bound the resident
        // grad_sum vectors.
        let chunk_blocks = (step.lanes() * 16).max(64);
        let n = self.train.n();
        let (indices, spans) = stream_blocks(n, info, self.cfg.max_micro);
        let mut acc = DiversityAccum::new(info.param_count);
        for chunk in spans.chunks(chunk_blocks) {
            let outs = step.run_blocks(chunk.len(), |lane, bi| {
                let (off, block) = chunk[bi];
                let mut s = LaneScratch::lock(&scratch[lane]);
                self.train.gather_into(&indices[off..off + block.take], block.micro, &mut s.buf);
                let exec = s.execs.train(self.rt, &self.cfg.model, true, block.micro)?;
                exec.run_train(params, &s.buf)
            })?;
            // Fold each chunk in block order before the next dispatch:
            // the overall push sequence is identical to the serial scan.
            for (out, (_, block)) in outs.iter().zip(chunk) {
                acc.push(&out.grad_sum, out.sqnorm_sum, block.take);
            }
        }
        Ok(acc.stats())
    }
}

#[cfg(test)]
mod tests {
    // Trainer requires a Runtime with compiled artifacts; end-to-end
    // behaviour (loss decreases, policies adapt, oracle matches estimate
    // on quadratic-like problems, registry-parsed specs match enum-built
    // configs, step-level policies resize mid-epoch, and the step-jobs
    // byte-equality + panic-isolation gates) is covered by
    // rust/tests/integration_trainer.rs, integration_policies.rs, and
    // step_parallel.rs over the committed interpreter fixtures.
}
