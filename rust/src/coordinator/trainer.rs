//! The training coordinator: DiveBatch's Algorithm 1 as a Rust event loop
//! over AOT-compiled PJRT executables.
//!
//! Per epoch `k` (batch size `m_k` from the policy):
//!
//! 1. shuffle the training set; iterate `ceil(n/m_k)` logical batches;
//! 2. decompose each logical batch into compiled micro-batches
//!    ([`MicroPlan`]), execute the train entry (diversity-instrumented iff
//!    the policy needs it), and accumulate the sample-sum outputs;
//! 3. apply one optimizer update per logical batch
//!    (`theta -= eta_k/m_k * sum_grad`, + momentum/wd for image runs);
//! 4. push `(grad_sum, sqnorm_sum)` into the epoch's [`DiversityAccum`];
//!    step-level policies (`wants_step_decisions`) may resize the
//!    remaining logical batches mid-epoch via `on_step`;
//! 5. at the epoch boundary: evaluate on the validation set, optionally
//!    recompute the exact diversity (Oracle), hand the policy an
//!    [`AdaptContext`] and apply its [`Decision`] (next batch size, next
//!    epoch's instrumentation, optional lr rescale), then the LR schedule
//!    (incl. Goyal rescaling).
//!
//! The trainer is generic over any boxed [`BatchPolicy`]: it builds a
//! fresh stateful instance from the config's [`PolicyHandle`] per run,
//! so trials never share controller state.  Python never runs here:
//! every numeric kernel is a compiled artifact.

use anyhow::{bail, Result};

use super::diversity::DiversityAccum;
use super::optimizer::{AdamOptimizer, Optim, SgdOptimizer};
use super::plan::MicroPlan;
use super::policy::{AdaptContext, DiversityNeed, DiversityStats, HistoryPoint, PolicyHandle};
use super::schedule::LrSchedule;
use super::sgld::SgldConfig;
use crate::cluster::ClusterModel;
use crate::data::{Batch, Dataset, EpochBatches};
use crate::metrics::{EpochRecord, MemMode, MemoryModel, RunRecord};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::util::timer::{Profiler, Timer};

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Manifest model name (e.g. "logreg512").
    pub model: String,
    /// Batch-size controller (any [`super::BatchPolicy`], via handle).
    pub policy: PolicyHandle,
    pub schedule: LrSchedule,
    pub epochs: usize,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Global-norm gradient clipping (image runs; see optimizer.rs).
    pub clip_norm: Option<f64>,
    /// Trial seed: selects init params file and the shuffling stream.
    pub seed: u64,
    /// Cap on instrumented micro-batch size (None = whole ladder).
    pub max_micro: Option<usize>,
    /// Use the fused on-device `update` executable instead of the Rust
    /// optimizer (P2 ablation; semantics are identical).  SGD only.
    pub device_update: bool,
    /// Use Adam instead of SGD (paper §6: "DiveBatch could complement
    /// these optimizers").  lr/schedule semantics unchanged.
    pub use_adam: bool,
    /// SGLD-style diversity boosting (paper §6 + Yin et al. §5): inject
    /// per-sample gradient noise of std sigma into the updates and apply
    /// the closed-form diversity adjustment (see coordinator/sgld.rs).
    pub sgld: SgldConfig,
    /// Simulated-cluster shape for this run's `sim_s` timing columns
    /// (worker count, instrumentation surcharge).  Default: the paper's
    /// a100x4 constants; the `train`/`sweep` CLI exposes it as
    /// `--sim-workers` / `--sim-div-overhead`.
    pub cluster: crate::cluster::ClusterSpec,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    /// `policy` accepts the legacy `Policy` enum, a `PolicyHandle` from
    /// [`super::PolicyRegistry::parse`], or any `Box<dyn BatchPolicy>`.
    pub fn new(
        model: &str,
        policy: impl Into<PolicyHandle>,
        schedule: LrSchedule,
        epochs: usize,
    ) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            policy: policy.into(),
            schedule,
            epochs,
            momentum: 0.0,
            weight_decay: 0.0,
            clip_norm: None,
            seed: 0,
            max_micro: None,
            device_update: false,
            use_adam: false,
            sgld: SgldConfig::disabled(),
            cluster: crate::cluster::ClusterSpec::default(),
            verbose: false,
        }
    }
}

/// Outcome of a run: the record plus profiling counters.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub profile: Profiler,
    /// Final parameters (for checkpoint-style chaining).
    pub params: Vec<f32>,
}

/// Orchestrates one training run over a [`Runtime`].
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    cfg: TrainConfig,
    cluster: ClusterModel,
    train: Dataset,
    val: Dataset,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: TrainConfig,
        train: Dataset,
        val: Dataset,
        cluster: ClusterModel,
    ) -> Result<Trainer<'rt>> {
        let info = rt.model(&cfg.model)?;
        if train.feat_len() != info.feat_len() {
            bail!(
                "dataset feature length {} != model {} ({})",
                train.feat_len(),
                cfg.model,
                info.feat_len()
            );
        }
        if train.y.dtype() != if info.label_dtype == crate::runtime::Dtype::S32 { "s32" } else { "f32" } {
            bail!(
                "dataset label dtype {} incompatible with model {}",
                train.y.dtype(),
                cfg.model
            );
        }
        Ok(Trainer {
            rt,
            cfg,
            cluster,
            train,
            val,
        })
    }

    /// Execute the run.
    pub fn run(&self) -> Result<TrainOutcome> {
        let cfg = &self.cfg;
        let info = self.rt.model(&cfg.model)?.clone();
        let n = self.train.n();
        // Fresh stateful policy instance for this run.
        let mut policy = cfg.policy.build();
        // Instrumentation for epoch 0; later epochs come from decisions.
        // Only estimating policies instrument their actual training
        // steps; Oracle trains plain and pays a separate exact pass at
        // the boundary.
        let mut need = policy.diversity_need();
        let step_decisions = policy.wants_step_decisions();

        if cfg.device_update && cfg.use_adam {
            bail!("device_update supports the SGD path only");
        }
        let mut params = self.rt.manifest.load_init_params(&cfg.model, cfg.seed as usize)?;
        let mut opt = if cfg.use_adam {
            Optim::Adam(AdamOptimizer::new(info.param_count, cfg.weight_decay))
        } else {
            let mut sgd = SgdOptimizer::new(info.param_count, cfg.momentum, cfg.weight_decay);
            sgd.clip_norm = cfg.clip_norm;
            Optim::Sgd(sgd)
        };
        let mut shuffle_rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xD117E);
        let mut sgld_rng = shuffle_rng.fork(0x56_1D);

        let mem_model = MemoryModel::for_model(
            info.param_count,
            info.feat_len(),
            info.input_shape.len(),
            info.chunk,
        );

        let mut record = RunRecord::new(
            &cfg.policy.label(),
            &cfg.model,
            cfg.policy.kind(),
            &self.train.name,
            cfg.seed,
        );
        let mut profile = Profiler::new();

        let m0 = policy.initial();
        // Goyal rescaling reference: the base policy's m0 even under
        // wrappers (a warmup batch must not inflate the rescaled lr).
        let lr_ref = policy.rescale_reference();
        let mut m_k = m0;
        // Policy-owned lr factor on top of the schedule (Decision::lr_rescale).
        let mut lr_scale = 1.0f64;
        let mut cum_wall = 0.0;
        let mut cum_sim = 0.0;
        let mut history: Vec<HistoryPoint> = Vec::new();

        // Reusable buffers (no allocation inside the epoch loop — §Perf).
        let mut batch_buf = Batch::empty();
        let mut grad_accum = vec![0.0f32; info.param_count];
        // Per-run executable handles: the runtime cache is keyed by a
        // formatted string (alloc + hash per lookup) behind a lock; the
        // ladder has <= 4 rungs, so a linear-scan Vec of Arc handles makes
        // the per-block lookup free and lock-free (§Perf L3 iteration 1).
        // Keyed by (micro, instrumented) because dynamic-need policies may
        // flip the executable variant between epochs.
        let mut exec_handles: Vec<((usize, bool), std::sync::Arc<crate::runtime::Executable>)> =
            Vec::new();

        for epoch in 0..cfg.epochs {
            let epoch_timer = Timer::start();
            let instrumented = need == DiversityNeed::Estimated;
            let mem_mode = if instrumented {
                MemMode::DivChunked
            } else {
                MemMode::Plain
            };
            let lr = cfg.schedule.lr(epoch, m_k, lr_ref) * lr_scale;
            let mut diversity = DiversityAccum::new(info.param_count);
            let mut train_loss_sum = 0.0;
            let mut train_correct = 0.0;
            let mut steps = 0usize;

            policy.on_epoch_start(&AdaptContext {
                epoch,
                step: 0,
                batch_size: m_k,
                n,
                m0: lr_ref,
                stats: None,
                history: &history,
                sim_elapsed: cum_sim,
                wall_elapsed: cum_wall,
            });

            // Current logical batch size; step-level policies may resize
            // the remaining batches of the epoch.
            let mut m_cur = m_k;
            let mut m_peak = m_k;
            let sim_before_steps = cum_sim;
            let mut batches = EpochBatches::new(n, m_cur, &mut shuffle_rng);
            while let Some(indices) = batches.next() {
                let logical = indices.len();
                let plan = MicroPlan::build(logical, &info.ladder, cfg.max_micro);
                grad_accum.iter_mut().for_each(|g| *g = 0.0);
                let mut offset = 0usize;
                for block in &plan.blocks {
                    let idx = &indices[offset..offset + block.take];
                    offset += block.take;
                    {
                        let _g = profile.section("gather");
                        self.train.gather_into(idx, block.micro, &mut batch_buf);
                    }
                    let key = (block.micro, instrumented);
                    let exec = match exec_handles.iter().find(|(k, _)| *k == key) {
                        Some((_, e)) => e.clone(),
                        None => {
                            let _g = profile.section("compile");
                            let e = self.rt.train_exec(&cfg.model, instrumented, block.micro)?;
                            exec_handles.push((key, e.clone()));
                            e
                        }
                    };
                    let out = {
                        let _g = profile.section("execute");
                        exec.run_train(&params, &batch_buf)?
                    };
                    {
                        let _g = profile.section("accumulate");
                        for (a, g) in grad_accum.iter_mut().zip(&out.grad_sum) {
                            *a += g;
                        }
                        train_loss_sum += out.loss_sum;
                        train_correct += out.correct;
                        if instrumented {
                            diversity.push(&out.grad_sum, out.sqnorm_sum, block.take);
                        }
                    }
                }
                debug_assert_eq!(offset, logical);
                // SGLD: inject per-sample-equivalent noise into the sum
                // gradient (diversity stats are adjusted analytically at
                // the epoch boundary; see coordinator/sgld.rs).
                if cfg.sgld.enabled() {
                    cfg.sgld.perturb_grad_sum(&mut grad_accum, logical, &mut sgld_rng);
                }
                // Optimizer update: theta <- theta - lr/m * sum_grad (+mu/wd).
                {
                    let _g = profile.section("update");
                    if cfg.device_update {
                        let sgd = opt.as_sgd_mut().expect("checked above");
                        let upd = self.rt.update_exec(&cfg.model)?;
                        // Clipping folds into the inv_m scalar, so the
                        // device path shares exact semantics with step().
                        let inv_m = sgd.effective_inv_m(&grad_accum, logical);
                        let (new_p, new_v) = upd.run_update(
                            &params,
                            sgd.velocity(),
                            &grad_accum,
                            lr as f32,
                            cfg.momentum as f32,
                            cfg.weight_decay as f32,
                            inv_m,
                        )?;
                        params = new_p;
                        sgd.set_velocity(new_v);
                    } else {
                        opt.step(&mut params, &grad_accum, lr, logical);
                    }
                }
                steps += 1;
                cum_sim += self.cluster.step_time(logical, instrumented);

                // Step-level adaptation (opt-in): the policy may resize
                // the remaining logical batches of this epoch.  Only
                // `next_batch` is applied here; instrumentation and lr
                // changes are epoch-granular.
                if step_decisions {
                    let step_stats = if instrumented && diversity.samples() > 0 {
                        Some(cfg.sgld.adjust_stats(
                            diversity.stats(),
                            diversity.samples(),
                            info.param_count,
                        ))
                    } else {
                        None
                    };
                    let ctx = AdaptContext {
                        epoch,
                        step: steps,
                        batch_size: m_cur,
                        n,
                        m0: lr_ref,
                        stats: step_stats,
                        history: &history,
                        sim_elapsed: cum_sim,
                        wall_elapsed: cum_wall + epoch_timer.seconds(),
                    };
                    if let Some(d) = policy.on_step(&ctx) {
                        let next = d.next_batch.max(1);
                        if next != m_cur {
                            m_cur = next;
                            m_peak = m_peak.max(m_cur);
                            batches.set_batch_size(m_cur);
                        }
                    }
                }
            }

            // Actual simulated time spent in this epoch's steps (exact
            // under mid-epoch resizes; equals the closed-form epoch
            // estimate only when the batch size was constant).
            let sim_steps = cum_sim - sim_before_steps;

            // Epoch boundary: diversity statistics for the policy.
            let (stats, delta_hat, n_delta, exact_delta) = match need {
                DiversityNeed::None => (None, None, None, None),
                DiversityNeed::Estimated => {
                    let s = cfg
                        .sgld
                        .adjust_stats(diversity.stats(), diversity.samples(), info.param_count);
                    (
                        Some(s),
                        Some(s.delta_hat()),
                        Some(diversity.samples() as f64 * s.delta_hat()),
                        None,
                    )
                }
                DiversityNeed::Exact => {
                    let _g = profile.section("oracle");
                    let s = self.exact_diversity(&params, &info, &mut batch_buf)?;
                    // Oracle pays a full instrumented pass over the data.
                    cum_sim += self.cluster.epoch_time(n, info.max_micro(), true);
                    (
                        Some(s),
                        None,
                        None,
                        Some(s.delta_hat()),
                    )
                }
            };

            // Validation.
            let (val_loss, val_acc) = {
                let _g = profile.section("eval");
                self.evaluate(&params, &info, &mut batch_buf)?
            };

            let wall = epoch_timer.seconds();
            cum_wall += wall;
            // Epoch-granular policies keep the paper's closed-form epoch
            // estimate (byte-identical records); step-level policies get
            // the per-step accumulation, which reflects mid-epoch sizes.
            let sim_epoch = if step_decisions {
                sim_steps
            } else {
                self.cluster.epoch_time(n, m_k, instrumented)
            };
            let train_loss = train_loss_sum / n as f64;
            record.epochs.push(EpochRecord {
                epoch,
                // The size the epoch *started* at; step-level policies
                // may have resized mid-epoch (see `steps` and `mem_mb`).
                batch_size: m_k,
                lr,
                steps,
                train_loss,
                train_acc: 100.0 * train_correct / n as f64,
                val_loss,
                val_acc,
                delta_hat,
                n_delta,
                exact_delta,
                wall_s: wall,
                sim_s: sim_epoch,
                cum_wall_s: cum_wall,
                cum_sim_s: cum_sim,
                // Peak batch size of the epoch (== m_k unless a
                // step-level policy grew it mid-epoch).
                mem_mb: mem_model.step_mb(m_peak, mem_mode),
            });
            history.push(HistoryPoint {
                epoch,
                batch_size: m_k,
                train_loss,
                val_loss,
                val_acc,
            });
            if cfg.verbose {
                eprintln!(
                    "[{}] epoch {epoch:>3}  m={m_k:<5} lr={lr:<8.4} train_loss={:.4} val_acc={val_acc:.2}%{}",
                    cfg.policy.kind(),
                    train_loss,
                    delta_hat
                        .or(exact_delta)
                        .map(|d| format!(" delta={d:.3e}"))
                        .unwrap_or_default(),
                );
            }

            // Next epoch's decision (Algorithm 1 line 11 for DiveBatch).
            let decision = policy.on_epoch_end(&AdaptContext {
                epoch,
                step: steps,
                batch_size: m_cur,
                n,
                m0: lr_ref,
                stats,
                history: &history,
                sim_elapsed: cum_sim,
                wall_elapsed: cum_wall,
            })?;
            m_k = decision.next_batch.max(1);
            need = decision.need;
            if let Some(f) = decision.lr_rescale {
                lr_scale = f;
            }
        }

        Ok(TrainOutcome {
            record,
            profile,
            params,
        })
    }

    /// Mean val loss + accuracy % over the validation set.
    fn evaluate(
        &self,
        params: &[f32],
        info: &crate::runtime::ModelInfo,
        buf: &mut Batch,
    ) -> Result<(f64, f64)> {
        let n = self.val.n();
        let mut loss = 0.0;
        let mut correct = 0.0;
        for indices in EpochBatches::sequential(n, info.max_micro()) {
            let plan = MicroPlan::build(indices.len(), &info.ladder, None);
            let mut offset = 0;
            for block in &plan.blocks {
                let idx = &indices[offset..offset + block.take];
                offset += block.take;
                self.val.gather_into(idx, block.micro, buf);
                let exec = self.rt.eval_exec(&self.cfg.model, block.micro)?;
                let out = exec.run_eval(params, buf)?;
                loss += out.loss_sum;
                correct += out.correct;
            }
        }
        Ok((loss / n as f64, 100.0 * correct / n as f64))
    }

    /// Exact Definition-1 gradient diversity over the FULL training set at
    /// fixed `params` (Oracle policy) — streams instrumented micro-batches
    /// without applying updates.
    fn exact_diversity(
        &self,
        params: &[f32],
        info: &crate::runtime::ModelInfo,
        buf: &mut Batch,
    ) -> Result<DiversityStats> {
        let n = self.train.n();
        let mut acc = DiversityAccum::new(info.param_count);
        for indices in EpochBatches::sequential(n, info.max_micro()) {
            let plan = MicroPlan::build(indices.len(), &info.ladder, self.cfg.max_micro);
            let mut offset = 0;
            for block in &plan.blocks {
                let idx = &indices[offset..offset + block.take];
                offset += block.take;
                self.train.gather_into(idx, block.micro, buf);
                let exec = self.rt.train_exec(&self.cfg.model, true, block.micro)?;
                let out = exec.run_train(params, buf)?;
                acc.push(&out.grad_sum, out.sqnorm_sum, block.take);
            }
        }
        Ok(acc.stats())
    }
}

#[cfg(test)]
mod tests {
    // Trainer requires a Runtime with compiled artifacts; end-to-end
    // behaviour (loss decreases, policies adapt, oracle matches estimate
    // on quadratic-like problems, registry-parsed specs match enum-built
    // configs, step-level policies resize mid-epoch) is covered by
    // rust/tests/integration_trainer.rs and integration_policies.rs over
    // the tiny artifacts.
}
