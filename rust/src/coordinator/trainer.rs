//! The training coordinator: DiveBatch's Algorithm 1 as a Rust event loop
//! over AOT-compiled PJRT executables.
//!
//! Per epoch `k` (batch size `m_k` from the policy):
//!
//! 1. shuffle the training set; iterate `ceil(n/m_k)` logical batches;
//! 2. decompose each logical batch into compiled micro-batches
//!    ([`MicroPlan`]), execute the train entry (diversity-instrumented iff
//!    the policy needs it), and accumulate the sample-sum outputs;
//! 3. apply one optimizer update per logical batch
//!    (`theta -= eta_k/m_k * sum_grad`, + momentum/wd for image runs);
//! 4. push `(grad_sum, sqnorm_sum)` into the epoch's [`DiversityAccum`];
//! 5. at the epoch boundary: evaluate on the validation set, optionally
//!    recompute the exact diversity (Oracle), ask the policy for
//!    `m_{k+1}`, and apply the LR schedule (incl. Goyal rescaling).
//!
//! Python never runs here: every numeric kernel is a compiled artifact.

use anyhow::{bail, Result};

use super::diversity::DiversityAccum;
use super::optimizer::{AdamOptimizer, Optim, SgdOptimizer};
use super::plan::MicroPlan;
use super::policy::{DiversityNeed, DiversityStats, Policy};
use super::schedule::LrSchedule;
use super::sgld::SgldConfig;
use crate::cluster::ClusterModel;
use crate::data::{Batch, Dataset, EpochBatches};
use crate::metrics::{EpochRecord, MemMode, MemoryModel, RunRecord};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::util::timer::{Profiler, Timer};

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Manifest model name (e.g. "logreg512").
    pub model: String,
    pub policy: Policy,
    pub schedule: LrSchedule,
    pub epochs: usize,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Global-norm gradient clipping (image runs; see optimizer.rs).
    pub clip_norm: Option<f64>,
    /// Trial seed: selects init params file and the shuffling stream.
    pub seed: u64,
    /// Cap on instrumented micro-batch size (None = whole ladder).
    pub max_micro: Option<usize>,
    /// Use the fused on-device `update` executable instead of the Rust
    /// optimizer (P2 ablation; semantics are identical).  SGD only.
    pub device_update: bool,
    /// Use Adam instead of SGD (paper §6: "DiveBatch could complement
    /// these optimizers").  lr/schedule semantics unchanged.
    pub use_adam: bool,
    /// SGLD-style diversity boosting (paper §6 + Yin et al. §5): inject
    /// per-sample gradient noise of std sigma into the updates and apply
    /// the closed-form diversity adjustment (see coordinator/sgld.rs).
    pub sgld: SgldConfig,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    pub fn new(model: &str, policy: Policy, schedule: LrSchedule, epochs: usize) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            policy,
            schedule,
            epochs,
            momentum: 0.0,
            weight_decay: 0.0,
            clip_norm: None,
            seed: 0,
            max_micro: None,
            device_update: false,
            use_adam: false,
            sgld: SgldConfig::disabled(),
            verbose: false,
        }
    }
}

/// Outcome of a run: the record plus profiling counters.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub profile: Profiler,
    /// Final parameters (for checkpoint-style chaining).
    pub params: Vec<f32>,
}

/// Orchestrates one training run over a [`Runtime`].
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    cfg: TrainConfig,
    cluster: ClusterModel,
    train: Dataset,
    val: Dataset,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: TrainConfig,
        train: Dataset,
        val: Dataset,
        cluster: ClusterModel,
    ) -> Result<Trainer<'rt>> {
        let info = rt.model(&cfg.model)?;
        if train.feat_len() != info.feat_len() {
            bail!(
                "dataset feature length {} != model {} ({})",
                train.feat_len(),
                cfg.model,
                info.feat_len()
            );
        }
        if train.y.dtype() != if info.label_dtype == crate::runtime::Dtype::S32 { "s32" } else { "f32" } {
            bail!(
                "dataset label dtype {} incompatible with model {}",
                train.y.dtype(),
                cfg.model
            );
        }
        Ok(Trainer {
            rt,
            cfg,
            cluster,
            train,
            val,
        })
    }

    /// Execute the run.
    pub fn run(&self) -> Result<TrainOutcome> {
        let cfg = &self.cfg;
        let info = self.rt.model(&cfg.model)?.clone();
        let n = self.train.n();
        let need = cfg.policy.diversity_need();
        // Only DiveBatch instruments its actual training steps; Oracle
        // trains plain and pays a separate exact pass at the boundary.
        let instrumented = need == DiversityNeed::Estimated;

        if cfg.device_update && cfg.use_adam {
            bail!("device_update supports the SGD path only");
        }
        let mut params = self.rt.manifest.load_init_params(&cfg.model, cfg.seed as usize)?;
        let mut opt = if cfg.use_adam {
            Optim::Adam(AdamOptimizer::new(info.param_count, cfg.weight_decay))
        } else {
            let mut sgd = SgdOptimizer::new(info.param_count, cfg.momentum, cfg.weight_decay);
            sgd.clip_norm = cfg.clip_norm;
            Optim::Sgd(sgd)
        };
        let mut shuffle_rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xD117E);
        let mut sgld_rng = shuffle_rng.fork(0x56_1D);

        let mem_model = MemoryModel::for_model(
            info.param_count,
            info.feat_len(),
            info.input_shape.len(),
            info.chunk,
        );
        let mem_mode = if instrumented {
            MemMode::DivChunked
        } else {
            MemMode::Plain
        };

        let mut record = RunRecord::new(
            &cfg.policy.label(),
            &cfg.model,
            cfg.policy.kind(),
            &self.train.name,
            cfg.seed,
        );
        let mut profile = Profiler::new();

        let m0 = cfg.policy.initial();
        let mut m_k = m0;
        let mut cum_wall = 0.0;
        let mut cum_sim = 0.0;

        // Reusable buffers (no allocation inside the epoch loop — §Perf).
        let mut batch_buf = Batch::empty();
        let mut grad_accum = vec![0.0f32; info.param_count];
        // Per-run executable handles: the runtime cache is keyed by a
        // formatted string (alloc + hash per lookup); the ladder has <= 4
        // rungs, so a linear-scan Vec of Rc handles makes the per-block
        // lookup free (§Perf L3 iteration 1).
        let mut exec_handles: Vec<(usize, std::rc::Rc<crate::runtime::Executable>)> = Vec::new();

        for epoch in 0..cfg.epochs {
            let epoch_timer = Timer::start();
            let lr = cfg.schedule.lr(epoch, m_k, m0);
            let mut diversity = DiversityAccum::new(info.param_count);
            let mut train_loss_sum = 0.0;
            let mut train_correct = 0.0;
            let mut steps = 0usize;

            let batches = EpochBatches::new(n, m_k, &mut shuffle_rng);
            for indices in batches {
                let logical = indices.len();
                let plan = MicroPlan::build(logical, &info.ladder, cfg.max_micro);
                grad_accum.iter_mut().for_each(|g| *g = 0.0);
                let mut offset = 0usize;
                for block in &plan.blocks {
                    let idx = &indices[offset..offset + block.take];
                    offset += block.take;
                    {
                        let _g = profile.section("gather");
                        self.train.gather_into(idx, block.micro, &mut batch_buf);
                    }
                    let exec = match exec_handles.iter().find(|(m, _)| *m == block.micro) {
                        Some((_, e)) => e.clone(),
                        None => {
                            let _g = profile.section("compile");
                            let e = self.rt.train_exec(&cfg.model, instrumented, block.micro)?;
                            exec_handles.push((block.micro, e.clone()));
                            e
                        }
                    };
                    let out = {
                        let _g = profile.section("execute");
                        exec.run_train(&params, &batch_buf)?
                    };
                    {
                        let _g = profile.section("accumulate");
                        for (a, g) in grad_accum.iter_mut().zip(&out.grad_sum) {
                            *a += g;
                        }
                        train_loss_sum += out.loss_sum;
                        train_correct += out.correct;
                        if need == DiversityNeed::Estimated {
                            diversity.push(&out.grad_sum, out.sqnorm_sum, block.take);
                        }
                    }
                }
                debug_assert_eq!(offset, logical);
                // SGLD: inject per-sample-equivalent noise into the sum
                // gradient (diversity stats are adjusted analytically at
                // the epoch boundary; see coordinator/sgld.rs).
                if cfg.sgld.enabled() {
                    cfg.sgld.perturb_grad_sum(&mut grad_accum, logical, &mut sgld_rng);
                }
                // Optimizer update: theta <- theta - lr/m * sum_grad (+mu/wd).
                {
                    let _g = profile.section("update");
                    if cfg.device_update {
                        let sgd = opt.as_sgd_mut().expect("checked above");
                        let upd = self.rt.update_exec(&cfg.model)?;
                        // Clipping folds into the inv_m scalar, so the
                        // device path shares exact semantics with step().
                        let inv_m = sgd.effective_inv_m(&grad_accum, logical);
                        let (new_p, new_v) = upd.run_update(
                            &params,
                            sgd.velocity(),
                            &grad_accum,
                            lr as f32,
                            cfg.momentum as f32,
                            cfg.weight_decay as f32,
                            inv_m,
                        )?;
                        params = new_p;
                        sgd.set_velocity(new_v);
                    } else {
                        opt.step(&mut params, &grad_accum, lr, logical);
                    }
                }
                steps += 1;
                cum_sim += self.cluster.step_time(logical, instrumented);
            }

            // Epoch boundary: diversity statistics for the policy.
            let (stats, delta_hat, n_delta, exact_delta) = match need {
                DiversityNeed::None => (None, None, None, None),
                DiversityNeed::Estimated => {
                    let s = cfg
                        .sgld
                        .adjust_stats(diversity.stats(), diversity.samples(), info.param_count);
                    (
                        Some(s),
                        Some(s.delta_hat()),
                        Some(diversity.samples() as f64 * s.delta_hat()),
                        None,
                    )
                }
                DiversityNeed::Exact => {
                    let _g = profile.section("oracle");
                    let s = self.exact_diversity(&params, &info, &mut batch_buf)?;
                    // Oracle pays a full instrumented pass over the data.
                    cum_sim += self.cluster.epoch_time(n, info.max_micro(), true);
                    (
                        Some(s),
                        None,
                        None,
                        Some(s.delta_hat()),
                    )
                }
            };

            // Validation.
            let (val_loss, val_acc) = {
                let _g = profile.section("eval");
                self.evaluate(&params, &info, &mut batch_buf)?
            };

            let wall = epoch_timer.seconds();
            cum_wall += wall;
            let sim_epoch = self.cluster.epoch_time(n, m_k, instrumented);
            record.epochs.push(EpochRecord {
                epoch,
                batch_size: m_k,
                lr,
                steps,
                train_loss: train_loss_sum / n as f64,
                train_acc: 100.0 * train_correct / n as f64,
                val_loss,
                val_acc,
                delta_hat,
                n_delta,
                exact_delta,
                wall_s: wall,
                sim_s: sim_epoch,
                cum_wall_s: cum_wall,
                cum_sim_s: cum_sim,
                mem_mb: mem_model.step_mb(m_k, mem_mode),
            });
            if cfg.verbose {
                eprintln!(
                    "[{}] epoch {epoch:>3}  m={m_k:<5} lr={lr:<8.4} train_loss={:.4} val_acc={val_acc:.2}%{}",
                    cfg.policy.kind(),
                    train_loss_sum / n as f64,
                    delta_hat
                        .or(exact_delta)
                        .map(|d| format!(" delta={d:.3e}"))
                        .unwrap_or_default(),
                );
            }

            // Next epoch's batch size (Algorithm 1 line 11 for DiveBatch).
            m_k = cfg.policy.next(epoch, m_k, n, stats);
        }

        Ok(TrainOutcome {
            record,
            profile,
            params,
        })
    }

    /// Mean val loss + accuracy % over the validation set.
    fn evaluate(
        &self,
        params: &[f32],
        info: &crate::runtime::ModelInfo,
        buf: &mut Batch,
    ) -> Result<(f64, f64)> {
        let n = self.val.n();
        let mut loss = 0.0;
        let mut correct = 0.0;
        for indices in EpochBatches::sequential(n, info.max_micro()) {
            let plan = MicroPlan::build(indices.len(), &info.ladder, None);
            let mut offset = 0;
            for block in &plan.blocks {
                let idx = &indices[offset..offset + block.take];
                offset += block.take;
                self.val.gather_into(idx, block.micro, buf);
                let exec = self.rt.eval_exec(&self.cfg.model, block.micro)?;
                let out = exec.run_eval(params, buf)?;
                loss += out.loss_sum;
                correct += out.correct;
            }
        }
        Ok((loss / n as f64, 100.0 * correct / n as f64))
    }

    /// Exact Definition-1 gradient diversity over the FULL training set at
    /// fixed `params` (Oracle policy) — streams instrumented micro-batches
    /// without applying updates.
    fn exact_diversity(
        &self,
        params: &[f32],
        info: &crate::runtime::ModelInfo,
        buf: &mut Batch,
    ) -> Result<DiversityStats> {
        let n = self.train.n();
        let mut acc = DiversityAccum::new(info.param_count);
        for indices in EpochBatches::sequential(n, info.max_micro()) {
            let plan = MicroPlan::build(indices.len(), &info.ladder, self.cfg.max_micro);
            let mut offset = 0;
            for block in &plan.blocks {
                let idx = &indices[offset..offset + block.take];
                offset += block.take;
                self.train.gather_into(idx, block.micro, buf);
                let exec = self.rt.train_exec(&self.cfg.model, true, block.micro)?;
                let out = exec.run_train(params, buf)?;
                acc.push(&out.grad_sum, out.sqnorm_sum, block.take);
            }
        }
        Ok(acc.stats())
    }
}

#[cfg(test)]
mod tests {
    // Trainer requires a Runtime with compiled artifacts; end-to-end
    // behaviour (loss decreases, policies adapt, oracle matches estimate
    // on quadratic-like problems) is covered by
    // rust/tests/integration_trainer.rs over the tiny artifacts.
}
