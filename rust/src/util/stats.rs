//! Small statistics toolkit: running moments, mean/stderr across trials,
//! percentiles — everything the metrics layer and bench harness need.

/// Welford running mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean — the paper reports acc ± stderr.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn stderr(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        std(xs) / (xs.len() as f64).sqrt()
    }
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Element-wise mean across equal-length series (curve averaging over
/// trials, as in the paper's "average over 5/10 trials" figures).
pub fn mean_curve(series: &[Vec<f64>]) -> Vec<f64> {
    assert!(!series.is_empty());
    let len = series[0].len();
    assert!(
        series.iter().all(|s| s.len() == len),
        "curves must share length"
    );
    (0..len)
        .map(|i| series.iter().map(|s| s[i]).sum::<f64>() / series.len() as f64)
        .collect()
}

/// Element-wise standard error across series.
pub fn stderr_curve(series: &[Vec<f64>]) -> Vec<f64> {
    let len = series[0].len();
    (0..len)
        .map(|i| {
            let col: Vec<f64> = series.iter().map(|s| s[i]).collect();
            stderr(&col)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 16.0);
        assert_eq!(r.count(), 5);
    }

    #[test]
    fn stderr_scales_with_sqrt_n() {
        let xs4 = vec![0.0, 1.0, 0.0, 1.0];
        let xs16: Vec<f64> = xs4.iter().cycle().take(16).copied().collect();
        let r = stderr(&xs4) / stderr(&xs16);
        // Exactly 2 with population std; the (n-1) sample correction
        // nudges it to sqrt(16/4 * 3/15 * 16/4) ≈ 2.24.
        assert!((1.8..2.5).contains(&r), "{r}");
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn curves_average() {
        let a = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(mean_curve(&a), vec![2.0, 3.0]);
        let se = stderr_curve(&a);
        assert!((se[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std(&[5.0]), 0.0);
        let mut r = Running::new();
        r.push(3.0);
        assert_eq!(r.var(), 0.0);
        assert_eq!(r.stderr(), 0.0);
    }
}
