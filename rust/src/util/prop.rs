//! Mini property-based testing framework (proptest is not vendored).
//!
//! Provides seeded generators and a `forall` runner with greedy shrinking:
//! on failure, the runner repeatedly tries smaller variants of the failing
//! input (as produced by `Shrink::shrink`) until a local minimum is found,
//! then panics with the minimal counterexample and the reproducing seed.
//!
//! Usage:
//! ```ignore
//! forall(100, |r| (r.below(4096) as usize + 1, ladder_gen(r)), |(m, ladder)| {
//!     let plan = Plan::build(*m, ladder);
//!     plan.covered() == *m
//! });
//! ```

use super::rng::Rng;

/// Types that know how to propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate smaller values, roughly ordered most-aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, remove single elements, shrink single elements.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        for i in 0..self.len().min(8) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for i in 0..self.len().min(4) {
            for s in self[i].shrink() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Run `cases` random trials of `property` over inputs drawn by `gen`.
///
/// The seed comes from `DIVEBATCH_PROP_SEED` (default 0) so failures are
/// reproducible; each case uses an independent forked stream.
pub fn forall<T, G, P>(cases: usize, mut gen: G, mut property: P)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let seed: u64 = std::env::var("DIVEBATCH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut r = root.fork(case as u64);
        let input = gen(&mut r);
        if !property(&input) {
            let minimal = shrink_to_minimal(input, &mut property);
            panic!(
                "property failed (seed={seed}, case={case}).\n  minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_to_minimal<T, P>(mut failing: T, property: &mut P) -> T
where
    T: Shrink + std::fmt::Debug,
    P: FnMut(&T) -> bool,
{
    // Greedy descent, bounded to avoid pathological loops.
    'outer: for _ in 0..1000 {
        for cand in failing.shrink() {
            if !property(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            50,
            |r| r.below(100) as usize,
            |_| {
                count += 1;
                true
            },
        );
        assert!(count >= 50);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics_with_counterexample() {
        forall(100, |r| r.below(1000) as usize, |&n| n < 500);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Catch the panic and check the reported example is minimal-ish.
        let result = std::panic::catch_unwind(|| {
            forall(200, |r| r.below(10_000) as usize, |&n| n < 100);
        });
        let msg = match result {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("expected failure"),
        };
        // Greedy shrinking should land exactly on the boundary value 100.
        assert!(msg.contains("counterexample: 100"), "{msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![5usize, 6, 7, 8];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }

    #[test]
    fn tuple_shrink_covers_both_components() {
        let t = (10usize, vec![3usize]);
        let shrunk = t.shrink();
        assert!(shrunk.iter().any(|(a, _)| *a < 10));
        assert!(shrunk.iter().any(|(_, v)| v.is_empty()));
    }
}
