//! Single-writer directory lock, shared by the results cache and the
//! sweep journal.
//!
//! A writer holds an exclusive advisory lock on a directory for the
//! duration of one mutation pass (store+evict, or a journal append).
//! `create_new` is atomic on every platform we care about; the lock
//! file is removed on drop.
//!
//! **Stale reclaim** — a lock older than [`STALE_LOCK`] is presumed
//! left behind by a crashed owner (live writers hold it for
//! milliseconds).  Reclaim uses a *tomb rename* rather than a bare
//! `remove_file`: `rename(.lock, .lock.reclaim.<pid>.<n>)` is atomic,
//! so when several blocked writers notice staleness at once exactly one
//! wins the rename (the losers' renames fail with `NotFound` and they
//! go back to waiting).  With plain `remove_file`, two reclaimers could
//! each "succeed" — the second deleting the *fresh* lock the first had
//! just created, silently admitting a third writer.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

use anyhow::{bail, Context, Result};

/// A lock older than this is treated as left behind by a crashed writer
/// and reclaimed (writers hold it for milliseconds).
pub const STALE_LOCK: Duration = Duration::from_secs(10);

/// How long a writer waits for the lock before giving up.
pub const LOCK_TIMEOUT: Duration = Duration::from_secs(30);

/// Distinguishes concurrent tomb names within one process.
static TOMB_SEQ: AtomicU64 = AtomicU64::new(0);

/// Exclusive advisory lock on a directory (file `.lock` inside it).
pub struct DirLock {
    path: PathBuf,
}

impl DirLock {
    /// Block until the lock is held, reclaiming stale locks, failing
    /// after [`LOCK_TIMEOUT`].
    pub fn acquire(dir: &Path) -> Result<DirLock> {
        let path = dir.join(".lock");
        let deadline = SystemTime::now() + LOCK_TIMEOUT;
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(_) => return Ok(DirLock { path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|md| md.modified())
                        .map(|m| m.elapsed().map(|d| d > STALE_LOCK).unwrap_or(false))
                        .unwrap_or(false);
                    if stale {
                        let tomb = dir.join(format!(
                            ".lock.reclaim.{}.{}",
                            std::process::id(),
                            TOMB_SEQ.fetch_add(1, Ordering::Relaxed)
                        ));
                        // Single-winner: only one reclaimer's rename can
                        // succeed; everyone else loops back to waiting.
                        if std::fs::rename(&path, &tomb).is_ok() {
                            let _ = std::fs::remove_file(&tomb);
                        }
                        continue;
                    }
                    if SystemTime::now() > deadline {
                        bail!("directory lock busy: {}", path.display());
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("locking {}", path.display()));
                }
            }
        }
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("divebatch-fslock-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn lock_excludes_and_releases() {
        let dir = tmpdir("basic");
        {
            let _l = DirLock::acquire(&dir).unwrap();
            assert!(dir.join(".lock").exists());
            // A second acquire would block; prove the file exists instead
            // of burning LOCK_TIMEOUT here.
        }
        assert!(!dir.join(".lock").exists(), "released on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_stale_reclaim_has_one_winner() {
        let dir = tmpdir("stale-race");
        let lock = dir.join(".lock");
        std::fs::write(&lock, "").unwrap();
        let old = SystemTime::now() - (STALE_LOCK + Duration::from_secs(5));
        std::fs::OpenOptions::new()
            .append(true)
            .open(&lock)
            .unwrap()
            .set_modified(old)
            .unwrap();
        // Many threads race to reclaim + acquire; the lock must
        // serialize them all and end up released.
        let counter = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let dir = &dir;
                let counter = &counter;
                s.spawn(move || {
                    let _l = DirLock::acquire(dir).unwrap();
                    let inside = counter.fetch_add(1, Ordering::SeqCst);
                    assert_eq!(inside, counter.load(Ordering::SeqCst) - 1);
                    std::thread::sleep(Duration::from_millis(2));
                    counter.fetch_sub(1, Ordering::SeqCst);
                });
            }
        });
        assert!(!lock.exists());
        // No tomb files left behind either.
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
