//! ASCII table renderer — prints the paper-style result tables
//! (Table 1 / 2 / 5 rows) from the bench harnesses.

/// A simple column-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        // Column widths in CHARS, not bytes — cells contain multibyte
        // glyphs like '±'.
        let w = |s: &String| s.chars().count();
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(w).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(w(c));
            }
        }
        let sep = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for width in w {
                s.push_str(&"-".repeat(width + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - w(&cells[i]);
                s.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad)));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep(&widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out
    }
}

/// Format `mean ± err` with paper-style 2-decimal precision.
pub fn pm(mean: f64, err: f64) -> String {
    format!("{mean:.2} ± {err:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["Algorithm", "Acc"]);
        t.row(vec!["SGD (128)".into(), pm(95.50, 0.02)]);
        t.row(vec!["DiveBatch (128 - 2048)".into(), pm(93.82, 0.08)]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| Algorithm"));
        assert!(s.contains("95.50 ± 0.02"));
        // All body lines equal CHAR width (cells contain multibyte '±').
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn empty_table_renders_header() {
        let t = Table::new("", &["col"]);
        assert!(t.is_empty());
        assert!(t.render().contains("| col |"));
    }
}
