//! Wall-clock timing helpers + a scoped section profiler used by the
//! §Perf pass to attribute epoch time to pipeline stages (marshal /
//! execute / fetch / optimizer / data).

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Accumulates per-section wall time across many scopes.
///
/// ```ignore
/// let mut prof = Profiler::new();
/// { let _g = prof.section("execute"); run(); }
/// println!("{}", prof.report());
/// ```
#[derive(Debug, Default)]
pub struct Profiler {
    totals: BTreeMap<&'static str, (f64, u64)>,
}

pub struct SectionGuard<'a> {
    prof: &'a mut Profiler,
    name: &'static str,
    start: Instant,
}

impl Profiler {
    pub fn new() -> Self {
        Profiler::default()
    }

    pub fn section(&mut self, name: &'static str) -> SectionGuard<'_> {
        SectionGuard {
            name,
            start: Instant::now(),
            prof: self,
        }
    }

    pub fn add(&mut self, name: &'static str, seconds: f64) {
        self.add_n(name, seconds, 1);
    }

    /// Fold externally-accumulated totals into a section — e.g. per-lane
    /// timings merged after a parallel region, where per-call
    /// `section()` guards cannot reach the `&mut` profiler.
    pub fn add_n(&mut self, name: &'static str, seconds: f64, calls: u64) {
        let e = self.totals.entry(name).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += calls;
    }

    pub fn total(&self, name: &str) -> f64 {
        self.totals.get(name).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.totals.get(name).map(|e| e.1).unwrap_or(0)
    }

    pub fn sections(&self) -> impl Iterator<Item = (&'static str, f64, u64)> + '_ {
        self.totals.iter().map(|(k, (t, n))| (*k, *t, *n))
    }

    /// Human-readable breakdown sorted by total time.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.totals.iter().collect();
        rows.sort_by(|a, b| b.1 .0.partial_cmp(&a.1 .0).unwrap());
        let grand: f64 = rows.iter().map(|(_, (t, _))| t).sum();
        let mut out = String::new();
        for (name, (t, n)) in rows {
            out.push_str(&format!(
                "  {name:<12} {t:>9.3}s  {:>5.1}%  ({n} calls, {:.3} ms/call)\n",
                if grand > 0.0 { 100.0 * t / grand } else { 0.0 },
                1e3 * t / (*n).max(1) as f64,
            ));
        }
        out
    }

    pub fn reset(&mut self) {
        self.totals.clear();
    }
}

impl Drop for SectionGuard<'_> {
    fn drop(&mut self) {
        self.prof
            .add(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.seconds() >= 0.004);
        assert!(t.millis() >= 4.0);
    }

    #[test]
    fn profiler_accumulates_sections() {
        let mut p = Profiler::new();
        for _ in 0..3 {
            let _g = p.section("work");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        p.add("manual", 1.5);
        p.add_n("manual", 0.5, 4);
        assert_eq!(p.count("work"), 3);
        assert!(p.total("work") >= 0.005);
        assert_eq!(p.total("manual"), 2.0);
        assert_eq!(p.count("manual"), 5);
        let rep = p.report();
        assert!(rep.contains("work"));
        assert!(rep.contains("manual"));
    }

    #[test]
    fn reset_clears() {
        let mut p = Profiler::new();
        p.add("a", 1.0);
        p.reset();
        assert_eq!(p.total("a"), 0.0);
    }
}
