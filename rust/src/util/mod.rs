//! Foundation substrates (DESIGN.md §4.11).
//!
//! This offline build environment vendors only the `xla` crate's
//! dependency closure, so the framework-grade utilities a project like
//! this would normally pull from crates.io are implemented in-tree:
//!
//! * [`rng`]    — xoshiro256++ / SplitMix64 PRNG (replaces `rand`)
//! * [`json`]   — full JSON parser + writer (replaces `serde_json`)
//! * [`args`]   — declarative CLI parsing (replaces `clap`)
//! * [`prop`]   — property-based testing with shrinking (replaces `proptest`)
//! * [`stats`]  — running moments, stderr, percentiles, curve averaging
//! * [`table`]  — paper-style ASCII tables
//! * [`plot`]   — ASCII line plots for the figures
//! * [`timer`]  — stopwatch + scoped section profiler for the §Perf pass
//! * [`fslock`] — shared tmp+rename directory lock with stale-lock
//!   reclaim (the results cache's and sweep journal's write discipline)

pub mod args;
pub mod fslock;
pub mod json;
pub mod plot;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
