//! ASCII line plots — renders the paper's figures (validation loss /
//! accuracy / batch-size / diversity curves) directly in the terminal and
//! in EXPERIMENTS.md code blocks.  Multiple labelled series per chart.

/// A labelled series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub ys: Vec<f64>,
}

impl Series {
    pub fn new(label: &str, ys: Vec<f64>) -> Self {
        Series {
            label: label.to_string(),
            ys,
        }
    }
}

/// Render series (sharing an implicit x = 0..n index, e.g. epochs) into a
/// `width` x `height` character grid with y-axis labels and a legend.
pub fn render(title: &str, x_label: &str, series: &[Series], width: usize, height: usize) -> String {
    const MARKS: &[char] = &['*', '+', 'o', 'x', '#', '@', '%', '&'];
    assert!(!series.is_empty(), "no series to plot");
    let max_len = series.iter().map(|s| s.ys.len()).max().unwrap();
    if max_len == 0 {
        return format!("{title}: (empty)\n");
    }
    let finite = |v: f64| v.is_finite();
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for s in series {
        for &y in s.ys.iter().filter(|y| finite(**y)) {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !ymin.is_finite() || !ymax.is_finite() {
        return format!("{title}: (no finite data)\n");
    }
    if (ymax - ymin).abs() < 1e-30 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for (i, &y) in s.ys.iter().enumerate() {
            if !finite(y) {
                continue;
            }
            let col = if max_len == 1 {
                0
            } else {
                i * (width - 1) / (max_len - 1)
            };
            let frac = (y - ymin) / (ymax - ymin);
            let row = height - 1 - ((frac * (height - 1) as f64).round() as usize).min(height - 1);
            grid[row][col] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("-- {title} --\n"));
    for (r, line) in grid.iter().enumerate() {
        let y_here = ymax - (ymax - ymin) * r as f64 / (height - 1) as f64;
        let label = if r == 0 || r == height - 1 || r == height / 2 {
            format!("{y_here:>10.4} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.push_str(&line.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>12}0 .. {} ({x_label})\n", "", max_len - 1));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "{:>12}{} = {}\n",
            "",
            MARKS[si % MARKS.len()],
            s.label
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic_curve() {
        let ys: Vec<f64> = (0..50).map(|i| (i as f64 * 0.2).sin()).collect();
        let s = render("sine", "epoch", &[Series::new("sin", ys)], 60, 12);
        assert!(s.contains("-- sine --"));
        assert!(s.contains("* = sin"));
        assert!(s.lines().count() > 12);
    }

    #[test]
    fn multiple_series_get_distinct_marks() {
        let a = Series::new("a", vec![0.0, 1.0, 2.0]);
        let b = Series::new("b", vec![2.0, 1.0, 0.0]);
        let s = render("two", "x", &[a, b], 30, 8);
        assert!(s.contains("* = a"));
        assert!(s.contains("+ = b"));
    }

    #[test]
    fn constant_series_does_not_panic() {
        let s = render("c", "x", &[Series::new("k", vec![5.0; 10])], 20, 5);
        assert!(s.contains("k"));
    }

    #[test]
    fn handles_nan_gracefully() {
        let s = render(
            "n",
            "x",
            &[Series::new("nan", vec![f64::NAN, 1.0, 2.0])],
            20,
            5,
        );
        assert!(s.contains("nan"));
    }

    #[test]
    fn empty_series_safe() {
        let s = render("e", "x", &[Series::new("none", vec![])], 20, 5);
        assert!(s.contains("empty"));
    }
}
