//! Minimal-but-complete JSON parser and writer.
//!
//! serde is not vendored in this environment, so the runtime's
//! `artifacts/manifest.json` loading and the metrics JSONL sinks use this
//! in-tree implementation.  It supports the full JSON grammar (objects,
//! arrays, strings with escapes + \uXXXX, numbers, bools, null) with
//! byte-offset error reporting; the writer escapes correctly and
//! round-trips everything the parser accepts.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are kept in a BTreeMap so output is
/// deterministic (stable across runs — important for golden-file tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` with a readable error chain.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field accessors used by the manifest loader.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a non-negative integer"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not an array"))
    }

    // ------------------------------------------------------------ building

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------- writing

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; encode as null (metrics sinks filter these).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parsing

/// Maximum container nesting the parser accepts.  The parser recurses
/// per nesting level, so without a cap a hostile document (`"[[[[..."`)
/// overflows the stack; 128 levels is far beyond anything this crate
/// reads or writes (the artifacts manifest nests ~6 deep, run records
/// 3) while keeping worst-case stack use trivially bounded.  This is a
/// hard requirement for the server, which parses network-supplied bytes.
pub const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed).
///
/// Total: every input either parses or yields a typed [`JsonError`] —
/// never a panic, and never unbounded recursion (see [`MAX_DEPTH`]).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {word})")))
        }
    }

    /// Bump the container depth, rejecting documents nested deeper than
    /// [`MAX_DEPTH`].  Paired with a manual decrement on container exit
    /// so siblings don't accumulate depth.
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
        assert_eq!(v.req_str("c").unwrap(), "x");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("line\nquote\"back\\slash\ttab \u{1F600}".into());
        let text = original.to_string();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // Surrogate pair for 😀 U+1F600.
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn errors_carry_offsets() {
        let e = parse("[1, 2,]").unwrap_err();
        assert!(e.offset >= 6, "{e}");
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1] extra").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip_document() {
        let text = r#"{"models":{"m":{"ladder":[4,8],"param_count":9,"tags":[]}},"version":1}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.to_string(), text);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn accessor_errors_are_descriptive() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.req_str("a").is_err());
        assert!(v.req("missing").is_err());
        assert_eq!(v.req_usize("a").unwrap(), 1);
    }

    #[test]
    fn nesting_depth_is_capped_not_stack_overflowed() {
        // Deeply nested documents get a typed error, not a blown stack:
        // the first-nesting-over-the-cap is rejected before recursing
        // further, so even a megabyte of '[' returns quickly.
        for n in [MAX_DEPTH + 1, 10_000, 1_000_000] {
            let e = parse(&"[".repeat(n)).unwrap_err();
            assert!(e.message.contains("nesting"), "{e}");
            let e = parse(&"{\"k\":".repeat(n)).unwrap_err();
            assert!(e.message.contains("nesting"), "{e}");
        }
    }

    #[test]
    fn nesting_below_cap_parses_and_siblings_do_not_accumulate() {
        // Exactly MAX_DEPTH levels is accepted.
        let deep = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&deep).is_ok());
        // One more is not.
        let over = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(parse(&over).is_err());
        // Depth is per-branch, not cumulative: thousands of shallow
        // siblings are fine.
        let wide = format!("[{}{{}}]", "{},".repeat(5000));
        assert!(parse(&wide).is_ok());
    }

    #[test]
    fn manifest_shape_smoke() {
        // Mirrors the structure aot.py emits.
        let text = r#"{"version": 1, "models": {"tinylogreg8": {
            "param_count": 9, "ladder": [4, 8],
            "entries": {"eval_b4": {"file": "tinylogreg8/eval_b4.hlo.txt",
                "inputs": [{"name": "params", "dtype": "f32", "shape": [9]}],
                "outputs": []}}}}}"#;
        let v = parse(text).unwrap();
        let m = v.get("models").unwrap().get("tinylogreg8").unwrap();
        assert_eq!(m.req_usize("param_count").unwrap(), 9);
        let e = m.get("entries").unwrap().get("eval_b4").unwrap();
        assert_eq!(
            e.req_arr("inputs").unwrap()[0].req_str("name").unwrap(),
            "params"
        );
    }
}
