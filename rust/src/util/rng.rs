//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate is vendored in this environment, so the data pipeline
//! and property-test framework use an in-tree xoshiro256++ generator
//! (Blackman & Vigna 2019) seeded through SplitMix64 — the same
//! construction the reference `rand_xoshiro` crate uses.  Reference
//! vectors from the published C implementation are pinned in the tests,
//! so every dataset / shuffle / trial in EXPERIMENTS.md is reproducible
//! bit-for-bit.

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
///
/// Period 2^256 - 1; passes BigCrush.  Good enough for synthetic-data
/// generation and shuffling (we are not doing cryptography).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (zero seed is safe — state cannot become all-zero).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. per trial / per dataset split).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Unbiased integer in `[0, n)` (Lemire-style rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Integer in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0).
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A shuffled index permutation `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with uniforms in `[lo, hi)` (f32).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo as f64, hi as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567, from the published SplitMix64 C code.
        let mut s = 1234567u64;
        let a = splitmix64(&mut s);
        let b = splitmix64(&mut s);
        assert_ne!(a, b);
        // Determinism across constructions.
        let mut s2 = 1234567u64;
        assert_eq!(a, splitmix64(&mut s2));
    }

    #[test]
    fn xoshiro_reference_vector() {
        // Cross-checked against the canonical xoshiro256++ C implementation
        // seeded with SplitMix64(0): state = [s0,s1,s2,s3] as in Rng::new(0).
        let mut r = Rng::new(0);
        let first: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        // Pinned values (regression guard for the generator implementation).
        assert_eq!(
            first,
            vec![
                5987356902031041503,
                7051070477665621255,
                6633766593972829180,
                211316841551650330
            ]
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(7);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(4);
        let perm = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &i in &perm {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
        // And not the identity (astronomically unlikely).
        assert!(perm.iter().enumerate().any(|(i, &v)| i as u32 != v));
    }

    #[test]
    fn range_endpoints() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.range(-3, 4);
            assert!((-3..4).contains(&v));
        }
    }
}
