//! Declarative CLI argument parser (clap is not vendored; see Cargo.toml).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, typed accessors with defaults, and auto-generated `--help`
//! text.  Used by the `divebatch` launcher, every example binary, and the
//! bench harness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument specification + parser.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    program: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
    positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed argument values.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        ArgSpec {
            program,
            about,
            ..Default::default()
        }
    }

    /// `--name <value>` option with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag (default false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Positional argument (required, in declaration order).
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = write!(s, "\nusage: {}", self.program);
        for (p, _) in &self.positionals {
            let _ = write!(s, " <{p}>");
        }
        let _ = writeln!(s, " [options]\n");
        for (p, h) in &self.positionals {
            let _ = writeln!(s, "  <{p:<18}> {h}");
        }
        for o in &self.opts {
            let left = if o.is_flag {
                format!("--{}", o.name)
            } else if let Some(d) = &o.default {
                format!("--{} <v={d}>", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let _ = writeln!(s, "  {left:<22} {}", o.help);
        }
        s
    }

    /// Parse a token list (no program name).  Returns Err(usage) on
    /// `--help` or malformed input.
    pub fn parse_tokens(&self, tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    args.flags.insert(name.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positionals.push(tok.clone());
            }
        }
        if args.positionals.len() < self.positionals.len() {
            return Err(format!(
                "missing positional <{}>\n\n{}",
                self.positionals[args.positionals.len()].0,
                self.usage()
            ));
        }
        Ok(args)
    }

    /// Parse `std::env::args()` (skipping the program name); print usage
    /// and exit on error — the behaviour binaries want.
    pub fn parse_or_exit(&self) -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_tokens(&tokens) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} missing (no default declared)"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self.str(name);
        raw.parse().unwrap_or_else(|_| {
            eprintln!("option --{name}: cannot parse {raw:?}");
            std::process::exit(2);
        })
    }

    pub fn positional(&self, idx: usize) -> &str {
        &self.positionals[idx]
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "a test program")
            .opt("epochs", Some("10"), "number of epochs")
            .opt("policy", None, "batch size policy")
            .flag("verbose", "chatty output")
            .pos("model", "model name")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = spec().parse_tokens(&toks(&["mymodel"])).unwrap();
        assert_eq!(a.usize("epochs"), 10);
        assert_eq!(a.positional(0), "mymodel");
        assert!(!a.flag("verbose"));

        let a = spec()
            .parse_tokens(&toks(&["m", "--epochs", "50", "--verbose"]))
            .unwrap();
        assert_eq!(a.usize("epochs"), 50);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = spec().parse_tokens(&toks(&["m", "--epochs=7"])).unwrap();
        assert_eq!(a.usize("epochs"), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse_tokens(&toks(&["m", "--nope", "1"])).is_err());
    }

    #[test]
    fn missing_positional_rejected() {
        let e = spec().parse_tokens(&toks(&[])).unwrap_err();
        assert!(e.contains("missing positional"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse_tokens(&toks(&["m", "--epochs"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = spec().parse_tokens(&toks(&["--help"])).unwrap_err();
        assert!(e.contains("usage: test"));
        assert!(e.contains("--epochs"));
    }

    #[test]
    fn list_option() {
        let s = ArgSpec::new("t", "").opt("models", Some("a,b , c"), "");
        let a = s.parse_tokens(&[]).unwrap();
        assert_eq!(a.list("models"), vec!["a", "b", "c"]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec().parse_tokens(&toks(&["m", "--verbose=1"])).is_err());
    }
}
