//! The shared worker-pool layer: one work-stealing core for **both**
//! levels of parallelism in this crate.
//!
//! Two schedulers live here, sharing the jobs-budget arithmetic and the
//! panic-isolation contract:
//!
//! * [`run_indexed`] / [`run_indexed_with`] — a scoped, spawn-per-call
//!   fan-out for **coarse** work items (training trials: seconds each,
//!   spawn cost irrelevant).  The trial engine ([`crate::engine`])
//!   specializes it to `TrialSpec -> RunRecord`.
//! * [`WorkerPool`] — a **persistent** pool for fine-grained repeated
//!   dispatch (the step executor: micro-batch blocks of one logical
//!   batch, microseconds each, dispatched thousands of times per run).
//!   Workers park between scatters instead of being respawned, so the
//!   per-step overhead is one condvar wake, not N thread spawns.
//!
//! Both return results **in item order** regardless of completion order
//! — the foundation of the crate-wide determinism guarantee (records are
//! byte-identical at any `--jobs` / `--step-jobs` level) — and both
//! capture per-item panics as [`JobError::Panicked`] instead of
//! propagating or hanging.
//!
//! ## One jobs budget, two levels
//!
//! Trial-level (`--jobs`) and step-level (`--step-jobs`) parallelism
//! compose under a single core budget instead of multiplying: the trial
//! engine hands each concurrently-running trial a step allowance of
//! `effective_jobs(jobs) / trial_workers` lanes (so `--jobs 8` over 2
//! trials = 2 trials x 4 step lanes = 8 busy cores, never 16), and
//! [`resolve_step_jobs`] arbitrates the per-trial knob: an explicit
//! `TrainConfig::step_jobs` wins, then the `DIVEBATCH_STEP_JOBS`
//! environment variable, then the engine's allowance.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use anyhow::Result;

/// Why one work item of a pool dispatch produced no result.
///
/// The trial engine re-exports this as `TrialError` (its historical
/// name), and `Display` keeps that consumer's historical wording
/// (`trial failed: ...` / `trial panicked: ...`): the only path that
/// surfaces this type to users IS the trial level — the step executor
/// never displays it, mapping the variants into block-named `anyhow`
/// errors instead (`step block 3 of 8 ...`).
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// The item returned an error (message carries the anyhow chain).
    Failed(String),
    /// The item panicked; the payload is the panic message.
    Panicked(String),
    /// Every retry attempt failed; the history holds each attempt's
    /// failure, oldest first (produced by the engine's `RetryPolicy`).
    Exhausted(Vec<JobError>),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Failed(m) => write!(f, "trial failed: {m}"),
            JobError::Panicked(m) => write!(f, "trial panicked: {m}"),
            JobError::Exhausted(attempts) => {
                write!(f, "trial failed after {} attempts", attempts.len())?;
                for (i, a) in attempts.iter().enumerate() {
                    write!(f, "; attempt {}: {a}", i + 1)?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Number of worker threads the platform offers (>= 1).
pub fn available_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a user-facing jobs knob: 0 means "all available cores".
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        available_jobs()
    } else {
        jobs
    }
}

/// Trial-engine jobs level from the `DIVEBATCH_JOBS` environment
/// variable, used by the bench harnesses (which have no CLI):
/// unset/invalid = 0 = auto.
pub fn jobs_from_env() -> usize {
    std::env::var("DIVEBATCH_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Step-executor lanes from the `DIVEBATCH_STEP_JOBS` environment
/// variable (integration suites / benches): unset/invalid = 0 = defer
/// to the caller's fallback.
pub fn step_jobs_from_env() -> usize {
    std::env::var("DIVEBATCH_STEP_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Arbitrate the step-executor lane count for one trial: an explicit
/// `TrainConfig::step_jobs` wins, then `DIVEBATCH_STEP_JOBS`, then
/// `fallback` (the trial engine's per-trial share of the jobs budget;
/// 1 for a directly-constructed `Trainer`).  Always >= 1.
pub fn resolve_step_jobs(explicit: usize, fallback: usize) -> usize {
    if explicit > 0 {
        return explicit;
    }
    let env = step_jobs_from_env();
    if env > 0 {
        env
    } else {
        fallback.max(1)
    }
}

/// Lock, recovering from poisoning: pool bookkeeping is always left
/// consistent (writers never panic mid-update — item panics are caught
/// before they reach pool state), so a panicking worker must not wedge
/// the pool for the rest of the run.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ------------------------------------------------- scoped coarse fan-out

/// Run `f` over every item of `items` on up to `jobs` worker threads
/// (0 = all cores), returning results **in item order**.  Each call is
/// panic-isolated; `on_done` fires from worker threads in completion
/// order (progress reporting — item index identifies the work item).
///
/// Threads are spawned per call (scoped), which is the right trade for
/// coarse items like training trials; for microsecond-scale repeated
/// dispatch use [`WorkerPool`] instead.
pub fn run_indexed_with<T, R, F, C>(
    items: &[T],
    jobs: usize,
    f: F,
    on_done: C,
) -> Vec<std::result::Result<R, JobError>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
    C: Fn(usize, &std::result::Result<R, JobError>) + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = effective_jobs(jobs).min(n).max(1);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<std::result::Result<R, JobError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i, &items[i])));
                let res = match out {
                    Ok(Ok(r)) => Ok(r),
                    // A closure that already classified its failure as a
                    // JobError (the engine's retry loop returning an
                    // Exhausted history) passes through unwrapped.
                    Ok(Err(e)) => Err(match e.downcast::<JobError>() {
                        Ok(je) => je,
                        Err(e) => JobError::Failed(format!("{e:#}")),
                    }),
                    Err(payload) => Err(JobError::Panicked(panic_message(payload.as_ref()))),
                };
                on_done(i, &res);
                *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(res);
            });
        }
    });

    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("every index was claimed by a worker")
        })
        .collect()
}

/// [`run_indexed_with`] without a progress callback.
pub fn run_indexed<T, R, F>(
    items: &[T],
    jobs: usize,
    f: F,
) -> Vec<std::result::Result<R, JobError>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
{
    run_indexed_with(items, jobs, f, |_, _| {})
}

// --------------------------------------------- persistent scatter pool

/// One published scatter: a type-erased item runner plus the claim /
/// completion counters.  `ctx` points into the scattering caller's
/// stack; soundness argument in [`WorkerPool::scatter`].
struct ScatterJob {
    /// Monomorphized trampoline: runs item `i` on lane `lane`, storing
    /// the result into the caller's slot.  Only called for `i < n`.
    run: unsafe fn(*const (), usize, usize),
    /// Abort trampoline: stores a `Panicked` result into slot `i`
    /// without running the closure — fired by [`ItemGuard`] when a
    /// worker thread dies between claiming an item and completing it
    /// (only possible via the lane fault-injection hook), so the
    /// scattering caller gets a typed failure instead of hanging on a
    /// `pending` count that would never reach zero.
    abort: unsafe fn(*const (), usize),
    ctx: *const (),
    next: AtomicUsize,
    n: usize,
    /// Items not yet completed; the caller returns only once this is 0.
    pending: AtomicUsize,
}

// The raw ctx pointer is only dereferenced through `run` for claimed
// item indices, all of which complete before the owning `scatter` call
// returns; see the soundness note on `scatter`.
unsafe impl Send for ScatterJob {}
unsafe impl Sync for ScatterJob {}

struct PoolState {
    job: Option<Arc<ScatterJob>>,
    /// Bumped per scatter so a worker never re-enters a job it already
    /// drained.
    generation: u64,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between scatters.
    work: Condvar,
    /// The scattering caller parks here until `pending` reaches 0.
    done: Condvar,
}

/// Trampoline for [`WorkerPool::scatter`]: recover the typed context,
/// run the user closure under `catch_unwind`, store the result.
///
/// # Safety
/// `ctx` must point at a live `(&F, &[Mutex<Option<Result<R, JobError>>>])`
/// for the duration of the call, and `i` must be in-bounds and claimed
/// exactly once.  `scatter` upholds both.
unsafe fn scatter_run_one<R, F>(ctx: *const (), lane: usize, i: usize)
where
    R: Send,
    F: Fn(usize, usize) -> Result<R> + Sync,
{
    type Slots<R> = [Mutex<Option<std::result::Result<R, JobError>>>];
    let (f, slots) = unsafe { &*(ctx as *const (&F, &Slots<R>)) };
    let out = catch_unwind(AssertUnwindSafe(|| f(lane, i)));
    let res = match out {
        Ok(Ok(r)) => Ok(r),
        Ok(Err(e)) => Err(match e.downcast::<JobError>() {
            Ok(je) => je,
            Err(e) => JobError::Failed(format!("{e:#}")),
        }),
        Err(payload) => Err(JobError::Panicked(panic_message(payload.as_ref()))),
    };
    *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(res);
}

/// Abort trampoline for [`ScatterJob::abort`]: mark item `i` failed
/// without running the closure.
///
/// # Safety
/// Same contract as [`scatter_run_one`]: `ctx` alive, `i` in-bounds and
/// claimed exactly once.
unsafe fn scatter_abort_one<R, F>(ctx: *const (), i: usize)
where
    R: Send,
    F: Fn(usize, usize) -> Result<R> + Sync,
{
    type Slots<R> = [Mutex<Option<std::result::Result<R, JobError>>>];
    let (_f, slots) = unsafe { &*(ctx as *const (&F, &Slots<R>)) };
    *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(Err(JobError::Panicked(
        "worker lane died before completing this item".to_string(),
    )));
}

/// Tracks one claimed scatter item on a worker thread.  Whatever
/// happens — normal completion, or a panic unwinding the whole worker
/// thread (the lane fault hook fires *outside* the per-item
/// `catch_unwind`) — the item's slot gets a result and `pending` is
/// decremented exactly once, so the scattering caller never hangs and
/// never reads an empty slot.
struct ItemGuard<'a> {
    shared: &'a Arc<PoolShared>,
    job: &'a Arc<ScatterJob>,
    i: usize,
    done: bool,
}

impl ItemGuard<'_> {
    fn finish(&mut self, aborted: bool) {
        if self.done {
            return;
        }
        self.done = true;
        if aborted {
            // Safety: the caller is still parked on `pending` (we have
            // not decremented yet), so ctx is alive; `i` was claimed
            // exactly once and `run` never stored a result for it.
            unsafe { (self.job.abort)(self.job.ctx, self.i) };
        }
        if self.job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last item: wake the caller.  Lock the state mutex so the
            // notify cannot slip between the caller's pending check and
            // its wait.
            let _st = lock_unpoisoned(&self.shared.state);
            self.shared.done.notify_all();
        }
    }
}

impl Drop for ItemGuard<'_> {
    fn drop(&mut self) {
        self.finish(true);
    }
}

fn worker_loop(shared: Arc<PoolShared>, lane: usize) {
    let mut seen_gen = 0u64;
    loop {
        let job: Arc<ScatterJob> = {
            let mut st = lock_unpoisoned(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                match &st.job {
                    Some(j) if st.generation != seen_gen => {
                        seen_gen = st.generation;
                        break j.clone();
                    }
                    _ => {}
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n {
                break;
            }
            let mut guard = ItemGuard {
                shared: &shared,
                job: &job,
                i,
                done: false,
            };
            // Lane fault hook, deliberately OUTSIDE the per-item
            // catch_unwind: a `lane-panic@wN` rule kills this whole
            // worker thread (the chaos scenario), and the guard above
            // converts the claimed item into a typed failure on the way
            // down.  The pool respawns the lane on its next scatter.
            let _ = crate::fault::check(crate::fault::FaultPoint::Lane { lane: lane as u64 });
            // Safety: i was claimed exactly once and is < n; the caller
            // blocks until `pending` hits 0, keeping ctx alive.
            unsafe { (job.run)(job.ctx, lane, i) };
            guard.finish(false);
        }
    }
}

/// A persistent pool of parked worker threads for repeated fine-grained
/// scatters (the step executor's micro-batch blocks).
///
/// `lanes` counts the **caller's thread too**: a pool with `lanes = 4`
/// spawns 3 workers and the scattering thread works alongside them as
/// lane 0, so `--step-jobs N` means N busy cores, not N+1.  Results come
/// back in item order; per-item panics are captured as
/// [`JobError::Panicked`].  Dropping the pool parks-then-joins every
/// worker.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// `(lane id, handle)` per spawned worker.  Behind a mutex because
    /// [`WorkerPool::respawn_dead`] replaces handles of dead lanes —
    /// a worker thread can die mid-scatter via the lane fault hook, and
    /// the pool must not permanently shrink.
    handles: Mutex<Vec<(usize, JoinHandle<()>)>>,
    lanes: usize,
    /// Serializes scatters from different threads sharing one pool (the
    /// trainer never does this, but the type stays safe if a caller
    /// does).
    dispatch: Mutex<()>,
}

fn spawn_worker(shared: &Arc<PoolShared>, lane: usize) -> JoinHandle<()> {
    let sh = shared.clone();
    std::thread::Builder::new()
        .name(format!("divebatch-step-{lane}"))
        .spawn(move || worker_loop(sh, lane))
        .expect("spawning step-pool worker")
}

impl WorkerPool {
    /// Build a pool with `lanes` total lanes (>= 1); `lanes - 1` threads
    /// are spawned, parked until the first scatter.
    pub fn new(lanes: usize) -> WorkerPool {
        let lanes = lanes.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                generation: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..lanes)
            .map(|lane| (lane, spawn_worker(&shared, lane)))
            .collect();
        WorkerPool {
            shared,
            handles: Mutex::new(handles),
            lanes,
            dispatch: Mutex::new(()),
        }
    }

    /// Total parallel lanes including the scattering caller.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Lanes currently able to run work: the caller plus every spawned
    /// worker whose thread is alive.
    pub fn live_lanes(&self) -> usize {
        1 + lock_unpoisoned(&self.handles)
            .iter()
            .filter(|(_, h)| !h.is_finished())
            .count()
    }

    /// Replace any worker whose thread has died (a lane fault-injection
    /// panic escapes the per-item catch by design).  Called at scatter
    /// start — under the dispatch lock, with no job published — so a
    /// fresh worker can never race an in-flight scatter.
    fn respawn_dead(&self) {
        let mut handles = lock_unpoisoned(&self.handles);
        for slot in handles.iter_mut() {
            if !slot.1.is_finished() {
                continue;
            }
            let fresh = spawn_worker(&self.shared, slot.0);
            let dead = std::mem::replace(&mut slot.1, fresh);
            // Reap immediately; join on a finished thread cannot block,
            // and a panicked payload is expected here.
            let _ = dead.join();
        }
    }

    /// Run `f(lane, i)` for every `i in 0..n` across the pool (the
    /// caller participates as lane 0), returning results **in item
    /// order**.  Lane ids are `< lanes()` and each lane runs at most one
    /// item at a time, so callers may keep per-lane scratch state.
    ///
    /// Soundness of the lifetime erasure: the closure and result slots
    /// live on this call's stack and are reached by workers through a
    /// raw pointer.  Every claimed item (`i < n`) finishes — and
    /// decrements `pending` — before this call observes `pending == 0`
    /// and returns; a straggler worker that wakes late only touches the
    /// job's own atomics (held alive by its `Arc`), never the caller's
    /// stack, because every index it claims is `>= n`.
    pub fn scatter<R, F>(&self, n: usize, f: F) -> Vec<std::result::Result<R, JobError>>
    where
        R: Send,
        F: Fn(usize, usize) -> Result<R> + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let _serialize = lock_unpoisoned(&self.dispatch);
        if self.lanes > 1 {
            self.respawn_dead();
        }
        let slots: Vec<Mutex<Option<std::result::Result<R, JobError>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let ctx: (&F, &[Mutex<Option<std::result::Result<R, JobError>>>]) = (&f, &slots);
        let job = Arc::new(ScatterJob {
            run: scatter_run_one::<R, F>,
            abort: scatter_abort_one::<R, F>,
            ctx: &ctx as *const _ as *const (),
            next: AtomicUsize::new(0),
            n,
            pending: AtomicUsize::new(n),
        });

        if self.lanes > 1 {
            let mut st = lock_unpoisoned(&self.shared.state);
            st.job = Some(job.clone());
            st.generation = st.generation.wrapping_add(1);
            drop(st);
            self.shared.work.notify_all();
        }

        // The caller is lane 0.
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n {
                break;
            }
            // Safety: same contract as the worker side.
            unsafe { (job.run)(job.ctx, 0, i) };
            job.pending.fetch_sub(1, Ordering::AcqRel);
        }

        if self.lanes > 1 {
            let mut st = lock_unpoisoned(&self.shared.state);
            while job.pending.load(Ordering::Acquire) > 0 {
                st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
        }
        debug_assert_eq!(job.pending.load(Ordering::Acquire), 0);

        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|e| e.into_inner())
                    .expect("every item index was claimed")
            })
            .collect()
    }
}

// ------------------------------------------------- counting semaphore

/// A counting semaphore with RAII permits (std has none; no deps).
///
/// The serve subsystem bounds its thread-per-connection model with one
/// of these: `try_acquire` either hands back a [`SemaphorePermit`] or
/// fails immediately (the server turns that into a 503 instead of
/// queueing unbounded connection threads).  Dropping the permit releases
/// the slot and wakes one blocked `acquire` waiter.
pub struct Semaphore {
    permits: Mutex<usize>,
    released: Condvar,
    capacity: usize,
}

/// RAII permit: the slot is held until this is dropped.
pub struct SemaphorePermit<'a> {
    sem: &'a Semaphore,
}

/// [`SemaphorePermit`] without the borrow: holds its semaphore by `Arc`
/// so the permit can move into a spawned thread (the serve subsystem
/// hands one to each connection thread).
pub struct OwnedSemaphorePermit {
    sem: Arc<Semaphore>,
}

impl Semaphore {
    /// A semaphore with `capacity` slots (>= 1).
    pub fn new(capacity: usize) -> Semaphore {
        let capacity = capacity.max(1);
        Semaphore {
            permits: Mutex::new(capacity),
            released: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        *lock_unpoisoned(&self.permits)
    }

    /// Take a slot if one is free; `None` means the semaphore is full.
    pub fn try_acquire(&self) -> Option<SemaphorePermit<'_>> {
        let mut n = lock_unpoisoned(&self.permits);
        if *n == 0 {
            return None;
        }
        *n -= 1;
        Some(SemaphorePermit { sem: self })
    }

    /// Block until a slot is free, then take it.
    pub fn acquire(&self) -> SemaphorePermit<'_> {
        let mut n = lock_unpoisoned(&self.permits);
        while *n == 0 {
            n = self.released.wait(n).unwrap_or_else(|e| e.into_inner());
        }
        *n -= 1;
        SemaphorePermit { sem: self }
    }

    /// [`Semaphore::try_acquire`], but the permit owns an `Arc` to the
    /// semaphore instead of borrowing it, so it can cross thread spawns.
    pub fn try_acquire_owned(self: &Arc<Self>) -> Option<OwnedSemaphorePermit> {
        let mut n = lock_unpoisoned(&self.permits);
        if *n == 0 {
            return None;
        }
        *n -= 1;
        Some(OwnedSemaphorePermit { sem: self.clone() })
    }
}

impl Drop for SemaphorePermit<'_> {
    fn drop(&mut self) {
        let mut n = lock_unpoisoned(&self.sem.permits);
        *n += 1;
        drop(n);
        self.sem.released.notify_one();
    }
}

impl Drop for OwnedSemaphorePermit {
    fn drop(&mut self) {
        let mut n = lock_unpoisoned(&self.sem.permits);
        *n += 1;
        drop(n);
        self.sem.released.notify_one();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_unpoisoned(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        let mut handles = lock_unpoisoned(&self.handles);
        for (_, h) in handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ------------------------------------------------ run_indexed core

    #[test]
    fn results_come_back_in_item_order() {
        // Work sized inversely to index so later items finish first.
        let items: Vec<u64> = (0..16).collect();
        let out = run_indexed(&items, 4, |i, &v| {
            std::thread::sleep(std::time::Duration::from_millis(16 - v));
            Ok(i as u64 * 100 + v)
        });
        let got: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        let want: Vec<u64> = (0..16).map(|v| v * 100 + v).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn jobs_level_does_not_change_results() {
        let items: Vec<u64> = (0..40).collect();
        let work = |_: usize, &v: &u64| -> Result<u64> {
            // Deterministic pseudo-work (splitmix-style scramble).
            let mut x = v.wrapping_mul(0x9E3779B97F4A7C15);
            x ^= x >> 30;
            Ok(x)
        };
        let serial: Vec<_> = run_indexed(&items, 1, work);
        for jobs in [2, 4, 8, 0] {
            assert_eq!(run_indexed(&items, jobs, work), serial, "jobs={jobs}");
        }
    }

    #[test]
    fn panics_and_errors_are_isolated_per_item() {
        let items: Vec<usize> = (0..8).collect();
        let out = run_indexed(&items, 4, |_, &v| -> Result<usize> {
            match v {
                3 => panic!("boom at {v}"),
                5 => anyhow::bail!("bad input {v}"),
                _ => Ok(v * 2),
            }
        });
        assert_eq!(out.len(), 8);
        for (i, r) in out.iter().enumerate() {
            match i {
                3 => assert_eq!(*r, Err(JobError::Panicked("boom at 3".into()))),
                5 => match r {
                    Err(JobError::Failed(m)) => assert!(m.contains("bad input 5"), "{m}"),
                    other => panic!("expected Failed, got {other:?}"),
                },
                _ => assert_eq!(*r, Ok(i * 2)),
            }
        }
    }

    #[test]
    fn completion_callback_sees_every_item_once() {
        let items: Vec<usize> = (0..10).collect();
        let seen = Mutex::new(vec![0usize; 10]);
        let _ = run_indexed_with(
            &items,
            3,
            |_, &v| Ok(v),
            |i, res| {
                assert!(res.is_ok());
                seen.lock().unwrap()[i] += 1;
            },
        );
        assert_eq!(*seen.lock().unwrap(), vec![1; 10]);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let none: Vec<u8> = Vec::new();
        assert!(run_indexed(&none, 4, |_, _| Ok(())).is_empty());
        let one = [7u8];
        let out = run_indexed(&one, 0, |_, &v| Ok(v));
        assert_eq!(out, vec![Ok(7)]);
        assert!(available_jobs() >= 1);
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
    }

    #[test]
    fn job_error_display_keeps_trial_wording() {
        // Pinned exactly: this is the user-visible sweep failure text
        // (via the engine's TrialError re-export), unchanged since PR 2.
        assert_eq!(JobError::Failed("x".into()).to_string(), "trial failed: x");
        assert_eq!(
            JobError::Panicked("y".into()).to_string(),
            "trial panicked: y"
        );
    }

    #[test]
    fn exhausted_display_lists_the_attempt_history() {
        let e = JobError::Exhausted(vec![
            JobError::Failed("io".into()),
            JobError::Panicked("boom".into()),
        ]);
        assert_eq!(
            e.to_string(),
            "trial failed after 2 attempts; attempt 1: trial failed: io; \
             attempt 2: trial panicked: boom"
        );
    }

    #[test]
    fn preclassified_job_errors_pass_through_unwrapped() {
        // A closure returning an anyhow error that *is* a JobError (the
        // engine's retry loop does this with Exhausted) must come back
        // as that JobError, not re-wrapped as Failed("trial failed ...").
        let items = [0u8];
        let history = JobError::Exhausted(vec![JobError::Failed("x".into())]);
        let h = history.clone();
        let out = run_indexed(&items, 1, move |_, _| -> Result<()> {
            Err(anyhow::Error::new(h.clone()))
        });
        assert_eq!(out, vec![Err(history)]);
    }

    #[test]
    fn step_jobs_resolution_precedence() {
        // Explicit beats everything (env is not set in-process here;
        // the env branch is covered by the CI DIVEBATCH_STEP_JOBS pass).
        assert_eq!(resolve_step_jobs(3, 8), 3);
        assert_eq!(resolve_step_jobs(1, 8), 1);
        // Fallback applies when explicit is 0 and clamps to >= 1.
        if step_jobs_from_env() == 0 {
            assert_eq!(resolve_step_jobs(0, 6), 6);
            assert_eq!(resolve_step_jobs(0, 0), 1);
        }
    }

    // ------------------------------------------------ persistent pool

    #[test]
    fn scatter_returns_results_in_item_order() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.lanes(), 4);
        let out = pool.scatter(33, |_, i| Ok(i * 3));
        let got: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, (0..33).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_is_reusable_and_matches_serial() {
        // The same pool dispatches many scatters (the per-step usage
        // pattern) and every one matches the single-lane result.
        let pool = WorkerPool::new(4);
        let serial = WorkerPool::new(1);
        let f = |_: usize, i: usize| -> Result<u64> {
            let mut x = (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
            x ^= x >> 29;
            Ok(x)
        };
        for n in [1usize, 2, 3, 7, 16, 64] {
            let a: Vec<_> = pool.scatter(n, f).into_iter().map(|r| r.unwrap()).collect();
            let b: Vec<_> = serial.scatter(n, f).into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn scatter_lane_ids_are_in_range_and_exclusive() {
        // Each lane id must only ever run one item at a time (per-lane
        // scratch safety) and stay < lanes().
        let lanes = 4;
        let pool = WorkerPool::new(lanes);
        let busy: Vec<AtomicUsize> = (0..lanes).map(|_| AtomicUsize::new(0)).collect();
        let results = pool.scatter(200, |lane, i| {
            assert!(lane < lanes, "lane {lane}");
            let was = busy[lane].fetch_add(1, Ordering::SeqCst);
            assert_eq!(was, 0, "lane {lane} ran two items concurrently");
            std::thread::sleep(std::time::Duration::from_micros(50));
            busy[lane].fetch_sub(1, Ordering::SeqCst);
            Ok(i)
        });
        assert_eq!(results.len(), 200);
        // Any in-closure assertion failure surfaces as a Panicked item.
        for (i, r) in results.into_iter().enumerate() {
            assert_eq!(r, Ok(i));
        }
    }

    #[test]
    fn scatter_captures_panics_per_item() {
        let pool = WorkerPool::new(4);
        let out = pool.scatter(8, |_, i| -> Result<usize> {
            if i == 5 {
                panic!("block {i} poisoned");
            }
            Ok(i)
        });
        for (i, r) in out.iter().enumerate() {
            if i == 5 {
                assert_eq!(*r, Err(JobError::Panicked("block 5 poisoned".into())));
            } else {
                assert_eq!(*r, Ok(i));
            }
        }
        // The pool survives the panic and keeps dispatching.
        let again = pool.scatter(4, |_, i| Ok(i + 1));
        assert!(again.into_iter().all(|r| r.is_ok()));
    }

    #[test]
    fn repeated_item_panics_never_shrink_the_pool() {
        // Satellite audit pin: per-item panics are caught inside the
        // worker loop, so lane count and bookkeeping must survive any
        // number of them — capacity loss would silently serialize every
        // later step.
        let pool = WorkerPool::new(4);
        for round in 0..10 {
            let out = pool.scatter(16, |_, i| -> Result<usize> {
                if i % 3 == 0 {
                    panic!("round {round} item {i}");
                }
                Ok(i)
            });
            assert_eq!(out.len(), 16);
            for (i, r) in out.into_iter().enumerate() {
                if i % 3 == 0 {
                    assert!(matches!(r, Err(JobError::Panicked(_))), "item {i}");
                } else {
                    assert_eq!(r, Ok(i));
                }
            }
            assert_eq!(pool.live_lanes(), 4, "after round {round}");
        }
        // And the pool still does clean work afterwards.
        let ok = pool.scatter(8, |_, i| Ok(i));
        assert!(ok.into_iter().all(|r| r.is_ok()));
    }

    #[test]
    fn scatter_empty_and_single_lane() {
        let pool = WorkerPool::new(1);
        assert!(pool.scatter(0, |_, i| Ok(i)).is_empty());
        let out = pool.scatter(5, |lane, i| {
            assert_eq!(lane, 0);
            Ok(i)
        });
        assert_eq!(out.len(), 5);
    }

    // ------------------------------------------------------ semaphore

    #[test]
    fn semaphore_try_acquire_bounds_and_releases() {
        let sem = Semaphore::new(2);
        assert_eq!(sem.capacity(), 2);
        assert_eq!(sem.available(), 2);
        let a = sem.try_acquire().expect("slot 1");
        let b = sem.try_acquire().expect("slot 2");
        assert_eq!(sem.available(), 0);
        assert!(sem.try_acquire().is_none(), "full semaphore must refuse");
        drop(a);
        assert_eq!(sem.available(), 1);
        let c = sem.try_acquire().expect("released slot is reusable");
        drop(b);
        drop(c);
        assert_eq!(sem.available(), 2);
    }

    #[test]
    fn semaphore_owned_permit_crosses_threads() {
        let sem = Arc::new(Semaphore::new(1));
        let permit = sem.try_acquire_owned().expect("slot");
        assert!(sem.try_acquire_owned().is_none(), "full must refuse");
        let handle = std::thread::spawn(move || drop(permit));
        handle.join().unwrap();
        assert_eq!(sem.available(), 1, "drop on another thread releases");
    }

    #[test]
    fn semaphore_acquire_blocks_until_release() {
        let sem = Arc::new(Semaphore::new(1));
        let held = sem.try_acquire().unwrap();
        let entered = Arc::new(AtomicUsize::new(0));
        let (s2, e2) = (sem.clone(), entered.clone());
        let waiter = std::thread::spawn(move || {
            let _p = s2.acquire();
            e2.fetch_add(1, Ordering::SeqCst);
        });
        // The waiter cannot get in while we hold the only permit.
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(entered.load(Ordering::SeqCst), 0);
        drop(held);
        waiter.join().unwrap();
        assert_eq!(entered.load(Ordering::SeqCst), 1);
        assert_eq!(sem.available(), 1);
    }

    #[test]
    fn semaphore_never_exceeds_capacity_under_contention() {
        let sem = Semaphore::new(3);
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..16 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let _p = sem.acquire();
                        let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        live.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 3);
        assert_eq!(sem.available(), 3);
    }

    #[test]
    fn drop_joins_workers() {
        // Dropping must not hang even right after a scatter.
        let pool = WorkerPool::new(8);
        let _ = pool.scatter(3, |_, i| Ok(i));
        drop(pool);
    }
}
