//! Experiment presets: every table/figure in the paper as a named bundle
//! of [`RunSpec`]s with the paper's hyperparameters (Tables 3 & 4),
//! scaled to this testbed by a [`Scale`] knob.
//!
//! | preset          | paper artifact        |
//! |-----------------|-----------------------|
//! | `fig1-convex`   | Figure 1 top row      |
//! | `fig1-nonconvex`| Figure 1 bottom row   |
//! | `fig2-convex`   | Figure 2 top row      |
//! | `fig2-nonconvex`| Figure 2 bottom row   |
//! | `fig3-cifar10`  | Figures 3/4 + Table 1 |
//! | `fig3-cifar100` | Figures 3/4 + Table 1 |
//! | `fig3-tin`      | Figures 3/4 + Table 1 |
//! | `fig3-interp8`  | Figures 3/4 structure on the committed `tinyresnet8` fixture (runs anywhere, no AOT) |
//! | `fig5-*`        | Appendix E (LR rescaling on) |
//! | `fig5-interp8`  | Appendix E structure on the `tinyresnet8` fixture |

use super::{flops_per_sample, DatasetSpec, RunSpec};
use crate::coordinator::{LrSchedule, Policy, TrainConfig};
use crate::data::{ImageSpec, SyntheticSpec};

/// Testbed scaling knobs.  `Scale::paper()` is the full configuration;
/// `Scale::quick()` is a minutes-scale smoke configuration used by the
/// examples; benches pick something between.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Epochs for the (cheap) synthetic runs.
    pub epochs: usize,
    /// Trials for the synthetic runs.
    pub trials: usize,
    /// Synthetic dataset size (paper: 20 000).
    pub n_synth: usize,
    /// Images per class for the CIFAR-like sets (cifar10; the many-class
    /// sets derive theirs, see `realworld`).
    pub per_class: usize,
    /// Epochs for the image runs (the CNN's diversity-instrumented steps
    /// cost ~10x a plain step on this 1-core CPU — see §Perf — so image
    /// budgets are scaled separately from the synthetic ones).
    pub image_epochs: usize,
    /// Trials for the image runs.
    pub image_trials: usize,
}

impl Scale {
    /// Paper-fidelity epoch/trial counts (many hours on this testbed).
    pub fn paper() -> Scale {
        Scale {
            epochs: 100,
            trials: 10,
            n_synth: 20_000,
            per_class: 500,
            image_epochs: 80,
            image_trials: 5,
        }
    }

    /// Default bench scale: preserves every qualitative shape at tens of
    /// minutes total on the 1-core testbed.
    pub fn bench() -> Scale {
        Scale {
            epochs: 36,
            trials: 2,
            n_synth: 20_000,
            per_class: 60,
            image_epochs: 18,
            image_trials: 1,
        }
    }

    /// Smoke scale for examples/CI.
    pub fn quick() -> Scale {
        Scale {
            epochs: 12,
            trials: 1,
            n_synth: 2_000,
            per_class: 20,
            image_epochs: 8,
            image_trials: 1,
        }
    }
}

/// A named experiment: a set of arms that share one figure/table.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub id: String,
    pub title: String,
    pub runs: Vec<RunSpec>,
}

fn synth(n: usize) -> DatasetSpec {
    DatasetSpec::Synthetic(SyntheticSpec {
        n,
        d: 512,
        noise: 0.1,
        seed: 1000,
    })
}

fn spec(
    model: &str,
    policy: Policy,
    schedule: LrSchedule,
    dataset: DatasetSpec,
    scale: Scale,
    momentum: f64,
    weight_decay: f64,
) -> RunSpec {
    let mut cfg = TrainConfig::new(model, policy, schedule, scale.epochs);
    cfg.momentum = momentum;
    cfg.weight_decay = weight_decay;
    if model.starts_with("logreg") || model.starts_with("mlp") {
        // §Perf L3 iteration 2: on the CPU-PJRT testbed, per-sample cost
        // of the dense train executables grows superlinearly with the
        // micro-batch (working set falls out of cache above ~512x512 f32),
        // so capping the planner at the 512 rung beats greedy-largest by
        // ~4x at m=2048+ (see perf_plan/perf_runtime benches).  On a real
        // accelerator dispatch overhead dominates and the cap would be
        // lifted.
        cfg.max_micro = Some(512);
    }
    if momentum > 0.0 {
        // Image runs: the BN-free resnet_tiny substitute uses global-norm
        // clipping for the stability BatchNorm provided in the paper's
        // ResNet-20 (DESIGN.md §3).
        cfg.clip_norm = Some(2.0);
    }
    RunSpec {
        flops_per_sample: flops_per_sample(model),
        cfg,
        dataset,
        trials: scale.trials,
    }
}

/// Figure 1 top: convex synthetic (logreg512).  Table 3 hyperparameters:
/// lr 16 at m0=128, DiveBatch delta=1, m_max=4096, decay 0.75/20,
/// lr rescaled with batch (eta/m held at eta_sgd/m_sgd).
pub fn fig1_convex(scale: Scale, with_oracle: bool) -> Experiment {
    let sched = |base: f64, rescale: bool| LrSchedule::step_075_20(base, rescale);
    let ds = || synth(scale.n_synth);
    let mut runs = vec![
        spec("logreg512", Policy::Fixed { m: 128 }, sched(16.0, false), ds(), scale, 0.0, 0.0),
        spec("logreg512", Policy::Fixed { m: 4096 }, sched(512.0, false), ds(), scale, 0.0, 0.0),
        spec(
            "logreg512",
            Policy::DiveBatch { m0: 128, delta: 1.0, m_max: 4096 },
            sched(16.0, true),
            ds(),
            scale,
            0.0,
            0.0,
        ),
    ];
    if with_oracle {
        runs.push(spec(
            "logreg512",
            Policy::Oracle { m0: 128, delta: 1.0, m_max: 4096 },
            sched(16.0, true),
            ds(),
            scale,
            0.0,
            0.0,
        ));
    }
    Experiment {
        id: "fig1-convex".into(),
        title: "Figure 1 (top): convex synthetic — logreg d=512".into(),
        runs,
    }
}

/// Figure 1 bottom: nonconvex synthetic (mlp512).  Table 3: lr 1 at
/// m0=512, DiveBatch delta=0.1, m_max=8192; fixed large batch 5028 at
/// lr 9.83 (= 1 * 5028/512).
pub fn fig1_nonconvex(scale: Scale, with_oracle: bool) -> Experiment {
    let sched = |base: f64, rescale: bool| LrSchedule::step_075_20(base, rescale);
    let ds = || synth(scale.n_synth);
    let mut runs = vec![
        spec("mlp512", Policy::Fixed { m: 512 }, sched(1.0, false), ds(), scale, 0.0, 0.0),
        spec("mlp512", Policy::Fixed { m: 5028 }, sched(9.83, false), ds(), scale, 0.0, 0.0),
        spec(
            "mlp512",
            Policy::DiveBatch { m0: 512, delta: 0.1, m_max: 8192 },
            sched(1.0, true),
            ds(),
            scale,
            0.0,
            0.0,
        ),
    ];
    if with_oracle {
        runs.push(spec(
            "mlp512",
            Policy::Oracle { m0: 512, delta: 0.1, m_max: 8192 },
            sched(1.0, true),
            ds(),
            scale,
            0.0,
            0.0,
        ));
    }
    Experiment {
        id: "fig1-nonconvex".into(),
        title: "Figure 1 (bottom): nonconvex synthetic — MLP d=512".into(),
        runs,
    }
}

/// Figures 3/4 + Table 1 arms for one image dataset.  Table 4
/// hyperparameters; `rescale_lr` selects main text (false) vs appendix E
/// (true, Figures 5/6 + Table 5).
pub fn realworld(dataset: &str, scale: Scale, rescale_lr: bool) -> Option<Experiment> {
    // (model, images, m0, m_small_lr, delta).  Batch structure (m0, m_max,
    // AdaBatch schedule, delta) follows the paper's Table 4; the base lr is
    // re-tuned for the BN-free resnet_tiny substitute (paper: 0.1/0.1/0.01
    // for BN ResNet-20 — our stable equivalents are 0.05/0.05/0.02, with
    // global-norm clipping standing in for BatchNorm; DESIGN.md §3).
    // Samples-per-class mirrors the paper's 10:1:1 ratio (CIFAR-10 has
    // 5000/class, CIFAR-100 and Tiny-ImageNet 500/class), floored so the
    // many-class sets stay learnable at testbed scale.
    let (model, images, m0, lr, delta) = match dataset {
        "cifar10" => ("resnet10", ImageSpec::cifar10_like(scale.per_class, 2000), 128, 0.05, 0.1),
        "cifar100" => (
            "resnet100",
            ImageSpec::cifar100_like((scale.per_class / 5).max(12), 3000),
            128,
            0.05,
            0.01,
        ),
        "tin" | "tiny-imagenet" => (
            "resnet200",
            ImageSpec::tiny_imagenet_like((scale.per_class / 8).max(8), 4000),
            256,
            0.02,
            0.01,
        ),
        _ => return None,
    };
    // Image runs use the image-specific budget knobs (see Scale).
    let scale = Scale {
        epochs: scale.image_epochs,
        trials: scale.image_trials,
        ..scale
    };
    let m_max = 2048;
    let ds = || DatasetSpec::Images(images.clone());
    // Image runs use momentum 0.9 + wd 5e-4 (the reference codebases).
    let (mu, wd) = (0.9, 5e-4);
    let sched = |base: f64, rescale: bool| LrSchedule::step_075_20(base, rescale);
    // SGD large-batch initial lr: scaled only in the appendix-E variant.
    let lr_large = if rescale_lr { lr * m_max as f64 / m0 as f64 } else { lr };
    let runs = vec![
        spec(model, Policy::Fixed { m: m0 }, sched(lr, false), ds(), scale, mu, wd),
        spec(model, Policy::Fixed { m: m_max }, sched(lr_large, false), ds(), scale, mu, wd),
        spec(
            model,
            Policy::AdaBatch { m0, factor: 2, every: 20, m_max },
            sched(lr, rescale_lr),
            ds(),
            scale,
            mu,
            wd,
        ),
        spec(
            model,
            Policy::DiveBatch { m0, delta, m_max },
            sched(lr, rescale_lr),
            ds(),
            scale,
            mu,
            wd,
        ),
    ];
    let variant = if rescale_lr { " (lr rescaled, appendix E)" } else { "" };
    Some(Experiment {
        id: if rescale_lr {
            format!("fig5-{dataset}")
        } else {
            format!("fig3-{dataset}")
        },
        title: format!("Figures 3/4 + Table 1: {dataset}-like{variant}"),
        runs,
    })
}

/// Figures 3/4-style CIFAR-like arms on the committed interpreter fixture
/// model (`tinyresnet8`: 8 classes, 16x16 images, two conv stages with a
/// stride-2 transition).  Same 4-arm structure as [`realworld`] — Fixed
/// small, Fixed large, AdaBatch, DiveBatch — shrunk onto the fixture's
/// (4, 8) micro-batch ladder so the full adaptive-batch conv pipeline
/// (fused blocked conv kernel included: the forward convs clear the cost
/// model's footprint/reuse bar, the weight-gradient convs stay im2col)
/// runs anywhere from `tests/fixtures/artifacts` with no jax/AOT step.
pub fn interp_cifar(scale: Scale, rescale_lr: bool) -> Experiment {
    let images = ImageSpec {
        num_classes: 8,
        per_class: scale.per_class.max(8),
        size: 16,
        noise: 0.45,
        max_shift: 2,
        seed: 5000,
    };
    let scale = Scale {
        epochs: scale.image_epochs,
        trials: scale.image_trials,
        ..scale
    };
    // The fixture ladder is (4, 8): the smallest real adaptive range.
    let (m0, m_max) = (4usize, 8usize);
    let (lr, delta) = (0.05, 0.1);
    let ds = || DatasetSpec::Images(images.clone());
    let (mu, wd) = (0.9, 5e-4);
    let sched = |base: f64, rescale: bool| LrSchedule::step_075_20(base, rescale);
    let lr_large = if rescale_lr { lr * m_max as f64 / m0 as f64 } else { lr };
    let runs = vec![
        spec("tinyresnet8", Policy::Fixed { m: m0 }, sched(lr, false), ds(), scale, mu, wd),
        spec("tinyresnet8", Policy::Fixed { m: m_max }, sched(lr_large, false), ds(), scale, mu, wd),
        spec(
            "tinyresnet8",
            Policy::AdaBatch { m0, factor: 2, every: 20, m_max },
            sched(lr, rescale_lr),
            ds(),
            scale,
            mu,
            wd,
        ),
        spec(
            "tinyresnet8",
            Policy::DiveBatch { m0, delta, m_max },
            sched(lr, rescale_lr),
            ds(),
            scale,
            mu,
            wd,
        ),
    ];
    let (id, variant) = if rescale_lr {
        ("fig5-interp8", " (lr rescaled, appendix E)")
    } else {
        ("fig3-interp8", "")
    };
    Experiment {
        id: id.into(),
        title: format!(
            "Figures 3/4 structure on the tinyresnet8 interpreter fixture{variant}"
        ),
        runs,
    }
}

/// Look up a preset by id.
pub fn preset(id: &str, scale: Scale) -> Option<Experiment> {
    match id {
        "fig1-convex" => Some(fig1_convex(scale, false)),
        "fig1-nonconvex" => Some(fig1_nonconvex(scale, false)),
        "fig2-convex" => Some(Experiment {
            id: "fig2-convex".into(),
            title: "Figure 2 (top): Oracle vs DiveBatch — convex".into(),
            runs: fig1_convex(scale, true).runs[2..].to_vec(),
        }),
        "fig2-nonconvex" => Some(Experiment {
            id: "fig2-nonconvex".into(),
            title: "Figure 2 (bottom): Oracle vs DiveBatch — nonconvex".into(),
            runs: fig1_nonconvex(scale, true).runs[2..].to_vec(),
        }),
        "fig3-cifar10" => realworld("cifar10", scale, false),
        "fig3-cifar100" => realworld("cifar100", scale, false),
        "fig3-tin" => realworld("tin", scale, false),
        "fig3-interp8" => Some(interp_cifar(scale, false)),
        "fig5-cifar10" => realworld("cifar10", scale, true),
        "fig5-cifar100" => realworld("cifar100", scale, true),
        "fig5-tin" => realworld("tin", scale, true),
        "fig5-interp8" => Some(interp_cifar(scale, true)),
        _ => None,
    }
}

/// All preset ids (for CLI listing).
pub fn preset_ids() -> Vec<&'static str> {
    vec![
        "fig1-convex",
        "fig1-nonconvex",
        "fig2-convex",
        "fig2-nonconvex",
        "fig3-cifar10",
        "fig3-cifar100",
        "fig3-tin",
        "fig3-interp8",
        "fig5-cifar10",
        "fig5-cifar100",
        "fig5-tin",
        "fig5-interp8",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DiversityNeed;

    #[test]
    fn all_presets_resolve() {
        for id in preset_ids() {
            let e = preset(id, Scale::quick()).unwrap_or_else(|| panic!("preset {id}"));
            assert!(!e.runs.is_empty(), "{id}");
            assert_eq!(e.id, *id);
        }
        assert!(preset("nope", Scale::quick()).is_none());
    }

    #[test]
    fn fig1_convex_matches_table3() {
        let e = fig1_convex(Scale::paper(), false);
        assert_eq!(e.runs.len(), 3);
        assert_eq!(e.runs[0].cfg.policy, Policy::Fixed { m: 128 });
        assert_eq!(e.runs[0].cfg.schedule.base, 16.0);
        assert_eq!(e.runs[1].cfg.policy, Policy::Fixed { m: 4096 });
        assert_eq!(e.runs[1].cfg.schedule.base, 512.0);
        assert_eq!(
            e.runs[2].cfg.policy,
            Policy::DiveBatch {
                m0: 128,
                delta: 1.0,
                m_max: 4096
            }
        );
        assert_eq!(
            e.runs[2].cfg.policy.spec(),
            "divebatch:m0=128,delta=1,mmax=4096"
        );
        assert!(e.runs[2].cfg.schedule.rescale_with_batch);
        assert_eq!(e.runs[2].cfg.schedule.decay, 0.75);
    }

    #[test]
    fn fig2_runs_are_divebatch_and_oracle() {
        let e = preset("fig2-nonconvex", Scale::quick()).unwrap();
        assert_eq!(e.runs.len(), 2);
        assert_eq!(e.runs[0].cfg.policy.diversity_need(), DiversityNeed::Estimated);
        assert_eq!(e.runs[1].cfg.policy.diversity_need(), DiversityNeed::Exact);
    }

    #[test]
    fn realworld_matches_table4() {
        let e = realworld("cifar100", Scale::paper(), false).unwrap();
        assert_eq!(e.runs.len(), 4);
        // delta = 0.01 for cifar100 (Table 4).
        assert_eq!(
            e.runs[3].cfg.policy,
            Policy::DiveBatch {
                m0: 128,
                delta: 0.01,
                m_max: 2048
            }
        );
        // momentum + wd on image runs.
        assert_eq!(e.runs[0].cfg.momentum, 0.9);
        // clipping enabled as the BN substitute on image runs.
        assert_eq!(e.runs[0].cfg.clip_norm, Some(2.0));
        // tin uses m0=256 and the substitute-tuned lr 0.02 (paper: 0.01
        // for BN ResNet-20; see the comment in realworld()).
        let t = realworld("tin", Scale::paper(), false).unwrap();
        assert_eq!(t.runs[0].cfg.policy, Policy::Fixed { m: 256 });
        assert_eq!(t.runs[0].cfg.schedule.base, 0.02);
    }

    #[test]
    fn interp_preset_runs_the_fixture_conv_model() {
        let e = preset("fig3-interp8", Scale::quick()).unwrap();
        assert_eq!(e.runs.len(), 4);
        for r in &e.runs {
            assert_eq!(r.cfg.model, "tinyresnet8");
            // 8-class 16x16 images, matching the fixture model's input.
            match &r.dataset {
                DatasetSpec::Images(s) => {
                    assert_eq!((s.num_classes, s.size), (8, 16));
                }
                other => panic!("expected an image dataset, got {other:?}"),
            }
        }
        // The adaptive arms live on the fixture's (4, 8) ladder.
        assert_eq!(
            e.runs[3].cfg.policy,
            Policy::DiveBatch { m0: 4, delta: 0.1, m_max: 8 }
        );
        assert_eq!(e.runs[0].cfg.policy, Policy::Fixed { m: 4 });
        // Appendix-E variant rescales the large-batch lr by m_max/m0.
        let f = preset("fig5-interp8", Scale::quick()).unwrap();
        assert!((f.runs[1].cfg.schedule.base - 0.05 * 2.0).abs() < 1e-12);
        assert!(f.runs[3].cfg.schedule.rescale_with_batch);
    }

    #[test]
    fn rescale_variant_scales_large_batch_lr() {
        let e = realworld("cifar10", Scale::paper(), true).unwrap();
        // SGD(2048) initial lr = (2048/128) * base (appendix E recipe).
        assert!((e.runs[1].cfg.schedule.base - 16.0 * 0.05).abs() < 1e-12);
        assert!(e.runs[3].cfg.schedule.rescale_with_batch);
    }

    #[test]
    fn scales_are_ordered() {
        assert!(Scale::quick().epochs < Scale::bench().epochs);
        assert!(Scale::bench().epochs <= Scale::paper().epochs);
    }
}
