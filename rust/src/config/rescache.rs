//! Bounded, single-writer filesystem results cache.
//!
//! PR 3 introduced plain `read`/`write` results memoization on
//! [`crate::config::RunSpec`]; that was fine for a CLI process that
//! writes a handful of entries and exits.  A long-running `divebatch
//! serve` process is different: thousands of distinct trial requests
//! would grow the directory without bound, and concurrent admission
//! batches could interleave writes.  This module makes the results cache
//! a shared service with the same shape as the executable cache
//! ([`crate::runtime::Runtime::set_exec_cache_limits`]):
//!
//! * **Eviction bounds** — entry-count and byte caps (0 = unbounded, the
//!   CLI default via [`ResultsCache::from_env`]).  After every store,
//!   least-recently-used entries (by file mtime; loads touch their entry
//!   so hits refresh recency) are removed until the bounds hold.  The
//!   just-stored entry is never evicted.
//! * **Single-writer locking** — stores serialize on the shared
//!   directory lock ([`crate::util::fslock::DirLock`]: `create_new`
//!   `.lock` file, removed on drop, stale locks from a crashed writer
//!   reclaimed via single-winner tomb rename), so two processes — or
//!   two admission batches — can never interleave a store/evict pass.
//! * **Fault hooks** — [`crate::fault::check`] guards both I/O paths:
//!   an injected load fault degrades to a counted miss, and an injected
//!   store fault is retried up to `STORE_ATTEMPTS` times (tmp file
//!   cleaned up between attempts) before surfacing.
//! * **Counters** — hit/miss/store/eviction counts, surfaced by the
//!   serve `/stats` endpoint and asserted by the cache-bound tests.
//!
//! Entries are JSON arrays of [`RunRecord`]s keyed by a caller-supplied
//! fingerprint ([`crate::config::RunSpec::fingerprint`] /
//! [`crate::engine::TrialSpec::fingerprint`]); a load only hits when the
//! entry parses and holds the expected record count, so truncated or
//! foreign files degrade to a miss, never an error.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use crate::fault::{self, FaultPoint, IoOp};
use crate::metrics::RunRecord;
use crate::util::fslock::DirLock;

/// Store attempts under injected I/O faults: a transient failure from
/// the fault layer is retried (with the tmp file cleaned up between
/// attempts) before surfacing; real I/O errors fail on first sight.
const STORE_ATTEMPTS: usize = 3;

/// Snapshot of the results cache's bound/usage counters.
#[derive(Clone, Debug, Default)]
pub struct ResultsCacheStats {
    /// Current `*.json` entries / total bytes under the directory.
    pub entries: usize,
    pub bytes: u64,
    pub hits: usize,
    pub misses: usize,
    pub stores: usize,
    pub evictions: usize,
    /// Configured caps; 0 = unbounded.
    pub max_entries: usize,
    pub max_bytes: u64,
}

/// One results-cache directory with eviction bounds and store locking.
pub struct ResultsCache {
    dir: PathBuf,
    max_entries: usize,
    max_bytes: u64,
    hits: AtomicUsize,
    misses: AtomicUsize,
    stores: AtomicUsize,
    evictions: AtomicUsize,
}

impl ResultsCache {
    /// Unbounded cache over `dir` (entries still store under the lock).
    pub fn new(dir: impl Into<PathBuf>) -> ResultsCache {
        ResultsCache::with_limits(dir, 0, 0)
    }

    /// Cache over `dir` keeping at most `max_entries` entries /
    /// `max_bytes` bytes (0 = unbounded).
    pub fn with_limits(dir: impl Into<PathBuf>, max_entries: usize, max_bytes: u64) -> ResultsCache {
        ResultsCache {
            dir: dir.into(),
            max_entries,
            max_bytes,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            stores: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// Cache over `dir` with bounds from `DIVEBATCH_RESULTS_MAX_ENTRIES`
    /// / `DIVEBATCH_RESULTS_MAX_BYTES` (unset/invalid/0 = unbounded —
    /// existing CLI and bench behaviour is unchanged unless asked for).
    pub fn from_env(dir: impl Into<PathBuf>) -> ResultsCache {
        let env_n = |k: &str| -> usize {
            std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(0)
        };
        ResultsCache::with_limits(
            dir,
            env_n("DIVEBATCH_RESULTS_MAX_ENTRIES"),
            env_n("DIVEBATCH_RESULTS_MAX_BYTES") as u64,
        )
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of `key`'s entry file.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Load `key`'s records if a valid entry with `expected` records
    /// exists.  A hit refreshes the entry's recency (mtime touch).
    pub fn load(&self, key: &str, expected: usize) -> Option<Vec<RunRecord>> {
        // An injected load fault degrades to a counted miss — the
        // caller recomputes, exactly as with a truncated entry.
        if fault::check(FaultPoint::Io { op: IoOp::Load }).is_err() {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let path = self.path_for(key);
        let recs = (|| {
            let text = std::fs::read_to_string(&path).ok()?;
            let json = crate::util::json::parse(&text).ok()?;
            let recs: Result<Vec<RunRecord>> =
                json.as_arr()?.iter().map(RunRecord::from_json).collect();
            let recs = recs.ok()?;
            (recs.len() == expected).then_some(recs)
        })();
        match recs {
            Some(recs) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // Best-effort LRU touch so eviction favours cold entries.
                if let Ok(f) = std::fs::OpenOptions::new().append(true).open(&path) {
                    let _ = f.set_modified(SystemTime::now());
                }
                Some(recs)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store `records` under `key` (atomic tmp+rename, serialized on the
    /// directory lock), then evict LRU entries down to the bounds.
    pub fn store(&self, key: &str, records: &[RunRecord]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating results cache dir {}", self.dir.display()))?;
        let _lock = DirLock::acquire(&self.dir)?;
        let path = self.path_for(key);
        let json = crate::util::json::Json::Arr(records.iter().map(|r| r.to_json()).collect());
        let text = json.to_string();
        let tmp = self.dir.join(format!(".{key}.tmp"));
        let mut last_err = None;
        for attempt in 1..=STORE_ATTEMPTS {
            match self.try_store(&tmp, &path, &text) {
                Ok(()) => {
                    self.stores.fetch_add(1, Ordering::Relaxed);
                    self.evict_over_caps(&path);
                    return Ok(());
                }
                Err(e) => {
                    // Never leave a half-written tmp file behind.
                    let _ = std::fs::remove_file(&tmp);
                    let transient = fault::is_injected(&e);
                    last_err = Some(e);
                    if !transient || attempt == STORE_ATTEMPTS {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
        Err(last_err.expect("loop ran at least once"))
    }

    /// One store attempt: fault hook, then atomic tmp+rename — a
    /// concurrent reader never observes a half-written entry (it would
    /// degrade to a miss anyway, but why risk it).
    fn try_store(&self, tmp: &Path, path: &Path, text: &str) -> Result<()> {
        fault::check(FaultPoint::Io { op: IoOp::Store }).map_err(anyhow::Error::new)?;
        std::fs::write(tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(tmp, path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    /// Remove oldest-mtime entries until the bounds hold; never removes
    /// `keep`.  Ties break on filename so eviction order is stable even
    /// on filesystems with coarse mtimes.
    fn evict_over_caps(&self, keep: &Path) {
        if self.max_entries == 0 && self.max_bytes == 0 {
            return;
        }
        let mut entries = self.scan();
        loop {
            let total: u64 = entries.iter().map(|e| e.1).sum();
            let over_entries = self.max_entries > 0 && entries.len() > self.max_entries;
            let over_bytes = self.max_bytes > 0 && total > self.max_bytes;
            if !over_entries && !over_bytes {
                return;
            }
            let Some(idx) = entries
                .iter()
                .enumerate()
                .filter(|(_, e)| e.0 != keep)
                .min_by(|(_, a), (_, b)| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)))
                .map(|(i, _)| i)
            else {
                return;
            };
            let (path, _, _) = entries.swap_remove(idx);
            if std::fs::remove_file(&path).is_ok() {
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// All `*.json` entries as (path, len, mtime).
    fn scan(&self) -> Vec<(PathBuf, u64, SystemTime)> {
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for e in rd.flatten() {
            let path = e.path();
            if path.extension().and_then(|s| s.to_str()) != Some("json") {
                continue;
            }
            if let Ok(md) = e.metadata() {
                let mtime = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
                out.push((path, md.len(), mtime));
            }
        }
        out
    }

    pub fn stats(&self) -> ResultsCacheStats {
        let entries = self.scan();
        ResultsCacheStats {
            entries: entries.len(),
            bytes: entries.iter().map(|e| e.1).sum(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            max_entries: self.max_entries,
            max_bytes: self.max_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochRecord;
    use crate::util::fslock::STALE_LOCK;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "divebatch-rescache-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn record(seed: u64, epochs: usize) -> RunRecord {
        let mut r = RunRecord::new("t", "m", "sgd", "d", seed);
        for e in 0..epochs {
            r.epochs.push(EpochRecord {
                epoch: e,
                batch_size: 8,
                lr: 0.1,
                steps: 4,
                train_loss: 1.0,
                train_acc: 0.5,
                val_loss: 1.0,
                val_acc: 0.5,
                delta_hat: None,
                n_delta: None,
                exact_delta: None,
                wall_s: 0.0,
                sim_s: 0.1,
                cum_wall_s: 0.0,
                cum_sim_s: 0.1,
                mem_mb: 1.0,
                dispatches: 1,
                pad_waste: 0.0,
                par_util: 1.0,
            });
        }
        r
    }

    #[test]
    fn store_load_roundtrip_and_counters() {
        let dir = tmpdir("roundtrip");
        let cache = ResultsCache::new(&dir);
        assert!(cache.load("k", 1).is_none());
        cache.store("k", &[record(0, 2)]).unwrap();
        let back = cache.load("k", 1).expect("stored entry loads");
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].epochs.len(), 2);
        // Wrong expected count is a miss, not an error.
        assert!(cache.load("k", 2).is_none());
        let st = cache.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 2);
        assert_eq!(st.stores, 1);
        assert_eq!(st.entries, 1);
        assert!(st.bytes > 0);
        // The lock is released after the store.
        assert!(!dir.join(".lock").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_entries_degrade_to_miss() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad.json"), "{not json").unwrap();
        let cache = ResultsCache::new(&dir);
        assert!(cache.load("bad", 1).is_none());
        assert_eq!(cache.stats().misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn entry_cap_evicts_down_to_bound_keeping_newest() {
        let dir = tmpdir("bound");
        let cache = ResultsCache::with_limits(&dir, 2, 0);
        for (i, key) in ["a", "b", "c", "d"].iter().enumerate() {
            cache.store(key, &[record(i as u64, 1)]).unwrap();
            // Distinct mtimes even on coarse-granularity filesystems.
            std::thread::sleep(Duration::from_millis(15));
        }
        let st = cache.stats();
        assert!(st.entries <= 2, "entries {} > cap 2", st.entries);
        assert!(st.evictions >= 2, "evictions {}", st.evictions);
        // The just-stored entry always survives its own store.
        assert!(cache.load("d", 1).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_cap_evicts() {
        let dir = tmpdir("bytes");
        // Far below one entry's size: every store evicts all others.
        let cache = ResultsCache::with_limits(&dir, 0, 16);
        cache.store("a", &[record(0, 1)]).unwrap();
        std::thread::sleep(Duration::from_millis(15));
        cache.store("b", &[record(1, 1)]).unwrap();
        let st = cache.stats();
        assert_eq!(st.entries, 1, "byte cap must evict older entries");
        assert!(cache.load("b", 1).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_stores_serialize_on_the_lock() {
        let dir = tmpdir("lock");
        let cache = ResultsCache::new(&dir);
        std::thread::scope(|s| {
            for t in 0..8 {
                let cache = &cache;
                s.spawn(move || {
                    for i in 0..5 {
                        cache
                            .store(&format!("k{t}-{i}"), &[record(t, 1)])
                            .unwrap();
                    }
                });
            }
        });
        assert_eq!(cache.stats().entries, 40);
        assert!(!dir.join(".lock").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_lock_is_reclaimed() {
        let dir = tmpdir("stale");
        std::fs::create_dir_all(&dir).unwrap();
        let lock = dir.join(".lock");
        std::fs::write(&lock, "").unwrap();
        // Age the lock past the stale threshold.
        let old = SystemTime::now() - (STALE_LOCK + Duration::from_secs(5));
        std::fs::OpenOptions::new()
            .append(true)
            .open(&lock)
            .unwrap()
            .set_modified(old)
            .unwrap();
        let cache = ResultsCache::new(&dir);
        cache.store("k", &[record(0, 1)]).unwrap();
        assert!(cache.load("k", 1).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
