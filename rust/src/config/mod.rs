//! Experiment configuration: dataset specs, run specs, and the presets
//! that map every paper table/figure to concrete configurations
//! (DESIGN.md §5).

pub mod presets;
pub mod rescache;

use anyhow::Result;

use crate::coordinator::TrainConfig;
use crate::data::{images, synthetic, Dataset, ImageSpec, SyntheticSpec};
use crate::metrics::RunRecord;
use crate::runtime::Runtime;
use crate::util::timer::Profiler;

/// Which dataset family a run uses.
#[derive(Clone, Debug)]
pub enum DatasetSpec {
    /// Eq. 3 synthetic (paper section 5.1); 80/20 train/val split.
    Synthetic(SyntheticSpec),
    /// Procedural images (CIFAR-like substitutes); 80/20 split.
    Images(ImageSpec),
}

impl DatasetSpec {
    /// Materialize (train, val).  `trial_seed` offsets the generator seed
    /// so each trial sees an independent draw, matching the paper's
    /// multi-trial averaging.
    pub fn build(&self, trial_seed: u64) -> (Dataset, Dataset) {
        let full = match self {
            DatasetSpec::Synthetic(s) => synthetic::generate(&SyntheticSpec {
                seed: s.seed + trial_seed,
                ..s.clone()
            }),
            DatasetSpec::Images(s) => images::generate(&ImageSpec {
                seed: s.seed + trial_seed,
                ..s.clone()
            }),
        };
        full.split(0.8)
    }

    pub fn n_total(&self) -> usize {
        match self {
            DatasetSpec::Synthetic(s) => s.n,
            DatasetSpec::Images(s) => s.n(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            DatasetSpec::Synthetic(s) => format!("synthetic-d{}", s.d),
            DatasetSpec::Images(s) => match s.num_classes {
                10 => "cifar10-like".to_string(),
                100 => "cifar100-like".to_string(),
                200 => "tiny-imagenet-like".to_string(),
                c => format!("images-{c}c"),
            },
        }
    }
}

/// One experiment arm: a training configuration over a dataset, repeated
/// for `trials` seeds.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub cfg: TrainConfig,
    pub dataset: DatasetSpec,
    pub trials: usize,
    /// fwd+bwd FLOPs per sample — feeds the simulated cluster model.
    pub flops_per_sample: f64,
}

impl RunSpec {
    /// Execute all trials serially; returns one [`RunRecord`] per trial.
    pub fn run(&self, rt: &Runtime) -> Result<Vec<RunRecord>> {
        self.run_jobs(rt, 1)
    }

    /// Execute all trials on up to `jobs` worker threads (0 = all cores)
    /// through the [`crate::engine`]; records come back in trial order
    /// and are identical to [`RunSpec::run`]'s at any jobs level (wall
    /// clock aside).  The first trial failure is reported after the
    /// whole sweep has completed (panic-isolated trials don't abort
    /// their siblings).
    pub fn run_jobs(&self, rt: &Runtime, jobs: usize) -> Result<Vec<RunRecord>> {
        let specs = crate::engine::TrialSpec::expand(self);
        let results = crate::engine::TrialRunner::new(jobs).run(rt, &specs);
        results
            .into_iter()
            .zip(&specs)
            .map(|(res, spec)| {
                res.map_err(|e| anyhow::anyhow!("{}: {e}", spec.label()))
            })
            .collect()
    }

    /// A stable fingerprint of everything that determines the run's
    /// outcome — the results-cache key.
    pub fn fingerprint(&self) -> String {
        let c = &self.cfg;
        let s = &c.schedule;
        let ds = match &self.dataset {
            DatasetSpec::Synthetic(sp) => {
                format!("syn-n{}-d{}-no{}-s{}", sp.n, sp.d, sp.noise, sp.seed)
            }
            DatasetSpec::Images(sp) => format!(
                "img-c{}-pc{}-sz{}-no{}-sh{}-s{}",
                sp.num_classes, sp.per_class, sp.size, sp.noise, sp.max_shift, sp.seed
            ),
        };
        // Extension knobs only contribute when non-default, so enabling
        // them never invalidates the cache of standard runs.
        let mut ext = if c.use_adam || c.sgld.enabled() {
            format!("|adam{}|sgld{}", c.use_adam, c.sgld.sigma)
        } else {
            String::new()
        };
        // Simulated-cluster shape feeds the sim_s columns, so scenario
        // overrides must key distinct cache entries.
        if !c.cluster.is_default() {
            ext.push_str(&format!(
                "|cw{}do{}",
                c.cluster.workers, c.cluster.div_overhead
            ));
            // Failure regimes reshape the simulated columns, so they key
            // distinct entries too — but only when actually enabled, so
            // plain non-default clusters keep their existing entries.
            if c.cluster.has_regimes() {
                ext.push_str(&format!(
                    "|rh{}sf{}sp{}pp{}fs{}",
                    c.cluster.heterogeneity,
                    c.cluster.straggler_factor,
                    c.cluster.straggler_prob,
                    c.cluster.preempt_prob,
                    c.cluster.fault_seed
                ));
            }
        }
        // v3: the policy component is the canonical registry spec
        // (PolicyHandle's Debug), not the old enum Debug format.
        let raw = format!(
            "v3|{}|{:?}|lr{}-d{}-e{}-r{}|ep{}|mu{}|wd{}|cl{:?}|mm{:?}|du{}|{}|t{}{ext}",
            c.model,
            c.policy,
            s.base,
            s.decay,
            s.every,
            s.rescale_with_batch,
            c.epochs,
            c.momentum,
            c.weight_decay,
            c.clip_norm,
            c.max_micro,
            c.device_update,
            ds,
            self.trials,
        );
        // FNV-1a over the description, rendered hex (filename-safe).
        let mut h: u64 = 0xcbf29ce484222325;
        for b in raw.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        format!("{}-{}-{h:016x}", c.model, c.policy.kind(), h = h)
    }

    /// Results-cache file for this spec under `cache_dir`.
    pub fn cache_path(&self, cache_dir: &std::path::Path) -> std::path::PathBuf {
        cache_dir.join(format!("{}.json", self.fingerprint()))
    }

    /// Cache directory for this spec's results at a given trial-engine
    /// jobs level.  Parallel trials contend for the CPU, inflating the
    /// REAL wall-clock columns of the records they produce — and a
    /// parallel step executor *deflates* them; segregating the cache
    /// under `jobs<N>[-step<M>]/` (with `M` = this spec's RESOLVED lane
    /// count: explicit `cfg.step_jobs`, else `DIVEBATCH_STEP_JOBS`, else
    /// serial) guarantees a later run in a different parallelism regime
    /// never silently reuses the wall times (the simulated columns are
    /// identical at every level).  Fully serial runs keep the base
    /// directory, so pre-existing caches stay valid.  This is the single
    /// owner of the tag derivation — the cached run paths pin
    /// `step_jobs` to the same resolution before executing, so the tag
    /// always names the regime that produced the records.
    pub fn cache_dir_for_run(&self, base: &std::path::Path, jobs: usize) -> std::path::PathBuf {
        let workers = crate::engine::effective_jobs(jobs);
        let step = crate::pool::resolve_step_jobs(self.cfg.step_jobs, 1);
        let mut tag = String::new();
        if workers > 1 {
            tag.push_str(&format!("jobs{workers}"));
        }
        if step > 1 {
            if !tag.is_empty() {
                tag.push('-');
            }
            tag.push_str(&format!("step{step}"));
        }
        if tag.is_empty() {
            base.to_path_buf()
        } else {
            base.join(tag)
        }
    }

    /// Load this spec's complete trial set from the results cache, if a
    /// valid entry exists.  (Routes through the bounded
    /// [`rescache::ResultsCache`] service — the single owner of entry
    /// format, locking, and eviction.)
    pub fn load_cached(&self, cache_dir: &std::path::Path) -> Option<Vec<RunRecord>> {
        let recs = rescache::ResultsCache::from_env(cache_dir).load(&self.fingerprint(), self.trials)?;
        eprintln!("  (cache hit: {})", self.cache_path(cache_dir).display());
        Some(recs)
    }

    /// Store a completed trial set in the results cache (atomic
    /// tmp+rename under the directory's single-writer lock; honors the
    /// `DIVEBATCH_RESULTS_MAX_ENTRIES` / `DIVEBATCH_RESULTS_MAX_BYTES`
    /// eviction bounds — unset = unbounded, the historical behaviour).
    pub fn store_cached(&self, cache_dir: &std::path::Path, records: &[RunRecord]) -> Result<()> {
        rescache::ResultsCache::from_env(cache_dir).store(&self.fingerprint(), records)
    }

    /// Like [`run`], but memoized on disk: results land in
    /// `<cache_dir>/<fingerprint>.json` and later invocations (e.g. the
    /// Table 1 bench reusing Figure 3's runs) load instead of retraining.
    pub fn run_cached(&self, rt: &Runtime, cache_dir: &std::path::Path) -> Result<Vec<RunRecord>> {
        self.run_cached_jobs(rt, cache_dir, 1)
    }

    /// [`run_cached`] with the trial engine's jobs knob (0 = all cores).
    /// Parallel results land in a jobs-segregated cache subdirectory —
    /// see [`RunSpec::cache_dir_for_run`].
    ///
    /// Cached runs PIN the step-executor lane count to
    /// explicit-`step_jobs` > `DIVEBATCH_STEP_JOBS` > serial — never the
    /// engine's pending-count-dependent auto allowance, which varies
    /// with how many trials happen to be uncached — and the cache
    /// directory is tagged with the RESOLVED lane count
    /// ([`RunSpec::cache_dir_for_run`]), so wall-clock columns measured
    /// under different lane regimes can never share one cache entry
    /// (including an explicit `cfg.step_jobs` that the fingerprint
    /// deliberately omits).
    pub fn run_cached_jobs(
        &self,
        rt: &Runtime,
        cache_dir: &std::path::Path,
        jobs: usize,
    ) -> Result<Vec<RunRecord>> {
        let mut pinned = self.clone();
        pinned.cfg.step_jobs = crate::pool::resolve_step_jobs(self.cfg.step_jobs, 1);
        let dir = pinned.cache_dir_for_run(cache_dir, jobs);
        if let Some(recs) = self.load_cached(&dir) {
            return Ok(recs);
        }
        let records = pinned.run_jobs(rt, jobs)?;
        self.store_cached(&dir, &records)?;
        Ok(records)
    }

    /// Execute one trial; returns the record and the stage profile.
    /// (Delegates to the engine's [`crate::engine::TrialSpec`] — the
    /// single definition of what a trial is.)
    pub fn run_trial(&self, rt: &Runtime, trial: u64) -> Result<(RunRecord, Profiler)> {
        crate::engine::TrialSpec::from_run(self, trial).execute_profiled(rt)
    }
}

/// Rough fwd+bwd FLOPs per sample per model family (ratios are what
/// matter for the simulated timing; see cluster/mod.rs).
pub fn flops_per_sample(model: &str) -> f64 {
    if model.starts_with("logreg") {
        3e3
    } else if model.starts_with("mlp") {
        4e5
    } else if model.starts_with("resnet") {
        3e7
    } else if model.starts_with("tiny") {
        1e3
    } else {
        1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_spec_builds_split() {
        let spec = DatasetSpec::Synthetic(SyntheticSpec {
            n: 100,
            d: 8,
            noise: 0.1,
            seed: 0,
        });
        let (tr, va) = spec.build(0);
        assert_eq!(tr.n(), 80);
        assert_eq!(va.n(), 20);
        assert_eq!(spec.n_total(), 100);
        assert_eq!(spec.label(), "synthetic-d8");
    }

    #[test]
    fn trials_use_different_data() {
        let spec = DatasetSpec::Synthetic(SyntheticSpec {
            n: 50,
            d: 4,
            noise: 0.1,
            seed: 0,
        });
        let (a, _) = spec.build(0);
        let (b, _) = spec.build(1);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn image_labels() {
        let spec = DatasetSpec::Images(ImageSpec::cifar100_like(4, 0));
        assert_eq!(spec.label(), "cifar100-like");
        assert_eq!(spec.n_total(), 400);
    }

    #[test]
    fn fingerprint_distinguishes_configs() {
        use crate::coordinator::{LrSchedule, Policy, TrainConfig};
        let base = RunSpec {
            cfg: TrainConfig::new(
                "m",
                Policy::Fixed { m: 8 },
                LrSchedule::constant(0.1, false),
                4,
            ),
            dataset: DatasetSpec::Synthetic(SyntheticSpec {
                n: 10,
                d: 4,
                noise: 0.1,
                seed: 0,
            }),
            trials: 2,
            flops_per_sample: 1.0,
        };
        let a = base.fingerprint();
        assert_eq!(a, base.fingerprint()); // stable
        let mut other = base.clone();
        other.cfg.epochs = 5;
        assert_ne!(a, other.fingerprint());
        let mut other = base.clone();
        other.trials = 3;
        assert_ne!(a, other.fingerprint());
        let mut other = base.clone();
        other.cfg.policy = Policy::Fixed { m: 16 }.into();
        assert_ne!(a, other.fingerprint());
        assert!(a.starts_with("m-sgd-"));
        // Registry-parsed and enum-built policies fingerprint identically
        // (both reduce to the canonical spec).
        let mut via_registry = base.clone();
        via_registry.cfg.policy = crate::coordinator::PolicyRegistry::builtin()
            .parse("sgd:m=8")
            .unwrap();
        assert_eq!(a, via_registry.fingerprint());
    }

    #[test]
    fn fingerprint_tracks_cluster_spec() {
        use crate::cluster::ClusterSpec;
        use crate::coordinator::{LrSchedule, Policy, TrainConfig};
        let base = RunSpec {
            cfg: TrainConfig::new(
                "m",
                Policy::Fixed { m: 8 },
                LrSchedule::constant(0.1, false),
                4,
            ),
            dataset: DatasetSpec::Synthetic(SyntheticSpec {
                n: 10,
                d: 4,
                noise: 0.1,
                seed: 0,
            }),
            trials: 1,
            flops_per_sample: 1.0,
        };
        let a = base.fingerprint();
        // The default cluster spec keeps pre-existing fingerprints valid.
        let mut explicit = base.clone();
        explicit.cfg.cluster = ClusterSpec::default();
        assert_eq!(a, explicit.fingerprint());
        // Scenario overrides key distinct cache entries.
        let mut wide = base.clone();
        wide.cfg.cluster = ClusterSpec {
            workers: 8,
            ..ClusterSpec::default()
        };
        assert_ne!(a, wide.fingerprint());
        let mut cheap = base.clone();
        cheap.cfg.cluster = ClusterSpec {
            div_overhead: 0.1,
            ..ClusterSpec::default()
        };
        assert_ne!(a, cheap.fingerprint());
        assert_ne!(wide.fingerprint(), cheap.fingerprint());
        // Failure regimes key further entries: same worker shape, but a
        // straggler schedule (or a different fault seed) must not share
        // cached sim columns with the calm cluster.
        let mut faulty = wide.clone();
        faulty.cfg.cluster.straggler_prob = 0.1;
        faulty.cfg.cluster.straggler_factor = 4.0;
        assert_ne!(wide.fingerprint(), faulty.fingerprint());
        let mut reseeded = faulty.clone();
        reseeded.cfg.cluster.fault_seed = 7;
        assert_ne!(faulty.fingerprint(), reseeded.fingerprint());
    }

    #[test]
    fn flops_table() {
        assert!(flops_per_sample("resnet10") > flops_per_sample("mlp512"));
        assert!(flops_per_sample("mlp512") > flops_per_sample("logreg512"));
    }
}
