//! Experiment configuration: dataset specs, run specs, and the presets
//! that map every paper table/figure to concrete configurations
//! (DESIGN.md §5).

pub mod presets;

use anyhow::Result;

use crate::cluster::ClusterModel;
use crate::coordinator::{TrainConfig, Trainer};
use crate::data::{images, synthetic, Dataset, ImageSpec, SyntheticSpec};
use crate::metrics::RunRecord;
use crate::runtime::Runtime;
use crate::util::timer::Profiler;

/// Which dataset family a run uses.
#[derive(Clone, Debug)]
pub enum DatasetSpec {
    /// Eq. 3 synthetic (paper section 5.1); 80/20 train/val split.
    Synthetic(SyntheticSpec),
    /// Procedural images (CIFAR-like substitutes); 80/20 split.
    Images(ImageSpec),
}

impl DatasetSpec {
    /// Materialize (train, val).  `trial_seed` offsets the generator seed
    /// so each trial sees an independent draw, matching the paper's
    /// multi-trial averaging.
    pub fn build(&self, trial_seed: u64) -> (Dataset, Dataset) {
        let full = match self {
            DatasetSpec::Synthetic(s) => synthetic::generate(&SyntheticSpec {
                seed: s.seed + trial_seed,
                ..s.clone()
            }),
            DatasetSpec::Images(s) => images::generate(&ImageSpec {
                seed: s.seed + trial_seed,
                ..s.clone()
            }),
        };
        full.split(0.8)
    }

    pub fn n_total(&self) -> usize {
        match self {
            DatasetSpec::Synthetic(s) => s.n,
            DatasetSpec::Images(s) => s.n(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            DatasetSpec::Synthetic(s) => format!("synthetic-d{}", s.d),
            DatasetSpec::Images(s) => match s.num_classes {
                10 => "cifar10-like".to_string(),
                100 => "cifar100-like".to_string(),
                200 => "tiny-imagenet-like".to_string(),
                c => format!("images-{c}c"),
            },
        }
    }
}

/// One experiment arm: a training configuration over a dataset, repeated
/// for `trials` seeds.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub cfg: TrainConfig,
    pub dataset: DatasetSpec,
    pub trials: usize,
    /// fwd+bwd FLOPs per sample — feeds the simulated cluster model.
    pub flops_per_sample: f64,
}

impl RunSpec {
    /// Execute all trials; returns one [`RunRecord`] per trial.
    pub fn run(&self, rt: &Runtime) -> Result<Vec<RunRecord>> {
        let mut records = Vec::with_capacity(self.trials);
        for trial in 0..self.trials {
            let (rec, _) = self.run_trial(rt, trial as u64)?;
            records.push(rec);
        }
        Ok(records)
    }

    /// A stable fingerprint of everything that determines the run's
    /// outcome — the results-cache key.
    pub fn fingerprint(&self) -> String {
        let c = &self.cfg;
        let s = &c.schedule;
        let ds = match &self.dataset {
            DatasetSpec::Synthetic(sp) => {
                format!("syn-n{}-d{}-no{}-s{}", sp.n, sp.d, sp.noise, sp.seed)
            }
            DatasetSpec::Images(sp) => format!(
                "img-c{}-pc{}-sz{}-no{}-sh{}-s{}",
                sp.num_classes, sp.per_class, sp.size, sp.noise, sp.max_shift, sp.seed
            ),
        };
        // Extension knobs only contribute when non-default, so enabling
        // them never invalidates the cache of standard runs.
        let ext = if c.use_adam || c.sgld.enabled() {
            format!("|adam{}|sgld{}", c.use_adam, c.sgld.sigma)
        } else {
            String::new()
        };
        // v3: the policy component is the canonical registry spec
        // (PolicyHandle's Debug), not the old enum Debug format.
        let raw = format!(
            "v3|{}|{:?}|lr{}-d{}-e{}-r{}|ep{}|mu{}|wd{}|cl{:?}|mm{:?}|du{}|{}|t{}{ext}",
            c.model,
            c.policy,
            s.base,
            s.decay,
            s.every,
            s.rescale_with_batch,
            c.epochs,
            c.momentum,
            c.weight_decay,
            c.clip_norm,
            c.max_micro,
            c.device_update,
            ds,
            self.trials,
        );
        // FNV-1a over the description, rendered hex (filename-safe).
        let mut h: u64 = 0xcbf29ce484222325;
        for b in raw.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        format!("{}-{}-{h:016x}", c.model, c.policy.kind(), h = h)
    }

    /// Like [`run`], but memoized on disk: results land in
    /// `<cache_dir>/<fingerprint>.json` and later invocations (e.g. the
    /// Table 1 bench reusing Figure 3's runs) load instead of retraining.
    pub fn run_cached(&self, rt: &Runtime, cache_dir: &std::path::Path) -> Result<Vec<RunRecord>> {
        let path = cache_dir.join(format!("{}.json", self.fingerprint()));
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(json) = crate::util::json::parse(&text) {
                if let Some(arr) = json.as_arr() {
                    let recs: Result<Vec<RunRecord>> =
                        arr.iter().map(RunRecord::from_json).collect();
                    if let Ok(recs) = recs {
                        if recs.len() == self.trials {
                            eprintln!("  (cache hit: {})", path.display());
                            return Ok(recs);
                        }
                    }
                }
            }
        }
        let records = self.run(rt)?;
        std::fs::create_dir_all(cache_dir)?;
        let json = crate::util::json::Json::Arr(records.iter().map(|r| r.to_json()).collect());
        std::fs::write(&path, json.to_string())?;
        Ok(records)
    }

    /// Execute one trial; returns the record and the stage profile.
    pub fn run_trial(&self, rt: &Runtime, trial: u64) -> Result<(RunRecord, Profiler)> {
        let (train, val) = self.dataset.build(trial);
        let info = rt.model(&self.cfg.model)?;
        let cluster = ClusterModel::a100x4(info.param_count, self.flops_per_sample);
        let mut cfg = self.cfg.clone();
        cfg.seed = trial;
        let trainer = Trainer::new(rt, cfg, train, val, cluster)?;
        let out = trainer.run()?;
        Ok((out.record, out.profile))
    }
}

/// Rough fwd+bwd FLOPs per sample per model family (ratios are what
/// matter for the simulated timing; see cluster/mod.rs).
pub fn flops_per_sample(model: &str) -> f64 {
    if model.starts_with("logreg") {
        3e3
    } else if model.starts_with("mlp") {
        4e5
    } else if model.starts_with("resnet") {
        3e7
    } else if model.starts_with("tiny") {
        1e3
    } else {
        1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_spec_builds_split() {
        let spec = DatasetSpec::Synthetic(SyntheticSpec {
            n: 100,
            d: 8,
            noise: 0.1,
            seed: 0,
        });
        let (tr, va) = spec.build(0);
        assert_eq!(tr.n(), 80);
        assert_eq!(va.n(), 20);
        assert_eq!(spec.n_total(), 100);
        assert_eq!(spec.label(), "synthetic-d8");
    }

    #[test]
    fn trials_use_different_data() {
        let spec = DatasetSpec::Synthetic(SyntheticSpec {
            n: 50,
            d: 4,
            noise: 0.1,
            seed: 0,
        });
        let (a, _) = spec.build(0);
        let (b, _) = spec.build(1);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn image_labels() {
        let spec = DatasetSpec::Images(ImageSpec::cifar100_like(4, 0));
        assert_eq!(spec.label(), "cifar100-like");
        assert_eq!(spec.n_total(), 400);
    }

    #[test]
    fn fingerprint_distinguishes_configs() {
        use crate::coordinator::{LrSchedule, Policy, TrainConfig};
        let base = RunSpec {
            cfg: TrainConfig::new(
                "m",
                Policy::Fixed { m: 8 },
                LrSchedule::constant(0.1, false),
                4,
            ),
            dataset: DatasetSpec::Synthetic(SyntheticSpec {
                n: 10,
                d: 4,
                noise: 0.1,
                seed: 0,
            }),
            trials: 2,
            flops_per_sample: 1.0,
        };
        let a = base.fingerprint();
        assert_eq!(a, base.fingerprint()); // stable
        let mut other = base.clone();
        other.cfg.epochs = 5;
        assert_ne!(a, other.fingerprint());
        let mut other = base.clone();
        other.trials = 3;
        assert_ne!(a, other.fingerprint());
        let mut other = base.clone();
        other.cfg.policy = Policy::Fixed { m: 16 }.into();
        assert_ne!(a, other.fingerprint());
        assert!(a.starts_with("m-sgd-"));
        // Registry-parsed and enum-built policies fingerprint identically
        // (both reduce to the canonical spec).
        let mut via_registry = base.clone();
        via_registry.cfg.policy = crate::coordinator::PolicyRegistry::builtin()
            .parse("sgd:m=8")
            .unwrap();
        assert_eq!(a, via_registry.fingerprint());
    }

    #[test]
    fn flops_table() {
        assert!(flops_per_sample("resnet10") > flops_per_sample("mlp512"));
        assert!(flops_per_sample("mlp512") > flops_per_sample("logreg512"));
    }
}
