//! Bench: **P4 (§Perf)** — compiled interpreter vs the retained tree-walk
//! reference evaluator, on the committed tinylogreg8 fixtures.
//!
//! This is the PR-4 accountability bench: it times every fixture entry
//! (train plain + diversity across the batch ladder, eval ladder, fused
//! update) through BOTH execution paths of the same compiled object —
//! [`xla::PjRtLoadedExecutable::execute`] (register program, buffer
//! arena) and [`xla::PjRtLoadedExecutable::execute_reference`] (the
//! pre-PR evaluator) — and writes `BENCH_4.json` at the repo root:
//!
//! ```text
//! entries.<key>.ns_per_step      compiled path, median ns per execution
//!                                (median-of-N, N >= 20 after 5 warm-up
//!                                iterations — robust to runner hiccups)
//! entries.<key>.steps_per_sec    1e9 / ns_per_step
//! entries.<key>.ref_ns_per_step  reference path, same inputs, same run
//! entries.<key>.speedup          ref / compiled
//! entries.<key>.allocs_proxy     arena allocations observed during the
//!                                timed loop (arenas created + buffers
//!                                grown; steady state must be 0)
//! ```
//!
//! Target: `train_div_b8` speedup >= 5x (the ISSUE-4 acceptance bar).
//! The committed BENCH_4.json is the regression baseline: CI's perf-smoke
//! step re-runs this bench and fails if any entry's `speedup` drops below
//! half its committed value (python/mirror/check_bench.py — the speedup
//! is measured against the reference path in the same process, so the
//! gate is machine-invariant; raw ns_per_step is recorded for humans).
//! To re-bless after an intentional change, run the bench and commit the
//! refreshed BENCH_4.json.
//!
//! Env knobs: `BENCH_OUT` overrides the output path;
//! `DIVEBATCH_PERF_ENFORCE=1` makes the process exit non-zero when the
//! train_div_b8 target is missed (CI sets it).
//!
//! Run: `cargo bench --bench perf_interp`

use divebatch::bench::{bench_header, fmt_time, Bencher};
use divebatch::runtime::{Dtype, Manifest, TensorSpec};
use divebatch::util::json::Json;
use divebatch::util::rng::Rng;

const TARGET_SPEEDUP: f64 = 5.0;

fn fixtures_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/artifacts").to_string()
}

fn default_out() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_4.json").to_string()
}

fn input_literal(spec: &TensorSpec, rng: &mut Rng) -> xla::Literal {
    let n = spec.elements();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        Dtype::S32 => {
            let v: Vec<i32> = (0..n).map(|_| rng.range(0, 2) as i32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "perf_interp",
        "P4: compiled register-program interpreter vs the retained reference \
         evaluator (tinylogreg8 fixtures); writes BENCH_4.json",
    );
    let manifest = Manifest::load(fixtures_dir())?;
    let model = manifest.model("tinylogreg8")?.clone();
    let client = xla::PjRtClient::interp();
    let b = Bencher {
        warmup_iters: 5,
        min_iters: 20,
        max_iters: 20_000,
        target_s: 0.5,
    };

    let mut entries: Vec<(&str, Json)> = Vec::new();
    let mut div_b8_speedup = None;
    println!(
        "{:<16} {:>14} {:>14} {:>9} {:>13}",
        "entry", "compiled", "reference", "speedup", "allocs-proxy"
    );
    for (key, info) in &model.entries {
        let path = manifest.path(&info.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let mut rng = Rng::new(0xBE7C);
        let inputs: Vec<xla::Literal> = info
            .inputs
            .iter()
            .map(|spec| input_literal(spec, &mut rng))
            .collect();

        // Warm the arena before counting, so the proxy measures steady
        // state (the first call legitimately builds one arena).
        exe.execute(&inputs)?;
        let (created0, grown0) = exe.interp_arena_stats().unwrap();
        let compiled = b.run(&format!("{key} compiled"), None, || {
            exe.execute(&inputs).unwrap();
        });
        let (created1, grown1) = exe.interp_arena_stats().unwrap();
        let allocs_proxy = (created1 - created0) + (grown1 - grown0);
        let reference = b.run(&format!("{key} reference"), None, || {
            exe.execute_reference(&inputs).unwrap();
        });

        let ns = compiled.median_s * 1e9;
        let ref_ns = reference.median_s * 1e9;
        let speedup = ref_ns / ns;
        if key == "train_div_b8" {
            div_b8_speedup = Some(speedup);
        }
        println!(
            "{key:<16} {:>14} {:>14} {:>8.1}x {:>13}",
            fmt_time(compiled.median_s),
            fmt_time(reference.median_s),
            speedup,
            allocs_proxy
        );
        entries.push((
            key.as_str(),
            Json::obj(vec![
                ("ns_per_step", Json::Num(ns)),
                ("steps_per_sec", Json::Num(1e9 / ns)),
                ("ref_ns_per_step", Json::Num(ref_ns)),
                ("speedup", Json::Num(speedup)),
                ("allocs_proxy", Json::Num(allocs_proxy as f64)),
            ]),
        ));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_interp".into())),
        ("model", Json::Str("tinylogreg8".into())),
        ("target_speedup_train_div_b8", Json::Num(TARGET_SPEEDUP)),
        ("entries", Json::obj(entries)),
    ]);
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| default_out());
    std::fs::write(&out_path, doc.to_string())?;
    println!();
    println!("wrote {out_path}");

    let speedup = div_b8_speedup.expect("train_div_b8 entry present in fixtures");
    if speedup < TARGET_SPEEDUP {
        eprintln!(
            "WARNING: train_div_b8 speedup {speedup:.1}x is below the {TARGET_SPEEDUP}x \
             target (ISSUE-4 acceptance bar)"
        );
        if std::env::var("DIVEBATCH_PERF_ENFORCE").is_ok_and(|v| v == "1") {
            std::process::exit(1);
        }
    } else {
        println!("train_div_b8 speedup {speedup:.1}x (target {TARGET_SPEEDUP}x) — OK");
    }
    Ok(())
}
