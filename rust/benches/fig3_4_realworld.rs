//! Bench: **Figures 3 & 4** — validation accuracy and loss curves on the
//! three CIFAR-like image datasets for SGD(small), SGD(large), AdaBatch,
//! DiveBatch (main-text variant: no lr rescaling).
//!
//! Run: `cargo bench --bench fig3_4_realworld`
//! Env: DIVEBATCH_SCALE=quick|bench|paper, DIVEBATCH_DATASETS=cifar10,...,
//! DIVEBATCH_JOBS=N trial-engine workers (unset/0 = all cores)

use divebatch::bench::{bench_header, run_experiment};
use divebatch::config::presets::{realworld, Scale};
use divebatch::runtime::Runtime;

fn scale_from_env() -> Scale {
    match std::env::var("DIVEBATCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::bench(),
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "fig3_4_realworld",
        "Figures 3/4: CIFAR-like image runs — val accuracy + loss curves for \
         SGD small/large, AdaBatch, DiveBatch (no lr rescaling; section 5.2)",
    );
    let scale = scale_from_env();
    let datasets = std::env::var("DIVEBATCH_DATASETS")
        .unwrap_or_else(|_| "cifar10,cifar100,tin".into());
    let rt = Runtime::load_default()?;

    for ds in datasets.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let exp = realworld(ds, scale, false).expect("dataset id");
        println!("--- {} ---", exp.title);
        let res = run_experiment(&rt, &exp, false)?;
        println!("{}", res.acc_figure(76, 16)); // Figure 3 panel
        println!("{}", res.loss_figure(76, 16)); // Figure 4 panel
        println!("{}", res.table1().render());

        // Paper-shape summary: DiveBatch leads at 25%, SGD-small best final.
        if let (Some(dive), Some(ada)) = (res.arm("DiveBatch"), res.arm("AdaBatch")) {
            let d25 = divebatch::util::stats::mean(&dive.acc_at(0.25));
            let a25 = divebatch::util::stats::mean(&ada.acc_at(0.25));
            println!(
                "shape check @25%: DiveBatch {:.2}% vs AdaBatch {:.2}% (paper: DiveBatch highest early)\n",
                d25, a25
            );
        }
    }
    Ok(())
}
