//! Bench: **Table 1** — validation accuracy at 25/50/75/100% of training
//! plus time to within ±1% of final accuracy, per dataset x algorithm,
//! including the headline "DiveBatch is 1.06-5x faster" speedup factors.
//!
//! Run: `cargo bench --bench table1_time_to_acc`
//! Env: DIVEBATCH_SCALE, DIVEBATCH_DATASETS (default all three),
//! DIVEBATCH_JOBS (trial-engine workers; set 1 for clean wall-clock
//! columns — sim(s) is jobs-invariant either way).

use divebatch::bench::{bench_header, run_experiment};
use divebatch::config::presets::{realworld, Scale};
use divebatch::runtime::Runtime;

fn scale_from_env() -> Scale {
    match std::env::var("DIVEBATCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::bench(),
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "table1_time_to_acc",
        "Table 1: accuracy milestones + time to ±1% of final accuracy \
         (simulated 4-worker cluster seconds AND real wall-clock)",
    );
    let scale = scale_from_env();
    let datasets =
        std::env::var("DIVEBATCH_DATASETS").unwrap_or_else(|_| "cifar10,cifar100,tin".into());
    let rt = Runtime::load_default()?;

    for ds in datasets.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let exp = realworld(ds, scale, false).expect("dataset id");
        println!("--- {} ---", exp.title);
        let res = run_experiment(&rt, &exp, false)?;
        println!("{}", res.table1().render());
        println!("{}", res.speedup_rows().render());
    }
    println!(
        "paper headline: DiveBatch reaches ±1% of final acc 1.06-5x faster than \
         small-batch SGD and AdaBatch (2x AdaBatch / 5x SGD on CIFAR-10)."
    );
    Ok(())
}
