//! Bench: **P5 (§Perf)** — sharded step execution: `--step-jobs` lanes
//! vs the serial loop, on the committed steplogreg8 fixtures.
//!
//! This is the PR-5 accountability bench.  It measures the step
//! executor exactly as the trainer drives it — per-lane gather into a
//! reused buffer, `train_div_b64` execution per block, then the
//! deterministic block-order fold — for one logical batch decomposed
//! into 64-row blocks, at 1 lane and at 4 lanes, and writes
//! `BENCH_5.json` at the repo root:
//!
//! ```text
//! entries.train_div_b64.ns_per_step         4-lane time per logical batch
//!                                           (median-of-N, N >= 30 after
//!                                           5 warm-up iterations)
//! entries.train_div_b64.ns_per_step_serial  1-lane time, same work
//! entries.train_div_b64.speedup             serial / parallel
//! entries.trainer_epoch.*                   same, end-to-end Trainer::run
//! ```
//!
//! Target: `train_div_b64` speedup >= 2x at 4 lanes (the ISSUE-5
//! acceptance bar).  The committed BENCH_5.json is the regression
//! baseline: CI re-runs this bench and compares each entry's speedup via
//! python/mirror/check_bench.py (fail on >2x regression) — the ratio is
//! machine-invariant, unlike raw ns/step.
//!
//! Measured vs simulated, side by side: the run's wall-clock speedup is
//! printed next to the prediction of the simulated-cluster cost model
//! calibrated to this machine's measured per-sample cost
//! ([`ClusterSpec::local`] — a `--step-jobs N` testbed IS an N-worker
//! synchronous data-parallel cluster), so the paper's simulated columns
//! and our measured columns can finally be read against each other.
//!
//! Env knobs: `BENCH_OUT` overrides the output path;
//! `DIVEBATCH_PERF_ENFORCE=1` makes the process exit non-zero when the
//! 2x target is missed (CI sets it).
//!
//! Run: `cargo bench --bench perf_step`

use std::sync::Mutex;

use divebatch::bench::{bench_header, fmt_time, Bencher};
use divebatch::cluster::{ClusterModel, ClusterSpec};
use divebatch::coordinator::{LrSchedule, MicroPlan, Policy, StepExecutor, TrainConfig, Trainer};
use divebatch::data::{synthetic, SyntheticSpec};
use divebatch::runtime::ExecCache;
use divebatch::util::json::Json;
use divebatch::{Batch, Runtime};

const MODEL: &str = "steplogreg8";
const TARGET_SPEEDUP: f64 = 2.0;
const LANES: usize = 4;
/// Logical batch for the raw step measurement: 64 blocks of 64 rows.
const LOGICAL_M: usize = 4096;

fn fixtures_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/artifacts").to_string()
}

fn default_out() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_5.json").to_string()
}

struct Lane {
    buf: Batch,
    execs: ExecCache,
}

/// The trainer-shaped step workload: scatter the plan's blocks (gather
/// + train_div execute per block) and fold the outputs in block order.
struct StepWork<'a> {
    rt: &'a Runtime,
    ds: &'a divebatch::Dataset,
    params: &'a [f32],
    indices: &'a [u32],
    spans: &'a [(usize, divebatch::coordinator::MicroBlock)],
}

impl StepWork<'_> {
    /// Run one logical-batch step; returns the folded loss so the work
    /// cannot be optimized away.
    fn run(&self, step: &StepExecutor, lanes: &[Mutex<Lane>], grad: &mut [f32]) -> f64 {
        let outs = step
            .run_blocks(self.spans.len(), |lane, bi| {
                let (off, block) = self.spans[bi];
                let mut s = lanes[lane].lock().unwrap();
                self.ds
                    .gather_into(&self.indices[off..off + block.take], block.micro, &mut s.buf);
                let exec = s.execs.train(self.rt, MODEL, true, block.micro)?;
                exec.run_train(self.params, &s.buf)
            })
            .expect("bench step failed");
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut loss = 0.0;
        for out in &outs {
            for (a, g) in grad.iter_mut().zip(&out.grad_sum) {
                *a += g;
            }
            loss += out.loss_sum;
        }
        loss
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "perf_step",
        "P5: sharded step executor (train_div_b64 blocks, 4 lanes vs serial) \
         on the steplogreg8 fixtures; writes BENCH_5.json",
    );
    let rt = Runtime::load(fixtures_dir())?;
    let info = rt.model(MODEL)?.clone();
    rt.warmup(MODEL)?;
    let params = rt.manifest.load_init_params(MODEL, 0)?;
    let ds = synthetic::generate(&SyntheticSpec {
        n: LOGICAL_M,
        d: 8,
        noise: 0.05,
        seed: 9,
    });

    // ---- raw sharded step: one logical batch of LOGICAL_M rows ----
    let indices: Vec<u32> = (0..LOGICAL_M as u32).collect();
    let plan = MicroPlan::build(LOGICAL_M, &info.ladder, None);
    let mut spans = Vec::with_capacity(plan.blocks.len());
    let mut off = 0usize;
    for b in &plan.blocks {
        spans.push((off, *b));
        off += b.take;
    }
    println!(
        "logical batch {LOGICAL_M} -> {} blocks (ladder {:?}), plan utilization at {LANES} lanes: {:.2}",
        plan.dispatches(),
        info.ladder,
        plan.utilization(LANES)
    );

    let b = Bencher {
        warmup_iters: 5,
        min_iters: 30,
        max_iters: 20_000,
        target_s: 1.0,
    };
    let mut grad = vec![0.0f32; info.param_count];
    let mut results: Vec<(&str, f64, f64)> = Vec::new(); // (entry, serial_ns, par_ns)

    let mk_lanes = |k: usize| -> Vec<Mutex<Lane>> {
        (0..k)
            .map(|_| {
                Mutex::new(Lane {
                    buf: Batch::empty(),
                    execs: ExecCache::new(),
                })
            })
            .collect()
    };

    let work = StepWork {
        rt: &rt,
        ds: &ds,
        params: &params,
        indices: &indices,
        spans: &spans,
    };
    let serial_exec = StepExecutor::new(1);
    let serial_lanes = mk_lanes(1);
    let serial = b.run("train_div_b64 serial", Some(LOGICAL_M as f64), || {
        work.run(&serial_exec, &serial_lanes, &mut grad);
    });
    let par_exec = StepExecutor::new(LANES);
    let par_lanes = mk_lanes(LANES);
    let par = b.run(&format!("train_div_b64 x{LANES}"), Some(LOGICAL_M as f64), || {
        work.run(&par_exec, &par_lanes, &mut grad);
    });
    println!("  {}", serial.line());
    println!("  {}", par.line());
    results.push(("train_div_b64", serial.median_s * 1e9, par.median_s * 1e9));

    // Measured vs simulated, side by side: calibrate the cluster cost
    // model to this machine's measured per-sample cost and compare its
    // predicted step-time ratio with the measured one.
    let per_sample_s = serial.median_s / LOGICAL_M as f64;
    let sim1 = ClusterModel::calibrated(1, per_sample_s, info.param_count)
        .step_time(LOGICAL_M, true);
    let sim4 = ClusterModel::calibrated(LANES, per_sample_s, info.param_count)
        .step_time(LOGICAL_M, true);
    println!();
    println!(
        "step time, measured vs simulated ({} workers = ClusterSpec::local({LANES})):",
        LANES
    );
    println!(
        "  measured:  {:>12} -> {:>12}   speedup {:.2}x",
        fmt_time(serial.median_s),
        fmt_time(par.median_s),
        serial.median_s / par.median_s
    );
    println!(
        "  simulated: {:>12} -> {:>12}   speedup {:.2}x",
        fmt_time(sim1),
        fmt_time(sim4),
        sim1 / sim4
    );

    // ---- end-to-end: Trainer::run wall clock at step-jobs 1 vs 4 ----
    let eb = Bencher {
        warmup_iters: 1,
        min_iters: 5,
        max_iters: 200,
        target_s: 1.5,
    };
    let mut epoch_ns = [0.0f64; 2];
    for (slot, lanes) in [(0usize, 1usize), (1, LANES)] {
        let mut cfg = TrainConfig::new(
            MODEL,
            Policy::Fixed { m: 2048 },
            LrSchedule::constant(0.1, false),
            2,
        );
        cfg.step_jobs = lanes;
        let (train, val) = ds.split(0.8);
        let trainer = Trainer::new(
            &rt,
            cfg,
            train,
            val,
            ClusterSpec::local(lanes).model(info.param_count, 1e3),
        )?;
        let r = eb.run(&format!("trainer 2 epochs, step-jobs {lanes}"), None, || {
            trainer.run().expect("bench trainer run failed");
        });
        println!("  {}", r.line());
        epoch_ns[slot] = r.median_s * 1e9;
    }
    results.push(("trainer_epoch", epoch_ns[0], epoch_ns[1]));

    // ---- BENCH_5.json ----
    let entries: Vec<(&str, Json)> = results
        .iter()
        .map(|&(key, serial_ns, par_ns)| {
            (
                key,
                Json::obj(vec![
                    ("ns_per_step", Json::Num(par_ns)),
                    ("ns_per_step_serial", Json::Num(serial_ns)),
                    ("speedup", Json::Num(serial_ns / par_ns)),
                ]),
            )
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_step".into())),
        ("model", Json::Str(MODEL.into())),
        ("lanes", Json::Num(LANES as f64)),
        ("target_speedup_train_div_b64", Json::Num(TARGET_SPEEDUP)),
        ("entries", Json::obj(entries)),
    ]);
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| default_out());
    std::fs::write(&out_path, doc.to_string())?;
    println!();
    println!("wrote {out_path}");

    let speedup = results[0].1 / results[0].2;
    if speedup < TARGET_SPEEDUP {
        eprintln!(
            "WARNING: train_div_b64 step speedup {speedup:.2}x at {LANES} lanes is below \
             the {TARGET_SPEEDUP}x target (ISSUE-5 acceptance bar)"
        );
        if std::env::var("DIVEBATCH_PERF_ENFORCE").is_ok_and(|v| v == "1") {
            std::process::exit(1);
        }
    } else {
        println!("train_div_b64 step speedup {speedup:.2}x (target {TARGET_SPEEDUP}x) — OK");
    }
    Ok(())
}
