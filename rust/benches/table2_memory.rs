//! Bench: **Table 2** — peak training memory per algorithm on the
//! CIFAR-10-like workload.
//!
//! Reports three views:
//!  * the analytic per-step model at each algorithm's batch trajectory
//!    in the paper's BackPACK regime (per-sample grads materialized,
//!    `m x P` — reproduces Table 2's ordering), averaged over epochs;
//!  * the same model under this repo's chunked design (`chunk x P`);
//!  * measured process RSS high-water mark while actually running a few
//!    epochs of each algorithm through PJRT.
//!
//! Run: `cargo bench --bench table2_memory`

use divebatch::bench::bench_header;
use divebatch::config::presets::{realworld, Scale};
use divebatch::metrics::{peak_rss_mb, MemMode, MemoryModel};
use divebatch::runtime::Runtime;
use divebatch::util::stats;
use divebatch::util::table::Table;

fn main() -> anyhow::Result<()> {
    bench_header(
        "table2_memory",
        "Table 2: average peak training-memory per algorithm (CIFAR-10-like). \
         Analytic model in the paper's BackPACK regime + our chunked design + measured RSS.",
    );
    let scale = match std::env::var("DIVEBATCH_SCALE").as_deref() {
        Ok("paper") => Scale::paper(),
        Ok("bench") => Scale::bench(),
        _ => Scale::quick(), // memory doesn't need many epochs
    };
    let rt = Runtime::load_default()?;
    let exp = realworld("cifar10", scale, false).unwrap();

    let mut table = Table::new(
        "Table 2 (per-epoch average peak memory, MB)",
        &[
            "Algorithm",
            "paper-regime (m x P)",
            "ours (chunk x P)",
            "measured ΔRSS (MB)",
        ],
    );

    for run in &exp.runs {
        let info = rt.model(&run.cfg.model)?;
        let mm = MemoryModel::for_model(
            info.param_count,
            info.feat_len(),
            info.input_shape.len(),
            info.chunk,
        );
        let instrumented = run.cfg.policy.kind() == "divebatch";
        let rss_before = peak_rss_mb().unwrap_or(0.0);
        // Deliberately serial (engine jobs = 1): the measured ΔRSS column
        // attributes the high-water mark to ONE algorithm at a time, which
        // concurrent trials would conflate.
        let records = run.run(&rt)?;
        let rss_after = peak_rss_mb().unwrap_or(0.0);

        // Batch trajectory from the actual run -> analytic averages.
        let batches: Vec<usize> = records[0].epochs.iter().map(|e| e.batch_size).collect();
        let naive: Vec<f64> = batches
            .iter()
            .map(|&m| {
                mm.step_mb(
                    m,
                    if instrumented {
                        MemMode::DivNaive
                    } else {
                        MemMode::Plain
                    },
                )
            })
            .collect();
        let chunked: Vec<f64> = batches
            .iter()
            .map(|&m| {
                mm.step_mb(
                    m,
                    if instrumented {
                        MemMode::DivChunked
                    } else {
                        MemMode::Plain
                    },
                )
            })
            .collect();
        table.row(vec![
            records[0].label.clone(),
            format!("{:.2}", stats::mean(&naive)),
            format!("{:.2}", stats::mean(&chunked)),
            format!("{:.1}", (rss_after - rss_before).max(0.0)),
        ]);
        eprintln!("  done: {}", records[0].label);
    }
    println!("{}", table.render());
    println!(
        "paper Table 2 (ResNet-20 / real CIFAR-10, MB): SGD(128) 717, SGD(2048) 9565, \
         AdaBatch 6751, DiveBatch 13164 — DiveBatch most memory-hungry in the \
         BackPACK regime; our chunked per-sample pass removes the m x P term."
    );
    Ok(())
}
