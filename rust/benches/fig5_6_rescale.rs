//! Bench: **Figures 5 & 6 + Table 5** (appendix E) — the lr-rescaling
//! variant: every adaptive method scales lr linearly with batch size, and
//! SGD(2048) starts at the linearly-scaled lr.  The paper finds this
//! destabilizes early training on CIFAR-10/100.
//!
//! Run: `cargo bench --bench fig5_6_rescale` (DIVEBATCH_JOBS=N trial-engine
//! workers, unset/0 = all cores)

use divebatch::bench::{bench_header, run_experiment};
use divebatch::config::presets::{realworld, Scale};
use divebatch::runtime::Runtime;

fn scale_from_env() -> Scale {
    match std::env::var("DIVEBATCH_SCALE").as_deref() {
        Ok("bench") => Scale::bench(),
        Ok("paper") => Scale::paper(),
        // Appendix-E variant defaults to quick scale: it re-trains every
        // arm of E3 with different lr configs (no cache sharing), and the
        // paper's finding here is qualitative (instability), which quick
        // scale already exhibits.
        _ => Scale::quick(),
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "fig5_6_rescale",
        "Figures 5/6 + Table 5 (appendix E): linear lr<->batch rescaling ON \
         for all adaptive arms and SGD(large)",
    );
    let scale = scale_from_env();
    let datasets =
        std::env::var("DIVEBATCH_DATASETS").unwrap_or_else(|_| "cifar10,cifar100,tin".into());
    let rt = Runtime::load_default()?;

    for ds in datasets.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let exp = realworld(ds, scale, true).expect("dataset id");
        println!("--- {} ---", exp.title);
        let res = run_experiment(&rt, &exp, false)?;
        println!("{}", res.acc_figure(76, 16)); // Figure 5 panel
        println!("{}", res.loss_figure(76, 16)); // Figure 6 panel
        println!("{}", res.table1().render()); // Table 5 rows
        println!("{}", res.speedup_rows().render());
    }
    println!(
        "paper shape: with rescaling, early-training accuracy is unstable \
         (larger early variance / dips) on CIFAR-10 and CIFAR-100 relative \
         to the main-text (unrescaled) runs."
    );
    Ok(())
}
