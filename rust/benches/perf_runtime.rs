//! Bench: **P1 (§Perf)** — runtime hot-path microbenchmarks: executable
//! dispatch cost vs micro-batch size, literal marshaling overhead,
//! gather cost, optimizer update cost, end-to-end step breakdown.
//!
//! This quantifies the fixed per-dispatch overhead that makes the greedy
//! largest-rung planner (and large batches generally) win — the
//! mechanism behind the paper's efficiency claims on this substrate.
//!
//! Run: `cargo bench --bench perf_runtime`

use divebatch::bench::{bench_header, Bencher};
use divebatch::coordinator::SgdOptimizer;
use divebatch::data::{synthetic, SyntheticSpec};
use divebatch::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bench_header(
        "perf_runtime",
        "P1: dispatch/marshal/update costs across the ladder (logreg512 + resnet10)",
    );
    let rt = Runtime::load_default()?;
    let b = Bencher::default();

    // Pre-compile everything a logreg512 run can touch — both train
    // variants, the eval ladder, AND the fused `update` entry — so no
    // JIT compile lands inside a measured region below.
    rt.warmup("logreg512")?;

    // ---------------- logreg512: dispatch cost per ladder rung ----------
    let info = rt.model("logreg512")?.clone();
    let ds = synthetic::generate(&SyntheticSpec {
        n: 8192,
        d: 512,
        noise: 0.1,
        seed: 0,
    });
    let params = rt.manifest.load_init_params("logreg512", 0)?;
    println!("logreg512 train_div dispatch (includes upload+execute+fetch):");
    for &m in &info.ladder {
        let idx: Vec<u32> = (0..m as u32).collect();
        let batch = ds.gather(&idx, m);
        let exec = rt.train_exec("logreg512", true, m)?;
        let r = b.run(&format!("train_div_b{m}"), Some(m as f64), || {
            exec.run_train(&params, &batch).unwrap();
        });
        println!("  {}", r.line());
    }
    println!();

    // Plain vs instrumented at one size (the diversity surcharge).
    for (label, div) in [("plain", false), ("div", true)] {
        let m = 2048;
        let idx: Vec<u32> = (0..m as u32).collect();
        let batch = ds.gather(&idx, m);
        let exec = rt.train_exec("logreg512", div, m)?;
        let r = b.run(&format!("logreg512 {label}_b{m}"), Some(m as f64), || {
            exec.run_train(&params, &batch).unwrap();
        });
        println!("  {}", r.line());
    }
    println!();

    // ---------------- gather (host-side data marshaling) ----------------
    println!("host-side costs:");
    {
        let idx: Vec<u32> = (0..2048u32).collect();
        let mut buf = divebatch::Batch::empty();
        let r = b.run("gather_into 2048x512", Some(2048.0), || {
            ds.gather_into(&idx, 2048, &mut buf);
        });
        println!("  {}", r.line());
    }

    // ---------------- optimizer step (rust) vs device update ------------
    {
        let p_count = info.param_count;
        let mut params2 = params.clone();
        let grad: Vec<f32> = (0..p_count).map(|i| (i as f32 * 0.01).sin()).collect();
        let mut opt = SgdOptimizer::new(p_count, 0.9, 5e-4);
        let r = b.run("rust sgd step (P=513)", None, || {
            opt.step(&mut params2, &grad, 0.1, 128);
        });
        println!("  {}", r.line());

        let upd = rt.update_exec("logreg512")?;
        let vel = vec![0.0f32; p_count];
        let r = b.run("device sgd update (P=513)", None, || {
            upd.run_update(&params, &vel, &grad, 0.1, 0.9, 5e-4, 1.0 / 128.0)
                .unwrap();
        });
        println!("  {}", r.line());
    }
    println!();

    // ---------------- resnet10: the heavy model ------------------------
    let quick = Bencher::quick();
    let info = rt.model("resnet10")?.clone();
    let img = divebatch::data::images::generate(&divebatch::ImageSpec::cifar10_like(40, 0));
    let params = rt.manifest.load_init_params("resnet10", 0)?;
    println!("resnet10 (P={}):", info.param_count);
    for &m in &info.ladder {
        let idx: Vec<u32> = (0..m.min(img.n()) as u32).collect();
        let batch = img.gather(&idx, m);
        for (label, div) in [("plain", false), ("div", true)] {
            let exec = rt.train_exec("resnet10", div, m)?;
            let r = quick.run(
                &format!("resnet10 {label}_b{m}"),
                Some(m as f64),
                || {
                    exec.run_train(&params, &batch).unwrap();
                },
            );
            println!("  {}", r.line());
        }
    }
    println!();
    println!(
        "compile cache: {} executables, {:.2}s total compile time",
        rt.cached_executables(),
        rt.stats().compile_seconds
    );
    Ok(())
}
