//! Bench: **Figure 2** — ORACLE (exact gradient diversity per epoch) vs
//! DIVEBATCH (within-epoch estimate): validation loss, batch-size
//! progression, and the diversity curves themselves.
//!
//! Run: `cargo bench --bench fig2_oracle` (DIVEBATCH_SCALE=quick|bench|paper,
//! DIVEBATCH_JOBS=N trial-engine workers, unset/0 = all cores)

use divebatch::bench::{bench_header, run_experiment};
use divebatch::config::presets::{preset, Scale};
use divebatch::runtime::Runtime;
use divebatch::util::plot::{render, Series};

fn scale_from_env() -> Scale {
    match std::env::var("DIVEBATCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::bench(),
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "fig2_oracle",
        "Figure 2: Oracle vs DiveBatch — estimate quality of Definition 2 \
         (val loss, batch-size schedule, diversity curves)",
    );
    let scale = scale_from_env();
    let rt = Runtime::load_default()?;

    for id in ["fig2-convex", "fig2-nonconvex"] {
        let exp = preset(id, scale).unwrap();
        println!("--- {} ---", exp.title);
        let res = run_experiment(&rt, &exp, false)?;
        println!("{}", res.loss_figure(76, 12));
        println!("{}", res.batch_figure(76, 12));

        // Diversity curves: estimated (DiveBatch) vs exact (Oracle).
        let mut series = Vec::new();
        if let Some(dive) = res.arm("DiveBatch") {
            series.push(Series::new(
                "estimated Delta (DiveBatch)",
                dive.records[0].delta_hat_curve(),
            ));
        }
        if let Some(oracle) = res.arm("Oracle") {
            series.push(Series::new(
                "exact Delta (Oracle)",
                oracle.records[0].exact_delta_curve(),
            ));
        }
        println!(
            "{}",
            render("gradient diversity: estimate vs exact", "epoch", &series, 76, 12)
        );

        // Estimate-quality summary for EXPERIMENTS.md.
        if let (Some(d), Some(o)) = (res.arm("DiveBatch"), res.arm("Oracle")) {
            let dh = d.records[0].delta_hat_curve();
            let ex = o.records[0].exact_delta_curve();
            let ratios: Vec<f64> = dh
                .iter()
                .zip(&ex)
                .filter(|(a, b)| a.is_finite() && b.is_finite() && **b > 0.0)
                .map(|(a, b)| a / b)
                .collect();
            if !ratios.is_empty() {
                println!(
                    "estimate/exact ratio: mean {:.3}, min {:.3}, max {:.3} (paper: close in convex, drifts in nonconvex)\n",
                    divebatch::util::stats::mean(&ratios),
                    ratios.iter().cloned().fold(f64::INFINITY, f64::min),
                    ratios.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
                );
            }
        }
    }
    Ok(())
}
