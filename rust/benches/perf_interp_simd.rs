//! Bench: **P6 (§Perf)** — the SIMD execution tier vs the scalar tier of
//! the compiled interpreter, on the committed steplogreg8 fixtures.
//!
//! This is the PR-7 accountability bench.  Both tiers run the SAME
//! compiled register program ([`xla::PjRtLoadedExecutable`]) — the tier
//! only swaps kernel strategy (8-lane blocked loops, cost-model-selected
//! dot variants, AVX where the CPU has it, vs plain scalar loops over
//! the identical pinned-lanes contract) — so the ratio isolates exactly
//! what this PR added, and both numerators produce bit-identical outputs
//! (the `differential_interp` suite enforces that).  Every steplogreg8
//! entry is timed at both tiers and `BENCH_6.json` is written at the
//! repo root:
//!
//! ```text
//! entries.<key>.ns_per_step         SIMD tier, median ns per execution
//!                                   (median-of-N, N >= 20 after 5
//!                                   warm-up iterations)
//! entries.<key>.ns_per_step_scalar  scalar tier, same inputs, same run
//! entries.<key>.speedup             scalar / simd
//! ```
//!
//! Target: `train_div_b64` speedup >= 4x (the ISSUE-7 acceptance bar).
//! The committed BENCH_6.json is the regression baseline: CI's perf-smoke
//! step re-runs this bench and fails via python/mirror/check_bench.py if
//! any entry's `speedup` drops below half its committed value.  The
//! ratio compares two in-process code paths on the same machine, so the
//! gate is machine-invariant; raw ns_per_step is recorded for humans.
//! To re-bless after an intentional change, run the bench and commit the
//! refreshed BENCH_6.json.
//!
//! Env knobs: `BENCH_OUT` overrides the output path;
//! `DIVEBATCH_PERF_ENFORCE=1` makes the process exit non-zero when the
//! train_div_b64 target is missed (CI sets it).  `DIVEBATCH_INTERP_TIER`
//! is deliberately ignored here — the bench pins each side's tier
//! explicitly through [`xla::PjRtLoadedExecutable::execute_with_tier`].
//!
//! Run: `cargo bench --bench perf_interp_simd`

use divebatch::bench::{bench_header, fmt_time, Bencher};
use divebatch::runtime::{Dtype, Manifest, TensorSpec};
use divebatch::util::json::Json;
use divebatch::util::rng::Rng;

const TARGET_SPEEDUP: f64 = 4.0;

fn fixtures_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/artifacts").to_string()
}

fn default_out() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_6.json").to_string()
}

fn input_literal(spec: &TensorSpec, rng: &mut Rng) -> xla::Literal {
    let n = spec.elements();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        Dtype::S32 => {
            let v: Vec<i32> = (0..n).map(|_| rng.range(0, 2) as i32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "perf_interp_simd",
        "P6: SIMD tier vs scalar tier of the compiled interpreter \
         (steplogreg8 fixtures); writes BENCH_6.json",
    );
    let manifest = Manifest::load(fixtures_dir())?;
    let model = manifest.model("steplogreg8")?.clone();
    let client = xla::PjRtClient::interp();
    let b = Bencher {
        warmup_iters: 5,
        min_iters: 20,
        max_iters: 20_000,
        target_s: 0.5,
    };

    let mut entries: Vec<(&str, Json)> = Vec::new();
    let mut div_b64_speedup = None;
    println!(
        "{:<16} {:>14} {:>14} {:>9}",
        "entry", "simd", "scalar", "speedup"
    );
    for (key, info) in &model.entries {
        let path = manifest.path(&info.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let mut rng = Rng::new(0x51D6);
        let inputs: Vec<xla::Literal> = info
            .inputs
            .iter()
            .map(|spec| input_literal(spec, &mut rng))
            .collect();

        let simd = b.run(&format!("{key} simd"), None, || {
            exe.execute_with_tier(&inputs, xla::InterpTier::Simd).unwrap();
        });
        let scalar = b.run(&format!("{key} scalar"), None, || {
            exe.execute_with_tier(&inputs, xla::InterpTier::Scalar)
                .unwrap();
        });

        let ns = simd.median_s * 1e9;
        let scalar_ns = scalar.median_s * 1e9;
        let speedup = scalar_ns / ns;
        if key == "train_div_b64" {
            div_b64_speedup = Some(speedup);
        }
        println!(
            "{key:<16} {:>14} {:>14} {:>8.1}x",
            fmt_time(simd.median_s),
            fmt_time(scalar.median_s),
            speedup
        );
        entries.push((
            key.as_str(),
            Json::obj(vec![
                ("ns_per_step", Json::Num(ns)),
                ("ns_per_step_scalar", Json::Num(scalar_ns)),
                ("speedup", Json::Num(speedup)),
            ]),
        ));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_interp_simd".into())),
        ("model", Json::Str("steplogreg8".into())),
        ("target_speedup_train_div_b64", Json::Num(TARGET_SPEEDUP)),
        ("entries", Json::obj(entries)),
    ]);
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| default_out());
    std::fs::write(&out_path, doc.to_string())?;
    println!();
    println!("wrote {out_path}");

    let speedup = div_b64_speedup.expect("train_div_b64 entry present in fixtures");
    if speedup < TARGET_SPEEDUP {
        eprintln!(
            "WARNING: train_div_b64 SIMD-over-scalar speedup {speedup:.1}x is below \
             the {TARGET_SPEEDUP}x target (ISSUE-7 acceptance bar)"
        );
        if std::env::var("DIVEBATCH_PERF_ENFORCE").is_ok_and(|v| v == "1") {
            std::process::exit(1);
        }
    } else {
        println!("train_div_b64 SIMD speedup {speedup:.1}x (target {TARGET_SPEEDUP}x) — OK");
    }
    Ok(())
}
