//! Bench: **P2 (§Perf)** — planner & policy ablations:
//!
//!  * greedy largest-rung plan vs smallest-rung-only accumulation
//!    (end-to-end epoch time at several logical batch sizes);
//!  * ladder granularity: how much padding waste a coarser ladder costs;
//!  * host optimizer vs fused on-device update, end to end;
//!  * delta sweep: DiveBatch's batch trajectory vs delta.
//!
//! Run: `cargo bench --bench perf_plan`

use divebatch::bench::{bench_header, Bencher};
use divebatch::cluster::ClusterModel;
use divebatch::coordinator::{LrSchedule, MicroPlan, Policy, TrainConfig, Trainer};
use divebatch::data::{synthetic, SyntheticSpec};
use divebatch::runtime::Runtime;
use divebatch::util::table::Table;

fn main() -> anyhow::Result<()> {
    bench_header(
        "perf_plan",
        "P2: accumulation-plan + policy ablations (logreg512)",
    );
    let rt = Runtime::load_default()?;
    let info = rt.model("logreg512")?.clone();
    let ds = synthetic::generate(&SyntheticSpec {
        n: 8192,
        d: 512,
        noise: 0.1,
        seed: 0,
    });
    let params = rt.manifest.load_init_params("logreg512", 0)?;
    let b = Bencher::quick();

    // ---- greedy vs smallest-only accumulation for one logical batch ----
    println!("logical-batch execution: greedy ladder plan vs smallest-rung only");
    let mut t = Table::new(
        "plan ablation (one logical batch, train_div)",
        &["m", "plan", "dispatches", "padded rows", "mean time"],
    );
    for &m in &[512usize, 2048, 4096] {
        for (name, plan) in [
            ("greedy", MicroPlan::build(m, &info.ladder, None)),
            ("smallest-only", MicroPlan::build_smallest_only(m, &info.ladder)),
        ] {
            let idx: Vec<u32> = (0..m as u32).collect();
            // Pre-gather all blocks once (isolate execution cost).
            let mut batches = Vec::new();
            let mut off = 0;
            for blk in &plan.blocks {
                batches.push((blk.micro, ds.gather(&idx[off..off + blk.take], blk.micro)));
                off += blk.take;
            }
            let execs: Vec<_> = plan
                .blocks
                .iter()
                .map(|blk| rt.train_exec("logreg512", true, blk.micro).unwrap())
                .collect();
            let r = b.run(&format!("{name}_m{m}"), Some(m as f64), || {
                for (e, (_, batch)) in execs.iter().zip(&batches) {
                    e.run_train(&params, batch).unwrap();
                }
            });
            t.row(vec![
                format!("{m}"),
                name.into(),
                format!("{}", plan.dispatches()),
                format!("{}", plan.padded()),
                divebatch::bench::fmt_time(r.mean_s),
            ]);
        }
    }
    println!("{}", t.render());

    // ---- ladder granularity: padding waste --------------------------
    let mut t = Table::new(
        "ladder granularity (padding waste at odd batch sizes)",
        &["ladder", "m=700", "m=3000", "m=5028"],
    );
    for ladder in [vec![128usize, 512, 2048, 4096], vec![128, 4096], vec![4096]] {
        let waste = |m: usize| {
            let p = MicroPlan::build(m, &ladder, None);
            format!("{:.1}% ({} disp)", 100.0 * p.waste(), p.dispatches())
        };
        t.row(vec![
            format!("{ladder:?}"),
            waste(700),
            waste(3000),
            waste(5028),
        ]);
    }
    println!("{}", t.render());

    // ---- host vs device update: full short run ----------------------
    println!("host vs device optimizer (6-epoch run, n=2048, DiveBatch):");
    let (train, val) = ds.slice(0, 2560).split(0.8);
    for device in [false, true] {
        let mut cfg = TrainConfig::new(
            "logreg512",
            Policy::DiveBatch {
                m0: 128,
                delta: 1.0,
                m_max: 4096,
            },
            LrSchedule::step_075_20(16.0, true),
            6,
        );
        cfg.device_update = device;
        let trainer = Trainer::new(
            &rt,
            cfg,
            train.clone(),
            val.clone(),
            ClusterModel::a100x4(info.param_count, 3e3),
        )?;
        let timer = divebatch::util::timer::Timer::start();
        let out = trainer.run()?;
        println!(
            "  device_update={device}: {:.3}s wall, final acc {:.2}%",
            timer.seconds(),
            out.record.final_val_acc()
        );
    }
    println!();

    // ---- delta sweep (batch trajectory) ------------------------------
    println!("DiveBatch delta sweep (n=2048): end batch size + epochs to m_max");
    let mut t = Table::new(
        "delta ablation",
        &["delta", "end m", "epochs to max", "final acc %"],
    );
    for delta in [0.001, 0.01, 0.1, 1.0] {
        let cfg = TrainConfig::new(
            "logreg512",
            Policy::DiveBatch {
                m0: 128,
                delta,
                m_max: 4096,
            },
            LrSchedule::step_075_20(16.0, true),
            10,
        );
        let trainer = Trainer::new(
            &rt,
            cfg,
            train.clone(),
            val.clone(),
            ClusterModel::a100x4(info.param_count, 3e3),
        )?;
        let rec = trainer.run()?.record;
        let end = rec.end_batch_size();
        let to_max = rec
            .epochs
            .iter()
            .position(|e| e.batch_size == end)
            .unwrap_or(0);
        t.row(vec![
            format!("{delta}"),
            format!("{end}"),
            format!("{to_max}"),
            format!("{:.2}", rec.final_val_acc()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
