//! Bench: **P7 (§Perf)** — the fused blocked convolution kernel vs the
//! materialized im2col path, on the committed tinyresnet8 fixtures.
//!
//! This is the ISSUE-10 accountability bench.  Both sides compile the
//! SAME HLO entry; the only difference is the conv strategy:
//!
//! * **blocked** — the default compile: `cost::select_conv_algo` picks
//!   the fused blocked kernel (`kernels::conv_blocked`) for every conv
//!   that clears the column-reuse + footprint bar (tinyresnet8's forward
//!   convs) and leaves the rest — the tiny-`ng` weight-gradient convs —
//!   on im2col, exactly as production does.
//! * **im2col** — compiled under `DIVEBATCH_CONV_ALGO=im2col`, forcing
//!   every conv through pad + gather + dot + scatter with the patch
//!   matrix materialized in the shared conv scratch.
//!
//! The two strategies are bit-identical (the pinned 8-lane patch-K
//! contract; `differential_interp` enforces it), so the ratio isolates
//! exactly the materialization traffic the blocked kernel removes.
//! Every tinyresnet8 entry is timed on the SIMD tier and `BENCH_7.json`
//! is written at the repo root:
//!
//! ```text
//! entries.<key>.ns_per_step         default compile (blocked where the
//!                                   cost model selects it), median ns
//!                                   per execution (median-of-N, N >= 20
//!                                   after 5 warm-up iterations)
//! entries.<key>.ns_per_step_im2col  DIVEBATCH_CONV_ALGO=im2col compile,
//!                                   same inputs, same run
//! entries.<key>.speedup             im2col / blocked
//! ```
//!
//! Target: `eval_b8` speedup >= 2x (the ISSUE-10 acceptance bar; the
//! forward pass is all blocked-eligible convs, so it is the cleanest
//! conv-dominated probe).  The committed BENCH_7.json is the regression
//! baseline: CI's perf-smoke step re-runs this bench and fails via
//! python/mirror/check_bench.py if any entry's `speedup` drops below
//! half its committed value.  The ratio compares two in-process code
//! paths on the same machine, so the gate is machine-invariant; raw
//! ns_per_step is recorded for humans.  To re-bless after an intentional
//! change, run the bench and commit the refreshed BENCH_7.json.
//!
//! Env knobs: `BENCH_OUT` overrides the output path;
//! `DIVEBATCH_PERF_ENFORCE=1` makes the process exit non-zero when the
//! eval_b8 target is missed (CI sets it).  `DIVEBATCH_CONV_ALGO` is
//! owned by the bench itself (set for the im2col compiles, removed for
//! the default ones); both sides pin the SIMD tier explicitly.
//!
//! Run: `cargo bench --bench perf_conv`

use divebatch::bench::{bench_header, fmt_time, Bencher};
use divebatch::runtime::{Dtype, Manifest, TensorSpec};
use divebatch::util::json::Json;
use divebatch::util::rng::Rng;

const TARGET_SPEEDUP: f64 = 2.0;

fn fixtures_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/artifacts").to_string()
}

fn default_out() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_7.json").to_string()
}

fn input_literal(spec: &TensorSpec, rng: &mut Rng) -> xla::Literal {
    let n = spec.elements();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        Dtype::S32 => {
            let v: Vec<i32> = (0..n).map(|_| rng.range(0, 8) as i32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "perf_conv",
        "P7: fused blocked conv kernel vs forced im2col \
         (tinyresnet8 fixtures); writes BENCH_7.json",
    );
    let manifest = Manifest::load(fixtures_dir())?;
    let model = manifest.model("tinyresnet8")?.clone();
    let client = xla::PjRtClient::interp();
    let b = Bencher {
        warmup_iters: 5,
        min_iters: 20,
        max_iters: 20_000,
        target_s: 0.5,
    };

    let mut entries: Vec<(&str, Json)> = Vec::new();
    let mut eval_b8_speedup = None;
    println!(
        "{:<16} {:>14} {:>14} {:>9}",
        "entry", "blocked", "im2col", "speedup"
    );
    for (key, info) in &model.entries {
        let path = manifest.path(&info.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        // Strategy is chosen at compile time, so each side gets its own
        // compile of the same module (the knob is strategy-only: both
        // executables produce bit-identical outputs).
        std::env::set_var("DIVEBATCH_CONV_ALGO", "im2col");
        let exe_im2col = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        std::env::remove_var("DIVEBATCH_CONV_ALGO");
        let exe_blocked = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let mut rng = Rng::new(0xC07F);
        let inputs: Vec<xla::Literal> = info
            .inputs
            .iter()
            .map(|spec| input_literal(spec, &mut rng))
            .collect();

        let blocked = b.run(&format!("{key} blocked"), None, || {
            exe_blocked
                .execute_with_tier(&inputs, xla::InterpTier::Simd)
                .unwrap();
        });
        let im2col = b.run(&format!("{key} im2col"), None, || {
            exe_im2col
                .execute_with_tier(&inputs, xla::InterpTier::Simd)
                .unwrap();
        });

        let ns = blocked.median_s * 1e9;
        let im2col_ns = im2col.median_s * 1e9;
        let speedup = im2col_ns / ns;
        if key == "eval_b8" {
            eval_b8_speedup = Some(speedup);
        }
        println!(
            "{key:<16} {:>14} {:>14} {:>8.1}x",
            fmt_time(blocked.median_s),
            fmt_time(im2col.median_s),
            speedup
        );
        entries.push((
            key.as_str(),
            Json::obj(vec![
                ("ns_per_step", Json::Num(ns)),
                ("ns_per_step_im2col", Json::Num(im2col_ns)),
                ("speedup", Json::Num(speedup)),
            ]),
        ));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_conv".into())),
        ("model", Json::Str("tinyresnet8".into())),
        ("target_speedup_eval_b8", Json::Num(TARGET_SPEEDUP)),
        ("entries", Json::obj(entries)),
    ]);
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| default_out());
    std::fs::write(&out_path, doc.to_string())?;
    println!();
    println!("wrote {out_path}");

    let speedup = eval_b8_speedup.expect("eval_b8 entry present in fixtures");
    if speedup < TARGET_SPEEDUP {
        eprintln!(
            "WARNING: eval_b8 blocked-over-im2col speedup {speedup:.1}x is below \
             the {TARGET_SPEEDUP}x target (ISSUE-10 acceptance bar)"
        );
        if std::env::var("DIVEBATCH_PERF_ENFORCE").is_ok_and(|v| v == "1") {
            std::process::exit(1);
        }
    } else {
        println!("eval_b8 blocked speedup {speedup:.1}x (target {TARGET_SPEEDUP}x) — OK");
    }
    Ok(())
}
