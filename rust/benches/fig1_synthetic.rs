//! Bench: **Figure 1** — synthetic convex (logreg) and nonconvex (MLP)
//! validation loss/accuracy curves for SGD(small), SGD(large), DiveBatch.
//!
//! Scale via env: DIVEBATCH_SCALE=quick|bench|paper (default bench).
//! Parallelism: DIVEBATCH_JOBS=N trial-engine workers (unset/0 = all
//! cores; use 1 when the real wall-clock columns matter).
//! Run: `cargo bench --bench fig1_synthetic`

use divebatch::bench::{bench_header, run_experiment};
use divebatch::config::presets::{fig1_convex, fig1_nonconvex, Scale};
use divebatch::runtime::Runtime;

fn scale_from_env() -> Scale {
    match std::env::var("DIVEBATCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::bench(),
    }
}

fn main() -> anyhow::Result<()> {
    bench_header(
        "fig1_synthetic",
        "Figure 1: synthetic convex + nonconvex — SGD small/large vs DiveBatch \
         (val loss & accuracy curves; paper section 5.1)",
    );
    let scale = scale_from_env();
    println!(
        "scale: epochs={} trials={} n={}\n",
        scale.epochs, scale.trials, scale.n_synth
    );
    let rt = Runtime::load_default()?;

    for exp in [fig1_convex(scale, false), fig1_nonconvex(scale, false)] {
        println!("--- {} ---", exp.title);
        let res = run_experiment(&rt, &exp, false)?;
        println!("{}", res.loss_figure(76, 14));
        println!("{}", res.acc_figure(76, 14));
        println!("{}", res.table1().render());
        // Paper shape checks, printed for EXPERIMENTS.md:
        if let (Some(dive), Some(small)) = (res.arm("DiveBatch"), res.arm("SGD")) {
            let d_final = divebatch::util::stats::mean(&dive.acc_at(1.0));
            let s_final = divebatch::util::stats::mean(&small.acc_at(1.0));
            println!(
                "shape check: DiveBatch final {:.2}% vs SGD(small) final {:.2}% (paper: comparable, gap < ~2%)\n",
                d_final, s_final
            );
        }
    }
    Ok(())
}
