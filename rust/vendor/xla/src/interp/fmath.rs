//! Deterministic `f32` math kernels for the compiled execution path.
//!
//! The pre-PR evaluator called the platform libm (`f32::exp`, `ln_1p`,
//! ...), whose last-ulp behaviour varies across libc versions — enough to
//! break byte-for-byte golden files pinned on one machine and replayed on
//! another.  The compiled path instead evaluates every transcendental with
//! the fixed `f64` polynomial kernels below: only IEEE-754 basic
//! operations (`+ - * /`, `floor`, `sqrt`, exact power-of-two scaling), in
//! a fixed order, so results are **bit-identical on every platform** and
//! exactly mirrorable from other languages (python/mirror/fmath.py is the
//! line-for-line numpy mirror that generates the committed golden run
//! record).
//!
//! Accuracy: the `f64` cores are accurate to ~1e-12 relative or better on
//! the reduced ranges, far below the 2^-24 `f32` rounding step, so the
//! final rounding to `f32` is faithful (within 1 ulp of the correctly
//! rounded result — the committed jax goldens agree to ~1e-5 relative,
//! same as before).  `sin`/`cos` lose accuracy for |x| > ~2^22 (no
//! Payne–Hanek reduction) but stay deterministic.
//!
//! KEEP IN SYNC with python/mirror/fmath.py: any change to an algorithm,
//! constant, or operation order here must be applied there too, and the
//! golden run record re-blessed.

const LOG2E: f64 = 1.4426950408889634;
const LN2_HI: f64 = 0.6931471803691238;
const LN2_LO: f64 = 1.9082149292705877e-10;
const SQRT_2: f64 = 1.4142135623730951;
const FRAC_2_PI: f64 = 0.6366197723675814;
// fdlibm's two-part pi/2 (pio2_1 / pio2_1t).
const PIO2_HI: f64 = 1.5707963267341256;
const PIO2_LO: f64 = 6.077100506506192e-11;

/// `p * 2^e` for `e` in [-1022, 1023] and normal results: a single exact
/// multiplication by a power of two.
#[inline]
fn scale2(p: f64, e: i64) -> f64 {
    debug_assert!((-1022..=1023).contains(&e));
    p * f64::from_bits(((e + 1023) as u64) << 52)
}

/// `e^x` for |x| <= 700: range reduction `x = k*ln2 + r` with round-half-up
/// `k`, degree-10 Taylor on `r` in [-ln2/2, ln2/2], exact `2^k` scaling.
fn exp_core(x: f64) -> f64 {
    let k = (x * LOG2E + 0.5).floor();
    let hi = x - k * LN2_HI;
    let r = hi - k * LN2_LO;
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0
                            + r * (1.0 / 720.0
                                + r * (1.0 / 5040.0
                                    + r * (1.0 / 40320.0
                                        + r * (1.0 / 362880.0 + r * (1.0 / 3628800.0))))))))));
    scale2(p, k as i64)
}

/// `e^x - 1` for |x| <= 700: direct series in the cancellation-prone
/// |x| <= ln2/2 region, `exp_core - 1` elsewhere.
fn expm1_core(x: f64) -> f64 {
    if x.abs() <= 0.34657359027997264 {
        let r = x;
        r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0
                            + r * (1.0 / 720.0
                                + r * (1.0 / 5040.0
                                    + r * (1.0 / 40320.0
                                        + r * (1.0 / 362880.0 + r * (1.0 / 3628800.0))))))))))
    } else {
        exp_core(x) - 1.0
    }
}

/// atanh-series core shared by ln/ln_1p: `2*atanh(t)` for |t| <= ~0.1716.
fn atanh2_core(t: f64) -> f64 {
    let t2 = t * t;
    2.0 * t
        * (1.0
            + t2 * (1.0 / 3.0
                + t2 * (1.0 / 5.0
                    + t2 * (1.0 / 7.0
                        + t2 * (1.0 / 9.0 + t2 * (1.0 / 11.0 + t2 * (1.0 / 13.0)))))))
}

/// `ln x` for positive, finite, f64-normal `x` (every positive f32 widens
/// to a normal f64): mantissa/exponent split via bit manipulation,
/// atanh series on the mantissa folded into [sqrt(1/2), sqrt(2)).
fn ln_core(x: f64) -> f64 {
    let bits = x.to_bits();
    let mut e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    let mut m = f64::from_bits((bits & 0x000F_FFFF_FFFF_FFFF) | 0x3FF0_0000_0000_0000);
    if m > SQRT_2 {
        m *= 0.5;
        e += 1;
    }
    let t = (m - 1.0) / (m + 1.0);
    let p = atanh2_core(t);
    let ef = e as f64;
    p + ef * LN2_LO + ef * LN2_HI
}

// ------------------------------------------------------------- f32 surface

pub(crate) fn exp(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let xd = x as f64;
    if xd > 700.0 {
        return f32::INFINITY;
    }
    if xd < -700.0 {
        return 0.0;
    }
    exp_core(xd) as f32
}

pub(crate) fn exp_m1(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let xd = x as f64;
    if xd > 700.0 {
        return f32::INFINITY;
    }
    if xd < -700.0 {
        return -1.0;
    }
    expm1_core(xd) as f32
}

pub(crate) fn ln(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    if x < 0.0 {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::NEG_INFINITY;
    }
    if x == f32::INFINITY {
        return x;
    }
    ln_core(x as f64) as f32
}

pub(crate) fn ln_1p(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    if x < -1.0 {
        return f32::NAN;
    }
    if x == -1.0 {
        return f32::NEG_INFINITY;
    }
    if x == f32::INFINITY {
        return x;
    }
    let xd = x as f64;
    if xd > -0.25 && xd < 0.25 {
        let t = xd / (2.0 + xd);
        atanh2_core(t) as f32
    } else {
        ln_core(1.0 + xd) as f32
    }
}

pub(crate) fn logistic(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let xd = x as f64;
    if xd >= 700.0 {
        return 1.0;
    }
    if xd <= -700.0 {
        return 0.0;
    }
    (1.0 / (1.0 + exp_core(-xd))) as f32
}

pub(crate) fn tanh(x: f32) -> f32 {
    if x.is_nan() || x == 0.0 {
        return x;
    }
    let xd = x as f64;
    let a = xd.abs();
    if a >= 20.0 {
        return if xd > 0.0 { 1.0 } else { -1.0 };
    }
    let em = expm1_core(-2.0 * a);
    let t = -em / (2.0 + em);
    (if xd < 0.0 { -t } else { t }) as f32
}

fn sin_poly(r: f64) -> f64 {
    let r2 = r * r;
    r * (1.0
        + r2 * (-1.0 / 6.0
            + r2 * (1.0 / 120.0 + r2 * (-1.0 / 5040.0 + r2 * (1.0 / 362880.0)))))
}

fn cos_poly(r: f64) -> f64 {
    let r2 = r * r;
    1.0 + r2
        * (-0.5
            + r2 * (1.0 / 24.0
                + r2 * (-1.0 / 720.0 + r2 * (1.0 / 40320.0 + r2 * (-1.0 / 3628800.0)))))
}

/// Quadrant + reduced argument for sin/cos (two-part pi/2 reduction; kept
/// entirely in f64 so the quadrant stays deterministic for any input).
fn sincos_reduce(xd: f64) -> (i32, f64) {
    let n = (xd * FRAC_2_PI + 0.5).floor();
    let r = xd - n * PIO2_HI - n * PIO2_LO;
    let nm = n - (n * 0.25).floor() * 4.0;
    ((nm as i32) & 3, r)
}

pub(crate) fn sin(x: f32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    let (q, r) = sincos_reduce(x as f64);
    (match q {
        0 => sin_poly(r),
        1 => cos_poly(r),
        2 => -sin_poly(r),
        _ => -cos_poly(r),
    }) as f32
}

pub(crate) fn cos(x: f32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    let (q, r) = sincos_reduce(x as f64);
    (match q {
        0 => cos_poly(r),
        1 => -sin_poly(r),
        2 => -cos_poly(r),
        _ => sin_poly(r),
    }) as f32
}

pub(crate) fn pow(a: f32, b: f32) -> f32 {
    if b == 0.0 || a == 1.0 {
        return 1.0;
    }
    if a.is_nan() || b.is_nan() {
        return f32::NAN;
    }
    let bd = b as f64;
    let b_is_int = bd.floor() == bd;
    let b_is_odd = b_is_int && (bd * 0.5).floor() * 2.0 != bd;
    if a == 0.0 {
        return if bd > 0.0 {
            if b_is_odd {
                a // preserves the sign of +-0 for odd integer exponents
            } else {
                0.0
            }
        } else if b_is_odd {
            1.0 / a
        } else {
            f32::INFINITY
        };
    }
    if b.is_infinite() {
        let mag = a.abs();
        return match (mag < 1.0, bd > 0.0) {
            (true, true) | (false, false) => 0.0,
            _ => f32::INFINITY,
        };
    }
    if a.is_infinite() {
        let pos = bd > 0.0;
        let neg_base_odd = a < 0.0 && b_is_odd;
        return match (pos, neg_base_odd) {
            (true, false) => f32::INFINITY,
            (true, true) => f32::NEG_INFINITY,
            (false, true) => -0.0,
            (false, false) => 0.0,
        };
    }
    if a < 0.0 && !b_is_int {
        return f32::NAN;
    }
    let t = bd * ln_core((a.abs()) as f64);
    let mag = if t > 700.0 {
        f64::INFINITY
    } else if t < -700.0 {
        0.0
    } else {
        exp_core(t)
    };
    let signed = if a < 0.0 && b_is_odd { -mag } else { mag };
    signed as f32
}

#[inline]
pub(crate) fn sqrt(x: f32) -> f32 {
    x.sqrt() // IEEE-exact on every platform
}

#[inline]
pub(crate) fn rsqrt(x: f32) -> f32 {
    1.0 / x.sqrt() // two correctly-rounded ops, deterministic
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-30)
    }

    #[test]
    fn exp_close_to_libm() {
        for &x in &[-87.0f32, -10.5, -1.0, -0.3, 0.0, 0.3, 1.0, 10.5, 87.0] {
            let got = exp(x) as f64;
            let want = (x as f64).exp();
            assert!(rel(got, want) < 1e-7, "exp({x}): {got} vs {want}");
        }
        assert_eq!(exp(f32::INFINITY), f32::INFINITY);
        assert_eq!(exp(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp(200.0), f32::INFINITY);
        assert!(exp(f32::NAN).is_nan());
    }

    #[test]
    fn ln_and_ln1p_close_to_libm() {
        for &x in &[1e-30f32, 1e-6, 0.5, 1.0, 2.0, 1e6, 3e38] {
            assert!(rel(ln(x) as f64, (x as f64).ln()) < 1e-7, "ln({x})");
        }
        for &x in &[-0.9f32, -0.2, -1e-6, 0.0, 1e-6, 0.2, 5.0, 1e10] {
            assert!(
                rel(ln_1p(x) as f64, (x as f64).ln_1p()) < 1e-7,
                "ln_1p({x})"
            );
        }
        assert_eq!(ln(0.0), f32::NEG_INFINITY);
        assert!(ln(-1.0).is_nan());
        assert_eq!(ln_1p(-1.0), f32::NEG_INFINITY);
        assert!(ln_1p(-1.5).is_nan());
    }

    #[test]
    fn logistic_tanh_expm1() {
        for &x in &[-30.0f32, -2.0, -1e-4, 0.0, 1e-4, 2.0, 30.0] {
            let want = 1.0 / (1.0 + (-(x as f64)).exp());
            assert!(rel(logistic(x) as f64, want) < 1e-7, "logistic({x})");
            assert!(
                (tanh(x) as f64 - (x as f64).tanh()).abs() < 1e-7,
                "tanh({x})"
            );
            assert!(
                (exp_m1(x) as f64 - (x as f64).exp_m1()).abs()
                    < 1e-7 * (1.0 + (x as f64).exp_m1().abs()),
                "exp_m1({x})"
            );
        }
        assert_eq!(tanh(50.0), 1.0);
        assert_eq!(tanh(-50.0), -1.0);
        assert_eq!(logistic(1000.0), 1.0);
        assert_eq!(logistic(-1000.0), 0.0);
    }

    #[test]
    fn sin_cos_on_moderate_range() {
        for i in -200..200 {
            let x = i as f32 * 0.173;
            assert!(
                (sin(x) as f64 - (x as f64).sin()).abs() < 1e-6,
                "sin({x})"
            );
            assert!(
                (cos(x) as f64 - (x as f64).cos()).abs() < 1e-6,
                "cos({x})"
            );
        }
        assert!(sin(f32::INFINITY).is_nan());
        assert!(cos(f32::NAN).is_nan());
    }

    #[test]
    fn pow_edges_and_values() {
        assert_eq!(pow(2.0, 10.0), 1024.0);
        assert!(rel(pow(3.0, 2.5) as f64, (3.0f64).powf(2.5)) < 1e-6);
        assert_eq!(pow(-2.0, 3.0), -8.0);
        assert_eq!(pow(-2.0, 2.0), 4.0);
        assert!(pow(-2.0, 0.5).is_nan());
        assert_eq!(pow(5.0, 0.0), 1.0);
        assert_eq!(pow(f32::NAN, 0.0), 1.0);
        assert_eq!(pow(0.0, 3.0), 0.0);
        assert_eq!(pow(0.0, -2.0), f32::INFINITY);
        assert_eq!(pow(0.5, f32::INFINITY), 0.0);
        assert_eq!(pow(2.0, f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn results_are_reproducible_bit_for_bit() {
        // The whole point of this module: same input, same bits, always.
        for i in 0..1000 {
            let x = (i as f32 - 500.0) * 0.11;
            assert_eq!(exp(x).to_bits(), exp(x).to_bits());
            assert_eq!(tanh(x).to_bits(), tanh(x).to_bits());
        }
    }
}
