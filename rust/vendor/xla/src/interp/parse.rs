//! HLO-text parsing: shapes, attributes, instructions, computations.
//!
//! Produces the [`Module`] consumed by both execution paths (the compiled
//! register program in [`super::program`] and the retained tree-walk
//! [`super::reference`] evaluator).  Anything outside the supported op
//! subset fails here — at "compile" time — with an error naming the
//! opcode, so misuse surfaces before any training loop starts.

use std::collections::HashMap;
use std::fmt;

use crate::{Error, Result};

// ------------------------------------------------------------------ shapes

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum DType {
    F32,
    S32,
    Pred,
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
            DType::Pred => "pred",
        })
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct Shape {
    pub(crate) dtype: DType,
    pub(crate) dims: Vec<usize>,
}

impl Shape {
    pub(crate) fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", self.dtype, dims.join(","))
    }
}

#[derive(Clone, Debug)]
pub(crate) enum ShapeSpec {
    Dense(Shape),
    Tuple(Vec<Shape>),
}

pub(crate) fn err(msg: String) -> Error {
    Error::Interp(msg)
}

pub(crate) fn elements(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Row-major strides for `dims`.
pub(crate) fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Decompose a flat row-major index into coordinates.
pub(crate) fn coords_of(mut flat: usize, dims: &[usize], st: &[usize]) -> Vec<usize> {
    let mut c = vec![0usize; dims.len()];
    for i in 0..dims.len() {
        c[i] = flat / st[i];
        flat %= st[i];
    }
    c
}

/// Cap on declared shape element counts: large enough for any model this
/// interpreter will ever see (the fixtures are tiny; real use is bounded
/// by host memory anyway), small enough that `dims.iter().product()`
/// can never overflow once a shape has parsed.
const MAX_SHAPE_ELEMENTS: usize = 1 << 33;

fn parse_dense_shape(tok: &str) -> Result<Shape> {
    let tok = tok.trim();
    let (dt, rest) = tok
        .split_once('[')
        .ok_or_else(|| err(format!("malformed shape {tok:?}")))?;
    let dtype = match dt.trim() {
        "f32" => DType::F32,
        "s32" => DType::S32,
        "pred" => DType::Pred,
        other => {
            return Err(err(format!(
                "unsupported element type {other:?} (interp handles f32/s32/pred)"
            )))
        }
    };
    let (dims_str, _layout) = rest
        .split_once(']')
        .ok_or_else(|| err(format!("malformed shape {tok:?}")))?;
    let mut dims = Vec::new();
    if !dims_str.trim().is_empty() {
        for d in dims_str.split(',') {
            dims.push(
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| err(format!("bad dimension {d:?} in shape {tok:?}")))?,
            );
        }
    }
    // Reject element counts that overflow (or would plausibly exhaust
    // memory) here at parse time, so `Shape::elements()` and downstream
    // buffer sizing stay panic-free on hostile input.
    let mut elems: usize = 1;
    for &d in &dims {
        elems = elems
            .checked_mul(d)
            .filter(|&e| e <= MAX_SHAPE_ELEMENTS)
            .ok_or_else(|| {
                err(format!(
                    "shape {tok:?} exceeds {MAX_SHAPE_ELEMENTS} elements"
                ))
            })?;
    }
    Ok(Shape { dtype, dims })
}

fn parse_shape_spec(s: &str) -> Result<ShapeSpec> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(') {
        let inner = inner
            .strip_suffix(')')
            .ok_or_else(|| err(format!("malformed tuple shape {s:?}")))?;
        let mut parts = Vec::new();
        for piece in split_top(inner, ',') {
            parts.push(parse_dense_shape(&piece)?);
        }
        Ok(ShapeSpec::Tuple(parts))
    } else {
        Ok(ShapeSpec::Dense(parse_dense_shape(s)?))
    }
}

/// Split on `sep` at nesting depth 0 w.r.t. `()`, `{}`, `[]`.
pub(crate) fn split_top(s: &str, sep: char) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth -= 1,
            _ => {}
        }
        if c == sep && depth == 0 {
            if !cur.trim().is_empty() {
                out.push(cur.trim().to_string());
            }
            cur.clear();
        } else {
            cur.push(c);
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

// --------------------------------------------------------------- constants

/// A parsed constant payload (dtype-neutral storage shared by both
/// execution paths).
#[derive(Clone, Debug)]
pub(crate) enum ConstPayload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

/// A constant value with its shape.
#[derive(Clone, Debug)]
pub(crate) struct ConstValue {
    pub(crate) dims: Vec<usize>,
    pub(crate) payload: ConstPayload,
}

fn parse_constant_payload(payload: &str, shape: &Shape) -> Result<ConstValue> {
    let toks: Vec<String> = payload
        .replace(['{', '}', ','], " ")
        .split_whitespace()
        .map(str::to_string)
        .collect();
    let want = shape.elements();
    if toks.len() != want {
        return Err(err(format!(
            "constant payload has {} values, shape {shape} wants {want}",
            toks.len()
        )));
    }
    let payload = match shape.dtype {
        DType::F32 => {
            let mut v = Vec::with_capacity(want);
            for t in &toks {
                v.push(
                    t.parse::<f32>()
                        .map_err(|_| err(format!("bad f32 constant {t:?}")))?,
                );
            }
            ConstPayload::F32(v)
        }
        DType::S32 => {
            let mut v = Vec::with_capacity(want);
            for t in &toks {
                v.push(
                    t.parse::<i32>()
                        .map_err(|_| err(format!("bad s32 constant {t:?}")))?,
                );
            }
            ConstPayload::I32(v)
        }
        DType::Pred => {
            let mut v = Vec::with_capacity(want);
            for t in &toks {
                v.push(match t.as_str() {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    _ => return Err(err(format!("bad pred constant {t:?}"))),
                });
            }
            ConstPayload::Pred(v)
        }
    };
    Ok(ConstValue {
        dims: shape.dims.clone(),
        payload,
    })
}

// ------------------------------------------------------------ instructions

/// One spatial dimension of a convolution window (`window={...}`).
#[derive(Clone, Copy, Debug)]
pub(crate) struct WindowDim {
    pub(crate) size: usize,
    pub(crate) stride: usize,
    pub(crate) pad_lo: i64,
    pub(crate) pad_hi: i64,
    /// `lhs_dilate` (input dilation — transposed convs).
    pub(crate) base_dilation: usize,
    /// `rhs_dilate` (kernel dilation — atrous convs).
    pub(crate) window_dilation: usize,
}

#[derive(Clone, Debug, Default)]
pub(crate) struct Attrs {
    pub(crate) dimensions: Vec<usize>,
    pub(crate) slice: Vec<(i64, i64, i64)>,
    pub(crate) padding: Vec<(i64, i64, i64)>,
    pub(crate) direction: Option<String>,
    pub(crate) to_apply: Option<String>,
    pub(crate) lhs_contracting: Vec<usize>,
    pub(crate) rhs_contracting: Vec<usize>,
    pub(crate) lhs_batch: Vec<usize>,
    pub(crate) rhs_batch: Vec<usize>,
    pub(crate) index: Option<usize>,
    pub(crate) iota_dimension: Option<usize>,
    pub(crate) window: Vec<WindowDim>,
    pub(crate) dim_labels: Option<String>,
    pub(crate) feature_group_count: Option<usize>,
    pub(crate) batch_group_count: Option<usize>,
    pub(crate) condition: Option<String>,
    pub(crate) body: Option<String>,
    pub(crate) dynamic_slice_sizes: Vec<usize>,
}

/// Parse `{size=3x3 stride=2x2 pad=1_1x1_1 ...}` into per-dimension specs.
/// `size` is required and sets the rank; every other key must match it.
fn parse_window_spec(s: &str) -> Result<Vec<WindowDim>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut size: Option<Vec<usize>> = None;
    let mut stride: Option<Vec<usize>> = None;
    let mut base_dil: Option<Vec<usize>> = None;
    let mut win_dil: Option<Vec<usize>> = None;
    let mut pad: Option<Vec<(i64, i64, i64)>> = None;
    let usizes = |v: &str| -> Result<Vec<usize>> {
        v.split('x')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|_| err(format!("bad window entry {t:?}")))
            })
            .collect()
    };
    for tok in inner.split_whitespace() {
        let Some((key, val)) = tok.split_once('=') else {
            return Err(err(format!("bad window token {tok:?}")));
        };
        match key {
            "size" => size = Some(usizes(val)?),
            "stride" => stride = Some(usizes(val)?),
            "lhs_dilate" => base_dil = Some(usizes(val)?),
            "rhs_dilate" => win_dil = Some(usizes(val)?),
            "pad" => pad = Some(parse_padding_spec(val)?),
            // rarely-emitted keys (window_reversal) are rejected so the
            // lowering can't silently ignore semantics it doesn't model.
            other => return Err(err(format!("unsupported window key {other:?}"))),
        }
    }
    let size = size.ok_or_else(|| err("window spec without size".into()))?;
    let rank = size.len();
    let check = |name: &str, len: usize| -> Result<()> {
        if len != rank {
            return Err(err(format!(
                "window {name} rank {len} does not match size rank {rank}"
            )));
        }
        Ok(())
    };
    if let Some(v) = &stride {
        check("stride", v.len())?;
    }
    if let Some(v) = &base_dil {
        check("lhs_dilate", v.len())?;
    }
    if let Some(v) = &win_dil {
        check("rhs_dilate", v.len())?;
    }
    if let Some(v) = &pad {
        check("pad", v.len())?;
    }
    Ok((0..rank)
        .map(|d| {
            let (pad_lo, pad_hi, _) = pad.as_ref().map(|v| v[d]).unwrap_or((0, 0, 0));
            WindowDim {
                size: size[d],
                stride: stride.as_ref().map(|v| v[d]).unwrap_or(1),
                pad_lo,
                pad_hi,
                base_dilation: base_dil.as_ref().map(|v| v[d]).unwrap_or(1),
                window_dilation: win_dil.as_ref().map(|v| v[d]).unwrap_or(1),
            }
        })
        .collect())
}

/// Drop `/* ... */` comments (jax annotates long tuple types and operand
/// lists with `/*index=N*/`).  An unterminated comment drops the tail.
pub(crate) fn strip_comments(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut rest = s;
    while let Some(i) = rest.find("/*") {
        out.push_str(&rest[..i]);
        match rest[i + 2..].find("*/") {
            Some(j) => rest = &rest[i + 2 + j + 2..],
            None => return out,
        }
    }
    out.push_str(rest);
    out
}

#[derive(Clone, Debug)]
pub(crate) struct Instr {
    pub(crate) name: String,
    pub(crate) shape: ShapeSpec,
    pub(crate) op: String,
    pub(crate) operands: Vec<usize>,
    pub(crate) attrs: Attrs,
    pub(crate) param: Option<usize>,
    pub(crate) literal: Option<ConstValue>,
    pub(crate) is_root: bool,
}

#[derive(Clone, Debug)]
pub(crate) struct Computation {
    pub(crate) name: String,
    pub(crate) instrs: Vec<Instr>,
    pub(crate) root: usize,
    /// Instruction index by parameter number.
    pub(crate) params: Vec<usize>,
}

/// A parsed, compilable HLO module.
#[derive(Debug)]
pub(crate) struct Module {
    pub(crate) computations: Vec<Computation>,
    pub(crate) by_name: HashMap<String, usize>,
    pub(crate) entry: usize,
}

/// Pre-resolution instruction: operand names instead of indices.
struct RawInstr {
    instr: Instr,
    operand_names: Vec<String>,
}

fn parse_usize_set(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for piece in inner.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        out.push(
            piece
                .parse::<usize>()
                .map_err(|_| err(format!("bad integer list entry {piece:?}")))?,
        );
    }
    Ok(out)
}

fn parse_slice_spec(s: &str) -> Result<Vec<(i64, i64, i64)>> {
    // {[0:8], [1:3:2]}
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for piece in split_top(inner, ',') {
        let piece = piece.trim().trim_start_matches('[').trim_end_matches(']');
        let parts: Vec<&str> = piece.split(':').collect();
        if parts.len() != 2 && parts.len() != 3 {
            return Err(err(format!("bad slice spec {piece:?}")));
        }
        let p = |i: usize| -> Result<i64> {
            parts[i]
                .trim()
                .parse::<i64>()
                .map_err(|_| err(format!("bad slice bound {:?}", parts[i])))
        };
        let stride = if parts.len() == 3 { p(2)? } else { 1 };
        out.push((p(0)?, p(1)?, stride));
    }
    Ok(out)
}

fn parse_padding_spec(s: &str) -> Result<Vec<(i64, i64, i64)>> {
    // 8_0 | 0_1x2_3 | 1_1_2 (lo_hi[_interior] per dim, joined by x)
    let mut out = Vec::new();
    for piece in s.trim().split('x') {
        let parts: Vec<&str> = piece.split('_').collect();
        if parts.len() != 2 && parts.len() != 3 {
            return Err(err(format!("bad padding spec {piece:?}")));
        }
        let p = |i: usize| -> Result<i64> {
            parts[i]
                .trim()
                .parse::<i64>()
                .map_err(|_| err(format!("bad padding entry {:?}", parts[i])))
        };
        let interior = if parts.len() == 3 { p(2)? } else { 0 };
        out.push((p(0)?, p(1)?, interior));
    }
    Ok(out)
}

/// Strip an operand token down to its instruction name: the last
/// whitespace-separated word (drops optional type prefixes in canonical
/// HLO), minus any leading `%`.
fn operand_name(tok: &str) -> String {
    tok.split_whitespace()
        .last()
        .unwrap_or("")
        .trim_start_matches('%')
        .to_string()
}

fn parse_instr(line: &str) -> Result<RawInstr> {
    let (lhs, rhs) = line
        .split_once(" = ")
        .ok_or_else(|| err(format!("malformed instruction {line:?}")))?;
    let lhs = lhs.trim();
    let is_root = lhs.starts_with("ROOT ");
    let name = lhs
        .trim_start_matches("ROOT ")
        .trim()
        .trim_start_matches('%')
        .to_string();

    // Shape: a leading parenthesized tuple type, or the first token.
    let rhs = rhs.trim();
    let (shape_str, rest) = if rhs.starts_with('(') {
        let mut depth = 0i32;
        let mut cut = None;
        for (i, c) in rhs.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        cut = Some(i + 1);
                        break;
                    }
                }
                _ => {}
            }
        }
        let cut = cut.ok_or_else(|| err(format!("unbalanced tuple shape in {line:?}")))?;
        (&rhs[..cut], rhs[cut..].trim_start())
    } else {
        let cut = rhs
            .find(' ')
            .ok_or_else(|| err(format!("malformed instruction {line:?}")))?;
        (&rhs[..cut], rhs[cut..].trim_start())
    };
    let shape = parse_shape_spec(shape_str)?;

    // Opcode, then its balanced parenthesized operand list.
    let open = rest
        .find('(')
        .ok_or_else(|| err(format!("missing operand list in {line:?}")))?;
    let op = rest[..open].trim().to_string();
    let mut depth = 0i32;
    let mut close = None;
    for (i, c) in rest.char_indices().skip(open) {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    close = Some(i);
                    break;
                }
            }
            _ => {}
        }
    }
    let close = close.ok_or_else(|| err(format!("unbalanced operand list in {line:?}")))?;
    let payload = &rest[open + 1..close];
    let attrs_str = rest[close + 1..].trim_start_matches(',').trim();

    let mut attrs = Attrs::default();
    for piece in split_top(attrs_str, ',') {
        let Some((key, val)) = piece.split_once('=') else {
            continue;
        };
        match key.trim() {
            "dimensions" => attrs.dimensions = parse_usize_set(val)?,
            "slice" => attrs.slice = parse_slice_spec(val)?,
            "padding" => attrs.padding = parse_padding_spec(val)?,
            "direction" => attrs.direction = Some(val.trim().to_string()),
            "to_apply" => attrs.to_apply = Some(val.trim().trim_start_matches('%').to_string()),
            "lhs_contracting_dims" => attrs.lhs_contracting = parse_usize_set(val)?,
            "rhs_contracting_dims" => attrs.rhs_contracting = parse_usize_set(val)?,
            "lhs_batch_dims" => attrs.lhs_batch = parse_usize_set(val)?,
            "rhs_batch_dims" => attrs.rhs_batch = parse_usize_set(val)?,
            "index" => {
                attrs.index = Some(
                    val.trim()
                        .parse::<usize>()
                        .map_err(|_| err(format!("bad get-tuple-element index {val:?}")))?,
                )
            }
            "iota_dimension" => {
                attrs.iota_dimension = Some(
                    val.trim()
                        .parse::<usize>()
                        .map_err(|_| err(format!("bad iota_dimension {val:?}")))?,
                )
            }
            "window" => attrs.window = parse_window_spec(val)?,
            "dim_labels" => attrs.dim_labels = Some(val.trim().to_string()),
            "feature_group_count" => {
                attrs.feature_group_count = Some(
                    val.trim()
                        .parse::<usize>()
                        .map_err(|_| err(format!("bad feature_group_count {val:?}")))?,
                )
            }
            "batch_group_count" => {
                attrs.batch_group_count = Some(
                    val.trim()
                        .parse::<usize>()
                        .map_err(|_| err(format!("bad batch_group_count {val:?}")))?,
                )
            }
            "condition" => {
                attrs.condition = Some(val.trim().trim_start_matches('%').to_string())
            }
            "body" => attrs.body = Some(val.trim().trim_start_matches('%').to_string()),
            "dynamic_slice_sizes" => attrs.dynamic_slice_sizes = parse_usize_set(val)?,
            // metadata / frontend_attributes / backend_config / sharding /
            // operand_precision … are irrelevant to evaluation.
            _ => {}
        }
    }

    const SUPPORTED: &[&str] = &[
        "parameter",
        "constant",
        "add",
        "subtract",
        "multiply",
        "divide",
        "maximum",
        "minimum",
        "power",
        "remainder",
        "and",
        "or",
        "xor",
        "abs",
        "negate",
        "exponential",
        "exponential-minus-one",
        "log",
        "log-plus-one",
        "logistic",
        "tanh",
        "sqrt",
        "rsqrt",
        "sign",
        "floor",
        "ceil",
        "cosine",
        "sine",
        "not",
        "copy",
        "compare",
        "select",
        "convert",
        "broadcast",
        "reshape",
        "transpose",
        "slice",
        "pad",
        "concatenate",
        "dot",
        "reduce",
        "iota",
        "tuple",
        "get-tuple-element",
        "convolution",
        "reverse",
        "while",
        "call",
        "dynamic-slice",
        "dynamic-update-slice",
    ];
    if !SUPPORTED.contains(&op.as_str()) {
        return Err(err(format!(
            "unsupported HLO opcode {op:?} (instruction {name}) — the interp backend \
             covers the elementwise/dot/reduce/conv/while/shape subset only; link the \
             real xla_extension binding for full HLO"
        )));
    }

    let mut param = None;
    let mut literal = None;
    let mut operand_names = Vec::new();
    match op.as_str() {
        "parameter" => {
            param = Some(
                payload
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| err(format!("bad parameter number {payload:?}")))?,
            );
        }
        "constant" => {
            let ShapeSpec::Dense(s) = &shape else {
                return Err(err(format!("tuple-shaped constant in {line:?}")));
            };
            literal = Some(parse_constant_payload(payload, s)?);
        }
        _ => {
            for tok in split_top(payload, ',') {
                operand_names.push(operand_name(&tok));
            }
        }
    }

    Ok(RawInstr {
        instr: Instr {
            name,
            shape,
            op,
            operands: Vec::new(),
            attrs,
            param,
            literal,
            is_root,
        },
        operand_names,
    })
}

impl Module {
    /// Parse an HLO text module.  Unsupported opcodes are rejected here —
    /// at "compile" time — rather than mid-execution.
    pub(crate) fn parse(text: &str) -> Result<Module> {
        let mut computations: Vec<Computation> = Vec::new();
        let mut by_name: HashMap<String, usize> = HashMap::new();
        let mut entry: Option<usize> = None;
        let mut cur: Option<(String, bool, Vec<RawInstr>)> = None;

        for raw_line in text.lines() {
            // jax annotates long tuple types / operand lists with
            // `/*index=N*/` comments; strip them before tokenizing.
            let stripped;
            let line = if raw_line.contains("/*") {
                stripped = strip_comments(raw_line);
                stripped.trim()
            } else {
                raw_line.trim()
            };
            if line.is_empty() || line.starts_with("HloModule") || line.starts_with("//") {
                continue;
            }
            if line == "}" {
                let (name, is_entry, raws) =
                    cur.take().ok_or_else(|| err("stray '}' in HLO text".into()))?;
                let comp = build_computation(name, raws)?;
                let idx = computations.len();
                if by_name.insert(comp.name.clone(), idx).is_some() {
                    return Err(err(format!("duplicate computation {:?}", comp.name)));
                }
                if is_entry {
                    entry = Some(idx);
                }
                computations.push(comp);
                continue;
            }
            if line.ends_with('{') && !line.contains(" = ") {
                if cur.is_some() {
                    return Err(err("nested computation block in HLO text".into()));
                }
                let is_entry = line.starts_with("ENTRY ");
                let rest = line.strip_prefix("ENTRY ").unwrap_or(line);
                let tok = rest
                    .split_whitespace()
                    .next()
                    .ok_or_else(|| err("missing computation name".into()))?;
                let name = tok
                    .trim_start_matches('%')
                    .split('(')
                    .next()
                    .unwrap_or("")
                    .to_string();
                cur = Some((name, is_entry, Vec::new()));
                continue;
            }
            let Some((_, _, raws)) = cur.as_mut() else {
                return Err(err(format!("instruction outside computation: {line:?}")));
            };
            raws.push(parse_instr(line)?);
        }
        if cur.is_some() {
            return Err(err("unterminated computation block".into()));
        }
        let entry = match entry {
            Some(e) => e,
            None if computations.len() == 1 => 0,
            None => return Err(err("no ENTRY computation in HLO text".into())),
        };
        Ok(Module {
            computations,
            by_name,
            entry,
        })
    }

    pub(crate) fn computation(&self, name: &str) -> Result<&Computation> {
        self.by_name
            .get(name)
            .map(|&i| &self.computations[i])
            .ok_or_else(|| err(format!("unknown computation {name:?}")))
    }

    pub(crate) fn entry_computation(&self) -> &Computation {
        &self.computations[self.entry]
    }
}

fn build_computation(name: String, raws: Vec<RawInstr>) -> Result<Computation> {
    let mut index: HashMap<String, usize> = HashMap::new();
    for (i, r) in raws.iter().enumerate() {
        if index.insert(r.instr.name.clone(), i).is_some() {
            return Err(err(format!(
                "duplicate instruction name {:?} in computation {name:?}",
                r.instr.name
            )));
        }
    }
    let mut instrs = Vec::with_capacity(raws.len());
    let mut params: Vec<(usize, usize)> = Vec::new();
    let mut root = None;
    for (i, raw) in raws.into_iter().enumerate() {
        let mut ins = raw.instr;
        for on in &raw.operand_names {
            let oi = *index.get(on).ok_or_else(|| {
                err(format!(
                    "unknown operand {on:?} of {:?} in computation {name:?}",
                    ins.name
                ))
            })?;
            ins.operands.push(oi);
        }
        if let Some(p) = ins.param {
            params.push((p, i));
        }
        if ins.is_root {
            root = Some(i);
        }
        instrs.push(ins);
    }
    let root = root.unwrap_or(instrs.len().saturating_sub(1));
    if instrs.is_empty() {
        return Err(err(format!("empty computation {name:?}")));
    }
    params.sort();
    for (want, &(got, _)) in params.iter().enumerate() {
        if want != got {
            return Err(err(format!(
                "computation {name:?} has non-contiguous parameter numbers"
            )));
        }
    }
    let params = params.into_iter().map(|(_, i)| i).collect();
    Ok(Computation {
        name,
        instrs,
        root,
        params,
    })
}

pub(crate) fn declared_dense(ins: &Instr) -> Result<&Shape> {
    match &ins.shape {
        ShapeSpec::Dense(s) => Ok(s),
        ShapeSpec::Tuple(_) => Err(err(format!("{}: unexpected tuple shape", ins.name))),
    }
}
